"""Bench regression gate: current bench JSON vs the committed baselines.

CI's bench-smoke job re-runs the quick benches on every push; this
module compares the fresh numbers against the **committed** baselines
(``BENCH_kernels.json``, ``BENCH_serve_adaptive.json``) and fails the
job only on regressions that can't be CPU-runner noise:

* a kernel row slower than ``tolerance``× its baseline (default 2× —
  shared-runner variance on micro-kernels routinely hits 1.5×), or a
  serve driver's wall-clock throughput under 1/tolerance of baseline;
* **any** increase in a serve driver's ``steady_compiles`` — a retrace
  in the steady state is a correctness bug in the bucketing/ladder
  carryover, never noise.

The fleet baseline (``BENCH_fleet.json``) adds two gates of its own:

* ``requests_lost`` in **any** fleet size of the current run must be 0 —
  a lost request means the router journal failed at-most-once failover,
  which is a correctness bug regardless of runner speed;
* each fleet size's ``req_per_s`` may not drop below 1/tolerance of its
  baseline.

Rows present on only one side are reported as informational skips, not
failures: benches gain and lose rows as the suite evolves, and a rename
must not wedge CI.  Keys are read tolerantly (``p50_ms`` or the older
``latency_ms_p50``) so the gate can compare across the rename boundary.

``python -m benchmarks.regression_check --kernels-baseline ... --kernels-current
... --serve-baseline ... --serve-current ... --fleet-baseline ...
--fleet-current ...`` exits 1 on any failure.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_TOLERANCE = 2.0

# serve report keys whose spelling changed across PRs: try left to right
_KEY_ALIASES = {
    "p50_ms": ("p50_ms", "latency_ms_p50"),
    "p99_ms": ("p99_ms", "latency_ms_p99"),
}


def get_key(d: Dict, key: str):
    """Read ``key`` from a report dict, tolerating older spellings."""
    for k in _KEY_ALIASES.get(key, (key,)):
        if k in d:
            return d[k]
    return None


def _rows_by_name(doc: Dict) -> Dict[str, float]:
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def check_kernels(current: Dict, baseline: Dict, *,
                  tolerance: float = DEFAULT_TOLERANCE
                  ) -> Tuple[List[str], List[str]]:
    """(failures, notes) comparing kernel rows by name on us_per_call."""
    cur = _rows_by_name(current)
    base = _rows_by_name(baseline)
    failures, notes = [], []
    for name in sorted(base):
        if name not in cur:
            notes.append(f"kernel row {name!r} missing from current run "
                         "(renamed or removed); skipped")
            continue
        b, c = base[name], cur[name]
        if b > 0 and c > tolerance * b:
            failures.append(
                f"kernel {name}: {c:.1f} us/call vs baseline {b:.1f} "
                f"({c / b:.2f}x > {tolerance:.1f}x tolerance)")
    for name in sorted(set(cur) - set(base)):
        notes.append(f"kernel row {name!r} new (no baseline); skipped")
    return failures, notes


def check_serve(current: Dict, baseline: Dict, *,
                tolerance: float = DEFAULT_TOLERANCE
                ) -> Tuple[List[str], List[str]]:
    """(failures, notes) for the adaptive-serving drivers.

    Throughput may drop to 1/tolerance of baseline; ``steady_compiles``
    (retraces after the warm pass) must never increase.
    """
    failures, notes = [], []
    drivers = [k for k, v in baseline.items() if isinstance(v, dict)
               and "steady_compiles" in v]
    for name in sorted(drivers):
        if name not in current or not isinstance(current[name], dict):
            notes.append(f"serve driver {name!r} missing from current run; "
                         "skipped")
            continue
        b, c = baseline[name], current[name]
        bt, ct = b.get("req_per_s_wall"), c.get("req_per_s_wall")
        if bt and ct and ct < bt / tolerance:
            failures.append(
                f"serve {name}: {ct:.1f} req/s vs baseline {bt:.1f} "
                f"({bt / ct:.2f}x slower > {tolerance:.1f}x tolerance)")
        br, cr = b.get("steady_compiles"), c.get("steady_compiles")
        if br is not None and cr is not None and cr > br:
            failures.append(
                f"serve {name}: steady_compiles rose {br} -> {cr} "
                "(steady-state retrace; not noise)")
    return failures, notes


def check_fleet(current: Dict, baseline: Dict, *,
                tolerance: float = DEFAULT_TOLERANCE
                ) -> Tuple[List[str], List[str]]:
    """(failures, notes) for the fleet failover bench.

    ``requests_lost`` must be 0 in every fleet size of the current run
    (hard correctness gate — the journal guarantees at-most-once
    completion even across a mid-run worker kill); throughput per fleet
    size may drop to 1/tolerance of baseline.
    """
    failures, notes = [], []
    sizes = [k for k, v in current.items() if isinstance(v, dict)
             and "requests_lost" in v]
    for name in sorted(sizes):
        lost = current[name].get("requests_lost", 0)
        if lost:
            failures.append(
                f"fleet {name}: {lost} request(s) lost across the "
                "mid-run worker kill (journal failover broke; not noise)")
    base_sizes = [k for k, v in baseline.items() if isinstance(v, dict)
                  and "req_per_s" in v]
    for name in sorted(base_sizes):
        if name not in current or not isinstance(current[name], dict):
            notes.append(f"fleet size {name!r} missing from current run; "
                         "skipped")
            continue
        bt = baseline[name].get("req_per_s")
        ct = current[name].get("req_per_s")
        if bt and ct and ct < bt / tolerance:
            failures.append(
                f"fleet {name}: {ct:.1f} req/s vs baseline {bt:.1f} "
                f"({bt / ct:.2f}x slower > {tolerance:.1f}x tolerance)")
    return failures, notes


def _load(path: Optional[str]) -> Optional[Dict]:
    if not path:
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels-baseline", default=None, metavar="PATH")
    ap.add_argument("--kernels-current", default=None, metavar="PATH")
    ap.add_argument("--serve-baseline", default=None, metavar="PATH")
    ap.add_argument("--serve-current", default=None, metavar="PATH")
    ap.add_argument("--fleet-baseline", default=None, metavar="PATH")
    ap.add_argument("--fleet-current", default=None, metavar="PATH")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    args = ap.parse_args(argv)

    failures: List[str] = []
    notes: List[str] = []
    for label, base_path, cur_path, check in (
            ("kernels", args.kernels_baseline, args.kernels_current,
             check_kernels),
            ("serve", args.serve_baseline, args.serve_current,
             check_serve),
            ("fleet", args.fleet_baseline, args.fleet_current,
             check_fleet)):
        base, cur = _load(base_path), _load(cur_path)
        if base is None or cur is None:
            notes.append(f"{label}: baseline or current JSON missing "
                         f"({base_path!r} / {cur_path!r}); skipped")
            continue
        f, n = check(cur, base, tolerance=args.tolerance)
        failures += f
        notes += n

    for n in notes:
        print(f"note: {n}")
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("regression check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
