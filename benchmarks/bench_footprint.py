"""Paper Fig. 8: SELLPACK-like streamed elements / CSR nnz vs density.

Reproduces the paper's accounting exactly (END_ROW run-length coding +
NULL padding to the chunk's longest stream) and adds the TPU Block-ELL
footprint ratio (our format adaptation) for the same matrices.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.formats import (BlockELL, CSR, blockell_stream_elements,
                                sellpack_stream_elements)
from repro.data.pipeline import random_sparse_dense


def run(quick: bool = True):
    ns = [4096, 16384] if quick else [16384, 32768, 65536]
    densities = [1e-3, 1e-2, 1e-1]
    mycs = [256, 1024]
    for n in ns:
        for density in densities:
            dense = random_sparse_dense(n, density, seed=42)
            csr = CSR.from_dense(dense)
            nnz = max(csr.nnz, 1)
            for myc in mycs:
                tot = sellpack_stream_elements(csr, myc, 64)
                emit(f"footprint_sellpack_n{n}_d{density:g}_myc{myc}",
                     0.0, f"ratio={tot / nnz:.2f}")
            ell = BlockELL.from_dense(dense, bm=64, bn=64)
            ratio = blockell_stream_elements(ell) / nnz
            emit(f"footprint_blockell_n{n}_d{density:g}_bm64",
                 0.0, f"ratio={ratio:.2f};occupancy={ell.occupancy():.3f}")


if __name__ == "__main__":
    run(quick=False)
