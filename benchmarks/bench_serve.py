"""Batched-serving throughput/latency sweep (the engine-level analog of
the paper's kernel benchmarks).

Drives ``BatchServingEngine`` with a stream of variably-shaped random
graphs at micro-batch sizes {1, 8, 32} and reports, per batch size:

  * req/s and p50/p99 request latency (ms),
  * executor compiles (retraces) vs batched calls,
  * padding waste (the bucket + batch-fill analog of the paper's
    padded-stream blow-up).

Batch 1 is the unbatched baseline — same bucketed executors, one graph
per dispatch; the batch-32 row's ``speedup_vs_unbatched`` shows what
block-diagonal composition buys.  Results also land in
``BENCH_serve.json`` so the perf trajectory is machine-readable.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

BATCH_SIZES = (1, 8, 32)
JSON_PATH = "BENCH_serve.json"


def _make_workload(quick: bool):
    from repro.configs.paper_gnn import GNNConfig
    from repro.models.gnn import build_graph, init_gcn
    from repro.data.pipeline import random_graph

    cfg = GNNConfig(name="serve-bench", in_features=32 if quick else 256,
                    hidden=16 if quick else 128, n_classes=4,
                    n_layers=2 if quick else 3, block_m=16, block_n=16)
    params = init_gcn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    sizes = rng.integers(40, 180 if quick else 720, size=12)
    graphs = [build_graph(random_graph(int(n), avg_degree=4, seed=i), cfg)
              for i, n in enumerate(sizes)]
    n_requests = 96 if quick else 512
    requests = []
    for i in range(n_requests):
        g = graphs[i % len(graphs)]
        x = jnp.asarray(rng.normal(size=(g.n_nodes, cfg.in_features))
                        .astype(np.float32))
        requests.append((g, x))
    return params, requests


def _drive(params, requests, max_batch: int, policy: str) -> Dict:
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=max_batch,
                                          max_delay_ms=4.0,
                                          policy=policy)) as eng:
        # warm every (bucket, batch) executor so the timed pass measures
        # steady-state serving, not XLA compilation
        for g, x in requests:
            eng.submit(g, x)
        eng.drain(timeout=600.0)
        warm_compiles = eng.executor.compiles
        eng.reset_metrics()
        t0 = time.perf_counter()
        futs = [eng.submit(g, x) for g, x in requests]
        for f in futs:
            f.result(timeout=600.0)
        elapsed = time.perf_counter() - t0
        rep = eng.report()
        rep["elapsed_s"] = elapsed
        rep["req_per_s_wall"] = len(requests) / elapsed
        rep["warm_compiles"] = warm_compiles
        rep["steady_compiles"] = eng.executor.compiles - warm_compiles
        return rep


def run(quick: bool = True, policy: str = "auto",
        json_path: Optional[str] = JSON_PATH) -> Dict:
    params, requests = _make_workload(quick)
    results: Dict[str, Dict] = {}
    for mb in BATCH_SIZES:
        rep = _drive(params, requests, mb, policy)
        results[f"batch{mb}"] = rep
        waste = rep["executor"]["waste"]
        emit(f"serve_gcn_b{mb}",
             1e6 / max(rep["req_per_s_wall"], 1e-9),
             f"req_per_s={rep['req_per_s_wall']:.1f};"
             f"p50_ms={rep['p50_ms']:.1f};"
             f"p99_ms={rep['p99_ms']:.1f};"
             f"retraces={rep['steady_compiles']};"
             f"compiles={rep['warm_compiles']};"
             f"padding_waste={waste['waste_fraction']:.3f}")
    speedup = (results["batch32"]["req_per_s_wall"]
               / max(results["batch1"]["req_per_s_wall"], 1e-9))
    emit("serve_gcn_batched_vs_unbatched",
         results["batch32"]["elapsed_s"] * 1e6,
         f"speedup_vs_unbatched={speedup:.2f};"
         f"n_requests={len(requests)}")
    results["speedup_batch32_vs_batch1"] = speedup
    results["n_requests"] = len(requests)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
    # the adaptive-runtime comparison (fixed grid vs learned ladder vs
    # continuous batching, drifting mix) rides the same `--only serve`
    # entry; it emits its own rows and BENCH_serve_adaptive.json
    from benchmarks import bench_serve_adaptive

    results["adaptive"] = bench_serve_adaptive.run(quick=quick,
                                                   policy=policy)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="auto")
    ap.add_argument("--json", default=JSON_PATH,
                    help="path for the structured results dump")
    args = ap.parse_args()
    run(quick=args.quick, policy=args.policy, json_path=args.json)
