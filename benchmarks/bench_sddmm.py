"""Paper Fig. 10: SDDMM speedup vs density, with the mnz (max_nonzeros
per worker tile) sensitivity — here the Block-COO ``pad_to`` analog.

The paper's GAT setting: d=2 (source/destination attention scores),
64x64 tiles per worker.  CPU baseline = dense B@C then mask (SciPy);
accelerator path = element-COO SDDMM (compute only sampled entries).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.formats import BlockCOO
from repro.core.sddmm import sddmm_coo
from repro.data.pipeline import random_sparse_dense
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

D = 2  # paper §4.4: GAT attention-score dimension


def run(quick: bool = True, policy: str = "auto", api: str = "sparse",
        cost_model=None):
    from repro.dispatch import DEFAULT_COST_MODEL, last_plan
    from repro.dispatch.dispatcher import dispatch_sddmm
    from repro.sparse import SparseMatrix
    from repro.sparse import sddmm as sparse_sddmm

    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL

    ns = [2048, 4096] if quick else [2048, 4096, 8192]
    # sparsities 0.999 / 0.99 / 0.9 / 0.5 — the BENCH_kernels.json axis
    densities = [1e-3, 1e-2, 1e-1, 0.5]
    for n in ns:
        b = random_sparse_dense(n, 1.0, seed=3, m=n)[:, :D].copy()
        c = random_sparse_dense(n, 1.0, seed=4, m=D)[:D, :].copy()
        for density in densities:
            if density >= 0.5 and n > 2048 and quick:
                continue  # near-dense points stay small in quick mode
            mask = random_sparse_dense(n, density, seed=23) != 0
            rows, cols = np.nonzero(mask)
            jb, jc = jnp.asarray(b), jnp.asarray(c)
            jr = jnp.asarray(rows.astype(np.int32))
            jcl = jnp.asarray(cols.astype(np.int32))

            def dense_sample():
                return np.where(mask, b @ c, 0.0)

            t_cpu = time_fn(dense_sample, warmup=1, iters=3)
            f = jax.jit(lambda r, cc, bb, ccm: sddmm_coo(r, cc, bb, ccm))
            t_coo = time_fn(f, jr, jcl, jb, jc, warmup=2, iters=5)
            emit(f"sddmm_n{n}_d{density:g}_dense_cpu", t_cpu, "")
            emit(f"sddmm_n{n}_d{density:g}_coo_cpu", t_coo,
                 f"speedup_vs_dense={t_cpu / t_coo:.2f}")

            # the dispatch layer's pick under the requested policy
            if api == "legacy":
                coo_a = BlockCOO.from_dense(mask.astype(np.float32), 64, 64)
                t_disp = time_fn(
                    lambda: dispatch_sddmm(coo_a, jb, jc,
                                           policy=policy).blocks,
                    warmup=1, iters=5)
            else:
                A = SparseMatrix.from_dense(mask.astype(np.float32),
                                            formats=("coo", "csr"))
                t_disp = time_fn(
                    lambda: sparse_sddmm(A, jb, jc, policy=policy,
                                         cost_model=cm).data,
                    warmup=1, iters=5)
            plan = last_plan("sddmm")
            emit(f"sddmm_n{n}_d{density:g}_dispatch_{policy}_{api}", t_disp,
                 f"chosen={plan.path};policy={plan.policy}")

            # mnz sensitivity: Block-COO tile padding overhead (paper: a
            # larger mnz means more device->host bytes for the same work)
            nnz = len(rows)
            for mnz_factor in (1.0, 2.0):
                coo = BlockCOO.from_dense(
                    mask.astype(np.float32), 64, 64,
                    pad_to=int(max(1, mask.reshape(
                        n // 64, 64, n // 64, 64).transpose(0, 2, 1, 3)
                        .reshape(n // 64, n // 64, -1).any(-1).sum()
                        * mnz_factor)))
                bytes_ = coo.blocks.size * 4 + coo.rows.size * 8
                flops = 2.0 * coo.nnzb * 64 * 64 * D
                proj = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
                emit(f"sddmm_n{n}_d{density:g}_mnzx{mnz_factor:g}"
                     "_tpu_projected", proj * 1e6,
                     f"nnzb={coo.nnzb};bytes={bytes_}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "sell", "csr",
                             "dense"])
    ap.add_argument("--api", default="sparse", choices=["legacy", "sparse"],
                    help="dispatch surface: legacy free functions or the "
                         "unified SparseMatrix front-end")
    args = ap.parse_args()
    run(quick=args.quick, policy=args.policy, api=args.api)
