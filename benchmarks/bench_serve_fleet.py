"""Fleet serving under failover: throughput and tail latency across
fleet sizes, with a worker killed mid-run.

Drives a drifting request mix (small → large → mixed sizes) through a
:class:`~repro.serve.fleet.ServingFleet` at 1, 2 and 4 workers.  At the
half-way mark one live worker is hard-killed; the run records

  * wall-clock throughput over the whole storm,
  * p99 latency **before** the kill, **during** the failover window,
    and **after** recovery (the during/after split is what the
    supervisor's respawn + warm-lane pre-compile is supposed to keep
    flat),
  * ``requests_lost`` — which must be **0**: the router journal
    re-routes the dead worker's in-flight to survivors (or parks it
    until the respawn) and every future resolves with a result.

Results land in ``BENCH_fleet.json`` (committed; refreshed as a CI
artifact by the bench-smoke job and gated by
``benchmarks/regression_check.py --fleet-*``).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import emit

JSON_PATH = "BENCH_fleet.json"


def _workload(quick: bool) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Drifting mix: phase A small, phase B large, phase C both."""
    rng = np.random.default_rng(11)
    per_phase = 40 if quick else 120
    d = 8
    phases = [(24, 48), (96, 160 if quick else 256), (24, 160 if quick else 256)]
    reqs = []
    for lo, hi in phases:
        sizes = [int(s) for s in rng.integers(lo, hi, size=4)]
        for _ in range(per_phase):
            n = sizes[int(rng.integers(len(sizes)))]
            dense = (rng.random((n, n)) < 0.1).astype(np.float32)
            h = rng.standard_normal((n, d)).astype(np.float32)
            reqs.append((dense, h))
    return reqs


def _percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _drive(requests, *, workers: int, backend: str, kill: bool) -> Dict:
    from repro.serve.fleet import FleetConfig, ServingFleet

    fleet = ServingFleet(FleetConfig(
        backend=backend, workers=workers, hedge_after_ms=10_000.0,
        max_restarts_per_worker=2))
    try:
        if not fleet.wait_live(workers, timeout=300.0):
            raise RuntimeError(f"fleet of {workers} did not come up")
        # warm every phase's lanes so the storm measures serving, not
        # first compiles
        seen = set()
        for dense, h in requests:
            key = (len(dense), h.shape[1])
            if key not in seen:
                seen.add(key)
                fleet.infer(dense, h, timeout=300.0)

        kill_at = len(requests) // 2
        futs, t_sub = [], []
        killed_t: Optional[float] = None
        t0 = time.perf_counter()
        for i, (dense, h) in enumerate(requests):
            if kill and i == kill_at:
                victims = fleet.sup.live()
                if victims:
                    killed_t = time.perf_counter()
                    fleet._kill_worker(victims[0])
            t_sub.append(time.perf_counter())
            futs.append(fleet.submit(dense, h))
        lat: List[Optional[float]] = []
        for f, ts in zip(futs, t_sub):
            f.result(timeout=600.0)
            lat.append((time.perf_counter() - ts) * 1e3)
        elapsed = time.perf_counter() - t0
        rep = fleet.report()

        # segment latencies by submit epoch relative to the kill: the
        # failover window is the 2s after the kill fired
        before, during, after = [], [], []
        for ts, ms in zip(t_sub, lat):
            if killed_t is None or ts < killed_t:
                before.append(ms)
            elif ts < killed_t + 2.0:
                during.append(ms)
            else:
                after.append(ms)
        return {
            "workers": workers,
            "backend": backend,
            "n_requests": len(requests),
            "req_per_s": len(requests) / elapsed,
            "p50_ms": _percentile(lat, 50),
            "p99_ms": _percentile(lat, 99),
            "p99_before_ms": _percentile(before, 99),
            "p99_during_failover_ms": _percentile(during, 99),
            "p99_after_ms": _percentile(after, 99),
            "requests_lost": rep["fleet"]["requests_lost"],
            "completed": rep["completed"],
            "failed": rep["failed"],
            "worker_states": {k: v["status"]
                              for k, v in rep["workers"].items()},
        }
    finally:
        fleet.close()


def run(quick: bool = True, backend: str = "thread",
        json_path: Optional[str] = JSON_PATH) -> Dict:
    requests = _workload(quick)
    results: Dict[str, object] = {"n_requests": len(requests),
                                  "backend": backend}
    for workers in (1, 2, 4):
        rep = _drive(requests, workers=workers, backend=backend,
                     kill=True)
        assert rep["requests_lost"] == 0, (
            f"fleet of {workers} lost {rep['requests_lost']} requests "
            f"across a mid-run worker kill")
        results[f"fleet_{workers}w"] = rep
        emit(f"serve_fleet_{workers}w",
             1e6 / max(rep["req_per_s"], 1e-9),
             f"req_per_s={rep['req_per_s']:.1f};"
             f"p99_before={rep['p99_before_ms']:.1f};"
             f"p99_during={rep['p99_during_failover_ms']:.1f};"
             f"p99_after={rep['p99_after_ms']:.1f};"
             f"lost={rep['requests_lost']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "process"))
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    run(quick=args.quick, backend=args.backend, json_path=args.json)
