"""Paper Fig. 2: the dense-format wall for GNN training.

The paper shows dense-dense GCN training time scaling with node count
until the dense adjacency exhausts on-chip memory (compile failure beyond
~60k nodes on CS-3).  Here: measure dense-GCN step time vs N on CPU, and
compute the analytic failure point for a 16 GB TPU v5e chip (dense adj
f32) vs the Block-ELL footprint at GNN-typical densities — the Table 1
argument reproduced for our target hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.data.pipeline import random_graph

HBM = 16e9  # v5e


def run(quick: bool = True):
    ns = [512, 1024, 2048] if quick else [512, 1024, 2048, 4096, 8192]
    hidden = 128  # paper Fig. 2 config
    for n in ns:
        adj = random_graph(n, avg_degree=8, seed=5, clustered=False)
        x = np.random.default_rng(0).normal(size=(n, hidden)) \
            .astype(np.float32)
        w = np.random.default_rng(1).normal(size=(hidden, hidden)) \
            .astype(np.float32)

        @jax.jit
        def dense_layer(a, h, w):
            return jax.nn.relu(a @ (h @ w))

        t = time_fn(dense_layer, jnp.asarray(adj), jnp.asarray(x),
                    jnp.asarray(w), warmup=1, iters=3)
        emit(f"dense_gcn_layer_n{n}", t,
             f"adj_bytes={4 * n * n}")

    # analytic wall: largest N whose dense adjacency alone fits one chip
    n_wall = int(np.sqrt(HBM / 4))
    emit("dense_wall_v5e_nodes", 0.0, f"N_max={n_wall}")
    # CSR/Block-ELL footprints for the paper's Table-1-style graphs
    for n, deg in ((169_343, 7), (2_449_029, 25)):  # arxiv, products
        dense_gb = 4 * n * n / 2**30
        csr_gb = (8 * n * deg + 8 * n) / 2**30
        emit(f"footprint_graph_n{n}", 0.0,
             f"dense_GB={dense_gb:.1f};csr_GB={csr_gb:.3f}")


if __name__ == "__main__":
    run(quick=False)
