"""Paper Fig. 9: SpMM performance across density and N (d = 256).

The paper compares CS-3 CSL kernels against CPU (PyTorch sparse / SciPy).
Here the CPU baseline is SciPy CSR SpMM; the accelerator-format path is
the Block-ELL implementation (jnp reference math on CPU — the Pallas
kernel is the TPU target, validated by tests in interpret mode; its
roofline-projected time is derived from the byte/FLOP model).

Derived fields per cell:
  speedup      — SciPy CSR time / Block-ELL time on this CPU
  tpu_roofline — projected TPU time for the Block-ELL kernel:
                 max(flops/197TF, bytes/819GBs) with bytes from the padded
                 Block-ELL layout (the paper's footprint effect shows up
                 here exactly as its Fig. 9 hyper-sparsity cliff).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from benchmarks.common import emit, time_fn
from repro.core.formats import BlockELL
from repro.core.spmm import spmm_dense
from repro.data.pipeline import random_sparse_dense
from repro.kernels.spmm.ref import spmm_blockell_ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

D = 256  # paper §4.1


def tpu_projection(ell: BlockELL, d: int) -> float:
    """Roofline-projected kernel time on one v5e chip (seconds)."""
    nbr, w, bm, bn = ell.blocks.shape
    flops = 2.0 * nbr * w * bm * bn * d  # padded blocks compute too
    bytes_ = (ell.blocks.size * ell.blocks.dtype.itemsize
              + ell.indices.size * 4
              + nbr * w * bn * d * 2  # gathered H tiles (bf16)
              + nbr * bm * d * 4)  # f32 output
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def run(quick: bool = True, policy: str = "auto", api: str = "sparse",
        cost_model=None):
    from repro.dispatch import DEFAULT_COST_MODEL, last_plan
    from repro.dispatch._forms import LazyForms
    from repro.dispatch.dispatcher import dispatch_spmm
    from repro.sparse import SparseMatrix, matmul

    cm = cost_model if cost_model is not None else DEFAULT_COST_MODEL

    ns = [2048, 4096] if quick else [2048, 4096, 8192, 16384]
    # sparsities 0.999 / 0.99 / 0.9 / 0.5 — the BENCH_kernels.json axis
    densities = [1e-3, 1e-2, 1e-1, 0.5]
    for n in ns:
        h = random_sparse_dense(n, 1.0, seed=7, m=n)[:, :D].copy()
        for density in densities:
            if density >= 0.5 and n > 2048 and quick:
                continue  # near-dense points stay small in quick mode
            dense = random_sparse_dense(n, density, seed=13)
            csr = sp.csr_matrix(dense)
            ell = BlockELL.from_dense(dense, bm=64, bn=64)

            t_csr = time_fn(lambda: csr @ h, warmup=1, iters=5)
            jh = jnp.asarray(h)
            blocked = jax.jit(lambda e, hh: spmm_blockell_ref(e, hh))
            t_ell = time_fn(blocked, ell, jh, warmup=2, iters=5)
            jd = jnp.asarray(dense)
            t_dense = time_fn(jax.jit(spmm_dense), jd, jh, warmup=1,
                              iters=3)
            proj = tpu_projection(ell, D)
            emit(f"spmm_n{n}_d{density:g}_csr_cpu", t_csr, "")
            emit(f"spmm_n{n}_d{density:g}_blockell_cpu", t_ell,
                 f"speedup_vs_csr={t_csr / t_ell:.2f};"
                 f"occupancy={ell.occupancy():.3f}")
            if density <= 1e-2:
                # the hyper-sparse regime the SELL-C-σ path targets
                from repro.core.formats import SellCS
                from repro.sparse.paths import spmm_sell_ref

                sell = SellCS.from_dense(dense, block=(64, 64))
                t_sell = time_fn(jax.jit(spmm_sell_ref), sell, jh,
                                 warmup=2, iters=5)
                emit(f"spmm_n{n}_d{density:g}_sell_cpu", t_sell,
                     f"speedup_vs_blockell={t_ell / t_sell:.2f};"
                     f"slots={sell.n_slots}")
            emit(f"spmm_n{n}_d{density:g}_dense_cpu", t_dense,
                 f"speedup_vs_dense={t_dense / t_ell:.2f}")
            emit(f"spmm_n{n}_d{density:g}_blockell_tpu_projected",
                 proj * 1e6,
                 f"projected_speedup_vs_cpu_csr={t_csr / (proj * 1e6):.1f}")

            # the dispatch layer's pick under the requested policy —
            # either the legacy free-function surface or the unified
            # SparseMatrix front-end (whose steady state is the
            # plan-cache hit path: plan once, then execute)
            if api == "legacy":
                op = LazyForms.from_dense(dense, block_m=64, block_n=64)
                t_disp = time_fn(
                    lambda: dispatch_spmm(op, jh, policy=policy),
                    warmup=1, iters=5)
            else:
                A = SparseMatrix.from_dense(dense, formats=("ell", "csr"))
                t_disp = time_fn(
                    lambda: matmul(A, jh, policy=policy, cost_model=cm),
                    warmup=1, iters=5)
            plan = last_plan("spmm")
            emit(f"spmm_n{n}_d{density:g}_dispatch_{policy}_{api}", t_disp,
                 f"chosen={plan.path};policy={plan.policy}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "sell", "csr",
                             "dense"])
    ap.add_argument("--api", default="sparse", choices=["legacy", "sparse"],
                    help="dispatch surface: legacy free functions or the "
                         "unified SparseMatrix front-end")
    args = ap.parse_args()
    run(quick=args.quick, policy=args.policy, api=args.api)
