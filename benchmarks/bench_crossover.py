"""The paper's crossover curve: SpMM path choice vs sparsity.

Sweeps sparsity from 0.5 to 0.999 and, per point, reports

  * the analytic cost model's numbers and chosen path,
  * measured wall-times of every path on this CPU,
  * the measured winner (the empirical crossover),
  * the SELL-C-σ speedup over the best other non-dense path (the
    quantified "cliff kill": past 99 % sparsity the Block-ELL padded
    stream and the csr scatter both degrade; sell's width-adaptive
    tile-pruned packing does neither),

as a JSON document with per-point chosen-path labels — the executable
form of the paper's Fig. 9 observation that the Block-ELL/SELLPACK-style
streaming design wins at moderate sparsity and degrades past ~99%.

Usage:
  PYTHONPATH=src:. python -m benchmarks.bench_crossover --sweep
  ... --policy {auto,autotune,ell,sell,csr,dense}  (policy to label)
  ... --out crossover.json                         (default: stdout)
"""
from __future__ import annotations

import argparse
import json
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.dispatch import last_plan
from repro.dispatch.policy import PATHS
from repro.sparse import SparseMatrix, matmul

SPARSITIES = [0.5, 0.75, 0.9, 0.95, 0.99, 0.995, 0.999]
# Small blocks keep the block-granular layout honest under *uniform*
# element sparsity (the paper's synthetic workload): with big blocks
# every block is nonzero long past the crossover and the curve is flat.
BLOCK = 4


def sweep(n: int = 1024, d: int = 64, *, policy: str = "auto",
          seed: int = 0, quick: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    points = []
    for s in SPARSITIES:
        mask = rng.random((n, n)) < (1.0 - s)
        dense = np.where(mask, rng.normal(size=(n, n)), 0.0) \
            .astype(np.float32)
        op = SparseMatrix.from_dense(dense, formats=("ell", "csr", "sell"),
                                     block=(BLOCK, BLOCK))
        stats = op.stats

        # dispatch under the requested policy (records the plan)
        matmul(op, h, policy=policy)
        plan = last_plan("spmm")

        # measure every path's jitted steady-state (what a consumer that
        # bakes the plan into its jitted forward actually pays)
        import jax

        from repro.kernels.spmm.ref import spmm_blockell_ref
        from repro.sparse.paths import (spmm_dense, spmm_elements,
                                        spmm_sell_ref)

        row_ids, col_ids, values = op.form("csr")
        iters = 5 if quick else 9
        times = {
            "ell": time_fn(jax.jit(spmm_blockell_ref), op.form("ell"), h,
                           warmup=2, iters=iters),
            "sell": time_fn(jax.jit(spmm_sell_ref), op.form("sell"), h,
                            warmup=2, iters=iters),
            "csr": time_fn(
                jax.jit(lambda r, c, v, hh: spmm_elements(r, c, v, hh, n)),
                row_ids, col_ids, values, h, warmup=2, iters=iters),
            "dense": time_fn(jax.jit(spmm_dense), jnp.asarray(dense), h,
                             warmup=2, iters=iters),
        }
        measured = min(times, key=times.get)
        best_other = min(times["ell"], times["csr"])

        points.append({
            "sparsity": s,
            "density": stats.density,
            "nnz": stats.nnz,
            "occupancy": stats.occupancy,
            "padded_stream_blowup": stats.padded_stream_blowup,
            "sell_slot_blowup": stats.sell_stored_elements
            / max(stats.nnz, 1),
            "chosen": plan.path,
            "policy": plan.policy,
            "costs": plan.costs,
            "times_us": times,
            "measured_winner": measured,
            "sell_speedup_vs_best_other": best_other / times["sell"],
        })
    return {
        "op": "spmm",
        "n": n,
        "d": d,
        "block": BLOCK,
        "policy": policy,
        "points": points,
    }


def run(quick: bool = True, policy: str = "auto"):
    """benchmarks.run entry: print the curve as name,us,derived rows."""
    result = sweep(n=512 if quick else 1024, d=64, policy=policy,
                   quick=quick)
    for pt in result["points"]:
        for path, us in pt["times_us"].items():
            mark = "*" if path == pt["chosen"] else ""
            derived = (f"chosen={pt['chosen']};"
                       f"winner={pt['measured_winner']}")
            if path == "sell":
                derived += (";speedup_vs_best_other="
                            f"{pt['sell_speedup_vs_best_other']:.2f}")
            print(f"crossover_s{pt['sparsity']:g}_{path}{mark},{us:.1f},"
                  f"{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="emit the JSON crossover curve")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "sell", "csr",
                             "dense"])
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    result = sweep(n=args.n, d=args.d, policy=args.policy, quick=args.quick)
    doc = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc + "\n")
        labels = [(p["sparsity"], p["chosen"]) for p in result["points"]]
        print(f"wrote {args.out}; chosen paths: {labels}", file=sys.stderr)
    else:
        print(doc)


if __name__ == "__main__":
    main()
