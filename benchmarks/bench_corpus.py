"""Structured-corpus sweep: every execution path over every matrix family.

The paper's kernels were characterized on uniform-random sparsity; real
workload matrices (DLMC, graph adjacencies, banded systems) have
structure that moves the crossovers.  This bench runs the synthetic
corpus (``repro.corpus``) — uniform / powerlaw / rmat / banded /
block_pruned at moderate and hyper sparsity — through ALL four SpMM
execution paths (forced) plus the auto plan, and the SpMV fast lane.

Each row carries the measured structure features (row-nnz CV, max row
nnz, bandwidth fraction) and which path the cost model picked, so the
JSON baseline shows *why* dispatch diverges across families at equal
global sparsity — the hub-heavy powerlaw matrix abandons the streaming
path long before the uniform one does.

Writes ``BENCH_corpus.json`` (the committed structured-matrix baseline).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

JSON_PATH = "BENCH_corpus.json"

PATHS = ("dense", "ell", "sell", "csr")


def run(quick: bool = True, policy: str = "auto",
        json_path: Optional[str] = JSON_PATH) -> Dict:
    from repro.corpus import default_corpus, make_matrix
    from repro.dispatch.dispatcher import plan_spmm, plan_spmv
    from repro.sparse import available_paths, matmul, spmv

    d = 64
    block = (8, 8) if quick else (16, 16)
    rows: List[Dict] = []
    rng = np.random.default_rng(11)
    for spec in default_corpus(quick=quick):
        a = make_matrix(spec, formats=("ell", "sell", "csr"), block=block)
        stats = a.stats
        cand = available_paths(a)
        auto = plan_spmm(stats, d, candidates=cand).path
        structure = (f"nnz={stats.nnz};cv={stats.row_nnz_cv:.2f};"
                     f"maxrow={stats.max_row_nnz};"
                     f"band={stats.bandwidth_frac:.2f}")
        n = a.shape[1]
        h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        tag = f"corpus_{spec.family}_s{spec.sparsity:g}"
        for path in PATHS:
            us = time_fn(jax.jit(
                lambda x, p=path: matmul(a, x, policy=p)), h)
            derived = structure + f";auto={auto}" \
                + (";picked" if path == auto else "")
            emit(f"{tag}_{path}", us, derived)
            rows.append({
                "name": f"{tag}_{path}", "family": spec.family,
                "sparsity": spec.sparsity, "path": path,
                "us_per_call": round(us, 1), "auto_path": auto,
                "nnz": stats.nnz, "row_nnz_cv": round(stats.row_nnz_cv, 3),
                "max_row_nnz": stats.max_row_nnz,
                "bandwidth_frac": round(stats.bandwidth_frac, 3),
            })
        # the SpMV fast lane (d = 1) replans on the unit-width surface
        v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        auto_v = plan_spmv(stats, candidates=cand).path
        us = time_fn(jax.jit(lambda x: spmv(a, x)), v)
        emit(f"{tag}_spmv", us, structure + f";auto={auto_v}")
        rows.append({
            "name": f"{tag}_spmv", "family": spec.family,
            "sparsity": spec.sparsity, "path": auto_v,
            "us_per_call": round(us, 1), "auto_path": auto_v,
            "nnz": stats.nnz, "row_nnz_cv": round(stats.row_nnz_cv, 3),
            "max_row_nnz": stats.max_row_nnz,
            "bandwidth_frac": round(stats.bandwidth_frac, 3),
        })
    out = {
        "bench": "corpus",
        "quick": quick,
        "d": d,
        "block": list(block),
        "families": sorted({r["family"] for r in rows}),
        "paths": list(PATHS) + ["spmv"],
        "rows": rows,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {json_path} ({len(rows)} rows)")
    return out
