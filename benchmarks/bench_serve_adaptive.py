"""Adaptive serving runtime vs the fixed grid, on a drifting mix.

The fixed geometric bucket grid prices every request up by a constant
growth factor; on mixed traffic 40–55 % of the streamed volume is
padding (``BENCH_serve.json``).  This bench drives the same GCN serving
workload through three configurations over a **drifting** request mix —
phase A (small graphs), phase B (large graphs), phase C (both) — and
reports each one's padding waste and latency:

  * ``micro_fixed``     — ``BatchServingEngine``, fixed geometric grid
                          (the status-quo baseline),
  * ``micro_adaptive``  — same engine, quantile-learned bucket ladder
                          (``BatchServeConfig(adaptive=True)``),
  * ``continuous``      — ``ContinuousBatchEngine`` (adaptive ladder +
                          slot-recycled running batches).

Results land in ``BENCH_serve_adaptive.json`` (committed; refreshed as
a CI artifact by the bench-smoke job via ``--only serve``).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

JSON_PATH = "BENCH_serve_adaptive.json"


def _make_drifting_workload(quick: bool):
    """(params, requests) with the size mix drifting across 3 phases.

    Within each phase traffic is shape-skewed — a few *hot* sizes take
    ~75 % of the requests, a long tail the rest — the realistic serving
    profile: the ladder parks rungs exactly on the hot shapes while the
    geometric grid pads every one of them up by ~half a growth step.
    """
    from repro.configs.paper_gnn import GNNConfig
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gcn

    cfg = GNNConfig(name="serve-adaptive-bench",
                    in_features=32 if quick else 128,
                    hidden=16 if quick else 64, n_classes=4,
                    n_layers=2, block_m=16, block_n=16)
    params = init_gcn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    per_phase = 96 if quick else 288
    phases: List[Tuple[int, int]] = [
        (40, 160),                      # A: small graphs
        (200, 420 if quick else 900),   # B: traffic drifts large
        (40, 420 if quick else 900),    # C: mixed tail
    ]
    requests = []
    for p, (lo, hi) in enumerate(phases):
        hot = rng.integers(lo, hi, size=3)
        tail = rng.integers(lo, hi, size=8)
        graphs = {int(n): build_graph(
            random_graph(int(n), avg_degree=4, seed=100 * p + i), cfg)
            for i, n in enumerate(np.concatenate([hot, tail]))}
        for i in range(per_phase):
            pool = hot if rng.random() < 0.75 else tail
            g = graphs[int(pool[rng.integers(len(pool))])]
            x = jnp.asarray(rng.normal(size=(g.n_nodes, cfg.in_features))
                            .astype(np.float32))
            requests.append((g, x))
    return params, requests


def _summarize(rep: Dict, elapsed: float, n: int) -> Dict:
    waste = rep["executor"]["waste"]
    out = {
        "req_per_s_wall": n / elapsed,
        "p50_ms": rep["p50_ms"],
        "p99_ms": rep["p99_ms"],
        "waste_fraction": waste["waste_fraction"],
        "nnz_blowup": waste["nnz_blowup"],
        "compiles": rep["executor"]["compiles"],
        "buckets": rep["executor"]["buckets"],
        "per_bucket_waste": {
            k: v["waste_fraction"]
            for k, v in waste.get("per_bucket", {}).items()},
    }
    if "ladder" in rep["executor"]:
        lad = rep["executor"]["ladder"]
        out["ladder"] = {k: lad[k] for k in
                         ("refits", "fallbacks", "snapped_rungs",
                          "last_drift")}
        out["rungs"] = {d: len(r) for d, r in lad["rungs"].items()}
    return out


def _drive_micro(params, requests, *, policy: str, adaptive: bool) -> Dict:
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    scfg = BatchServeConfig(max_batch=32, max_delay_ms=4.0, policy=policy,
                            adaptive=adaptive)
    with BatchServingEngine.for_gcn(params, scfg=scfg) as eng:
        for g, x in requests:         # warm compiles (and the ladder)
            eng.submit(g, x)
        eng.drain(timeout=600.0)
        warm = eng.executor.compiles
        eng.reset_metrics()
        # the warm pass ran partly on the ladder's pre-fit geometric
        # fallback; measure steady-state waste only
        eng.executor.waste = type(eng.executor.waste)()
        t0 = time.perf_counter()
        futs = [eng.submit(g, x) for g, x in requests]
        for f in futs:
            f.result(timeout=600.0)
        elapsed = time.perf_counter() - t0
        out = _summarize(eng.report(), elapsed, len(requests))
        out["steady_compiles"] = eng.executor.compiles - warm
        return out


def _drive_continuous(params, requests, *, policy: str) -> Dict:
    from repro.serve.runtime import ContinuousBatchEngine, ContinuousConfig

    # a wider batching window than the default lets low-traffic lanes
    # accumulate occupants instead of stepping near-empty
    cfg = ContinuousConfig(slots=4, policy=policy, adaptive=True,
                           max_wait_ms=40.0)
    with ContinuousBatchEngine.for_gcn(params, cfg=cfg) as eng:
        for g, x in requests:         # warm pass
            eng.submit(g, x)
        eng.drain(timeout=600.0)
        warm = eng.executor.compiles
        eng.reset_metrics()
        t0 = time.perf_counter()
        futs = []
        # admission keeps a backlog of a few waves, so freed slots have
        # queued work to recycle and lanes step full — the continuous
        # engine's intended operating point
        backlog = 8 * cfg.slots
        for i, (g, x) in enumerate(requests):
            futs.append(eng.submit(g, x))
            while eng.pending() > backlog:
                eng.step()
        eng.drain(timeout=600.0)
        for f in futs:
            f.result(timeout=600.0)
        elapsed = time.perf_counter() - t0
        rep = eng.report()
        out = _summarize(rep, elapsed, len(requests))
        out["steady_compiles"] = eng.executor.compiles - warm
        out["lanes"] = {k: round(v["occupancy"], 3)
                        for k, v in rep["lanes"].items()}
        return out


def run(quick: bool = True, policy: str = "auto",
        json_path: Optional[str] = JSON_PATH) -> Dict:
    params, requests = _make_drifting_workload(quick)
    results: Dict[str, Dict] = {"n_requests": len(requests)}
    drivers = {
        "micro_fixed": lambda: _drive_micro(params, requests,
                                            policy=policy, adaptive=False),
        "micro_adaptive": lambda: _drive_micro(params, requests,
                                               policy=policy, adaptive=True),
        "continuous": lambda: _drive_continuous(params, requests,
                                                policy=policy),
    }
    for name, fn in drivers.items():
        rep = fn()
        results[name] = rep
        emit(f"serve_adaptive_{name}",
             1e6 / max(rep["req_per_s_wall"], 1e-9),
             f"req_per_s={rep['req_per_s_wall']:.1f};"
             f"p50_ms={rep['p50_ms']:.1f};"
             f"p99_ms={rep['p99_ms']:.1f};"
             f"waste={rep['waste_fraction']:.3f};"
             f"retraces={rep['steady_compiles']}")
    fixed = results["micro_fixed"]["waste_fraction"]
    adap = results["micro_adaptive"]["waste_fraction"]
    results["waste_cut"] = fixed - adap
    emit("serve_adaptive_waste_cut", 0.0,
         f"fixed={fixed:.3f};adaptive={adap:.3f};"
         f"continuous={results['continuous']['waste_fraction']:.3f}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="auto")
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    run(quick=args.quick, policy=args.policy, json_path=args.json)
