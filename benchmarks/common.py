"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time in microseconds of a jit'd callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# Rows emitted by the current process, in order — the harness's --json
# mode serializes these alongside the CSV stream.
ROWS: List[Dict] = []


def reset_rows() -> None:
    ROWS.clear()


def emit(name: str, us: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}")
