"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_dense_limit  — Fig. 2 (dense-format wall)
  bench_footprint    — Fig. 8 (SELLPACK-like vs CSR footprint)
  bench_spmm         — Fig. 9 (SpMM vs density/N, d=256)
  bench_sddmm        — Fig. 10 (SDDMM vs density, d=2, mnz sensitivity)

``python -m benchmarks.run [--full]`` (quick mode by default so the CPU
container finishes in minutes; --full matches the paper's largest sizes).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_dense_limit, bench_footprint, bench_sddmm,
                            bench_spmm)
    benches = {
        "dense_limit": bench_dense_limit.run,
        "footprint": bench_footprint.run,
        "spmm": bench_spmm.run,
        "sddmm": bench_sddmm.run,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn(quick=quick)


if __name__ == "__main__":
    main()
