"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_dense_limit  — Fig. 2 (dense-format wall)
  bench_footprint    — Fig. 8 (SELLPACK-like vs CSR footprint)
  bench_spmm         — Fig. 9 (SpMM vs density/N, d=256)
  bench_sddmm        — Fig. 10 (SDDMM vs density, d=2, mnz sensitivity)
  bench_crossover    — Fig. 9's crossover as a dispatch-path sweep
  bench_serve        — batched-serving throughput/latency sweep (also
                       writes BENCH_serve.json) + the adaptive-runtime
                       comparison on a drifting mix (bench_serve_adaptive,
                       writes BENCH_serve_adaptive.json)
  bench_fused        — fused-vs-unfused GCN epilogue + GAT attention
                       sweep (also writes BENCH_fused.json)
  bench_corpus       — structured-matrix corpus (uniform/powerlaw/rmat/
                       banded/block_pruned) over every execution path +
                       the SpMV lane (also writes BENCH_corpus.json)
  bench_serve_fleet  — multi-worker fleet with a mid-run worker kill:
                       throughput + p99 before/during/after failover,
                       requests-lost must be 0 (writes BENCH_fleet.json)

``python -m benchmarks.run [--full] [--policy auto] [--json out.json]``
(quick mode by default so the CPU container finishes in minutes; --full
matches the paper's largest sizes; --policy sets the dispatch policy for
the benches that route through the dispatch layer; --json additionally
dumps every emitted row plus the plan-cache counters as JSON;
--calibrate runs the ``dispatch.autotune.calibrate`` microbenchmark
first and prices the spmm/sddmm benches with the measured constants,
round-tripped through an ``AutotuneCache`` save/load).

When both kernel benches (spmm + sddmm) run with ``--json``, their rows
are additionally written to ``BENCH_kernels.json`` — the committed
kernel-performance baseline future PRs regress against (the CI
bench-smoke job refreshes it as an artifact every push).
"""
import argparse
import json
import sys

KERNELS_BASELINE = "BENCH_kernels.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "sell", "csr",
                             "dense"])
    ap.add_argument("--api", default="sparse", choices=["legacy", "sparse"],
                    help="dispatch surface for the spmm/sddmm benches")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON to PATH")
    ap.add_argument("--calibrate", action="store_true",
                    help="measure the cost-model constants on this "
                         "backend first and use them for the kernel "
                         "benches (persisted via AutotuneCache)")
    ap.add_argument("--obs-snapshot", default=None, metavar="PATH",
                    help="write repro.obs.snapshot() (metrics, span "
                         "summary, retrace sentry, cost audit) as JSON "
                         "after the benches finish")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_corpus, bench_crossover,
                            bench_dense_limit, bench_footprint, bench_fused,
                            bench_sddmm, bench_serve, bench_serve_fleet,
                            bench_spmm, common)
    from repro.sparse import plan_cache_stats, reset_plan_cache_stats
    benches = {
        "dense_limit": bench_dense_limit.run,
        "footprint": bench_footprint.run,
        "spmm": bench_spmm.run,
        "sddmm": bench_sddmm.run,
        "crossover": bench_crossover.run,
        "serve": bench_serve.run,
        "fused": bench_fused.run,
        "corpus": bench_corpus.run,
        "fleet": bench_serve_fleet.run,
    }
    dispatched = {"spmm", "sddmm", "crossover", "serve", "fused", "corpus"}
    api_axis = {"spmm", "sddmm"}
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(benches)
        if unknown:
            ap.error(f"unknown bench name(s) {sorted(unknown)}; "
                     f"expected among {sorted(benches)}")
    reset_plan_cache_stats()
    common.reset_rows()
    print("name,us_per_call,derived")

    cost_model = None
    if args.calibrate:
        import os
        import tempfile

        from repro.dispatch import AutotuneCache, calibrate

        print("# --- calibrate ---", file=sys.stderr)
        cache = AutotuneCache()
        calibrate(n=256 if quick else 1024, d=64,
                  densities=(0.5, 0.05, 0.005), cache=cache)
        # the calibration must survive the cache's JSON round-trip —
        # that is how a serving host would pick it up next process
        fd, path = tempfile.mkstemp(suffix=".json")
        os.close(fd)
        try:
            cache.save(path)
            reloaded = AutotuneCache()
            reloaded.load(path)
            cost_model = reloaded.cost_model
        finally:
            os.remove(path)
        common.emit("calibrate_constants", 0.0,
                    f"c_ell={cost_model.c_ell:.3g};"
                    f"c_sell={cost_model.c_sell:.3g};"
                    f"c_csr={cost_model.c_csr:.3g}")

    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        if name in api_axis:
            fn(quick=quick, policy=args.policy, api=args.api,
               cost_model=cost_model)
        elif name in dispatched:
            fn(quick=quick, policy=args.policy)
        else:
            fn(quick=quick)
    if args.obs_snapshot:
        from repro import obs

        with open(args.obs_snapshot, "w") as f:
            json.dump(obs.snapshot(), f, indent=2)
            f.write("\n")
        print(f"# wrote obs snapshot to {args.obs_snapshot}",
              file=sys.stderr)
    pc = plan_cache_stats()
    emitted = pc["hits"] + pc["misses"]
    rate = pc["hits"] / emitted if emitted else 0.0
    print(f"plan_cache,{pc['hits']},misses={pc['misses']};"
          f"hit_rate={rate:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "rows": common.ROWS,
                "plan_cache": {**pc, "hit_rate": round(rate, 3)},
            }, f, indent=2)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}",
              file=sys.stderr)
        ran = set(benches) if only is None else only
        if {"spmm", "sddmm"} <= ran:
            kernel_rows = [r for r in common.ROWS
                           if r["name"].startswith(("spmm_", "sddmm_"))]
            with open(KERNELS_BASELINE, "w") as f:
                json.dump({
                    "quick": quick,
                    "policy": args.policy,
                    "api": args.api,
                    "rows": kernel_rows,
                }, f, indent=2)
                f.write("\n")
            print(f"# wrote {len(kernel_rows)} kernel rows to "
                  f"{KERNELS_BASELINE}", file=sys.stderr)


if __name__ == "__main__":
    main()
