"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_dense_limit  — Fig. 2 (dense-format wall)
  bench_footprint    — Fig. 8 (SELLPACK-like vs CSR footprint)
  bench_spmm         — Fig. 9 (SpMM vs density/N, d=256)
  bench_sddmm        — Fig. 10 (SDDMM vs density, d=2, mnz sensitivity)
  bench_crossover    — Fig. 9's crossover as a dispatch-path sweep

``python -m benchmarks.run [--full] [--policy auto]`` (quick mode by
default so the CPU container finishes in minutes; --full matches the
paper's largest sizes; --policy sets the dispatch policy for the
benches that route through the dispatch layer).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "csr", "dense"])
    ap.add_argument("--api", default="sparse", choices=["legacy", "sparse"],
                    help="dispatch surface for the spmm/sddmm benches")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (bench_crossover, bench_dense_limit,
                            bench_footprint, bench_sddmm, bench_spmm)
    from repro.sparse import plan_cache_stats, reset_plan_cache_stats
    benches = {
        "dense_limit": bench_dense_limit.run,
        "footprint": bench_footprint.run,
        "spmm": bench_spmm.run,
        "sddmm": bench_sddmm.run,
        "crossover": bench_crossover.run,
    }
    dispatched = {"spmm", "sddmm", "crossover"}
    api_axis = {"spmm", "sddmm"}
    only = set(args.only.split(",")) if args.only else None
    reset_plan_cache_stats()
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        if name in api_axis:
            fn(quick=quick, policy=args.policy, api=args.api)
        elif name in dispatched:
            fn(quick=quick, policy=args.policy)
        else:
            fn(quick=quick)
    pc = plan_cache_stats()
    emitted = pc["hits"] + pc["misses"]
    rate = pc["hits"] / emitted if emitted else 0.0
    print(f"plan_cache,{pc['hits']},misses={pc['misses']};"
          f"hit_rate={rate:.3f}")


if __name__ == "__main__":
    main()
