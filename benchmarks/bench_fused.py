"""Fused-vs-unfused sparse-pipeline sweep (GCN epilogue + GAT attention).

Two layer-level comparisons, each jitted end to end:

  * **GCN layer** — ``relu(A @ (H W) + b)`` as (a) the unfused
    composition (planned SpMM, then a separate bias+relu pass) vs (b)
    the fused epilogue (``matmul(..., epilogue="relu", bias=b)``).
  * **GAT layer** — SDDMM → leaky_relu → segment softmax → SpMM as (a)
    three planned dispatches vs (b) one ``fused_graph_attention``.

Wall-clock on a noisy CPU container under-reports the fusion win (XLA
already fuses elementwise tails into neighboring ops), so each row also
carries the *deterministic* fusion metric: how many E-length (edge-
count-sized) intermediates the traced program materializes.  The fused
GAT pipeline must show **zero** — the E-length score vector exists only
as VMEM-resident tile statistics — while the unfused composition
carries several.  That streamed-intermediate reduction is the
TPU-relevant quantity (every such array is an HBM round-trip on the
real target).

Writes ``BENCH_fused.json`` (the committed fused-pipeline baseline).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

JSON_PATH = "BENCH_fused.json"


def count_length_intermediates(closed_jaxpr, length: int) -> int:
    """Count 1-D arrays of exactly ``length`` produced inside a jaxpr.

    Recurses into sub-jaxprs (pjit/custom_vjp bodies), so the count
    covers the whole traced program — the static analog of counting
    E-length HBM round-trips.
    """

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if aval is not None and getattr(aval, "shape", None) \
                        == (length,):
                    n += 1
            for v in eqn.params.values():
                sub = getattr(v, "jaxpr", None)
                if sub is not None:
                    n += walk(sub)
        return n

    return walk(closed_jaxpr.jaxpr)


def _graph(n: int, density: float, seed: int):
    from repro.configs.paper_gnn import GNNConfig
    from repro.models.gnn import build_graph

    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    cfg = GNNConfig(name="fused-bench", in_features=64, hidden=64,
                    n_classes=8, n_layers=2, block_m=16, block_n=16)
    return build_graph(adj, cfg), cfg


def run(quick: bool = True, policy: str = "auto",
        json_path: Optional[str] = JSON_PATH) -> Dict:
    from repro.models.gnn import _segment_softmax, graph_spmm
    from repro.sparse import fused_graph_attention, matmul, sample

    ns = [512] if quick else [1024, 2048]
    densities = [0.1, 0.01] if quick else [0.1, 0.01, 0.001]
    d = 64
    rows: List[Dict] = []
    rng = np.random.default_rng(7)
    for n in ns:
        for density in densities:
            graph, cfg = _graph(n, density, seed=int(n + 1 / density))
            adj = graph.adj
            nnz = adj.stats.nnz
            h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
            b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))

            # -- GCN layer: relu(A @ H + b) -----------------------------
            def gcn_unfused(h):
                return jax.nn.relu(graph_spmm(graph, h, policy=policy) + b)

            def gcn_fused(h):
                return graph_spmm(graph, h, policy=policy,
                                  epilogue="relu", bias=b)

            ju, jf = jax.jit(gcn_unfused), jax.jit(gcn_fused)
            np.testing.assert_allclose(np.asarray(ju(h)),
                                       np.asarray(jf(h)),
                                       rtol=1e-4, atol=1e-4)
            t_u = time_fn(ju, h, warmup=2, iters=10)
            t_f = time_fn(jf, h, warmup=2, iters=10)
            tag = f"fused_gcn_n{n}_d{density:g}"
            derived = (f"speedup_vs_unfused={t_u / t_f:.2f};"
                       f"unfused_us={t_u:.1f}")
            emit(tag, t_f, derived)
            rows.append({"name": tag, "us_per_call": round(t_f, 1),
                         "unfused_us": round(t_u, 1),
                         "speedup": round(t_u / t_f, 3)})

            # -- GAT layer: one-pass attention --------------------------
            s_src = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            s_dst = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
            patt = adj.to("csr").pattern()

            def gat_unfused(s_src, s_dst, h):
                q = jnp.stack([s_src, jnp.ones_like(s_src)], axis=1)
                c = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=0)
                e = sample(patt, q, c, policy="csr").data
                e = jax.nn.leaky_relu(e, 0.2)
                alpha = _segment_softmax(e, patt.form("csr")[0], n)
                return matmul(patt.with_data(alpha), h, policy="csr")

            def gat_fused(s_src, s_dst, h):
                q = jnp.stack([s_src, jnp.ones_like(s_src)], axis=1)
                k = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=1)
                return fused_graph_attention(adj, q, k, h, policy=policy)

            def gat_fused_blocked(s_src, s_dst, h):
                # the streaming (kernel-target) layout: the E-length
                # metric is pinned on this path — csr is E-granular by
                # construction and stays the reference
                q = jnp.stack([s_src, jnp.ones_like(s_src)], axis=1)
                k = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=1)
                return fused_graph_attention(adj, q, k, h, policy="ell")

            ju, jf = jax.jit(gat_unfused), jax.jit(gat_fused)
            np.testing.assert_allclose(
                np.asarray(ju(s_src, s_dst, h)),
                np.asarray(jf(s_src, s_dst, h)), rtol=1e-4, atol=1e-4)
            e_unfused = count_length_intermediates(
                jax.make_jaxpr(gat_unfused)(s_src, s_dst, h), nnz)
            e_fused = count_length_intermediates(
                jax.make_jaxpr(gat_fused_blocked)(s_src, s_dst, h), nnz)
            t_u = time_fn(ju, s_src, s_dst, h, warmup=2, iters=10)
            t_f = time_fn(jf, s_src, s_dst, h, warmup=2, iters=10)
            tag = f"fused_gat_n{n}_d{density:g}"
            derived = (f"speedup_vs_unfused={t_u / t_f:.2f};"
                       f"e_intermediates={e_fused}"
                       f"(unfused={e_unfused});nnz={nnz}")
            emit(tag, t_f, derived)
            rows.append({"name": tag, "us_per_call": round(t_f, 1),
                         "unfused_us": round(t_u, 1),
                         "speedup": round(t_u / t_f, 3),
                         "e_intermediates_fused": e_fused,
                         "e_intermediates_unfused": e_unfused,
                         "nnz": int(nnz)})

    results = {"quick": quick, "policy": policy, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "autotune", "ell", "sell", "csr",
                             "dense"])
    ap.add_argument("--json", default=JSON_PATH)
    args = ap.parse_args()
    run(quick=args.quick, policy=args.policy, json_path=args.json)
