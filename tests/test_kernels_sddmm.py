"""Pallas Block-COO SDDMM kernel vs pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.formats import BlockCOO
from repro.core.sddmm import sddmm_coo
from repro.kernels.sddmm.ops import sddmm_blockcoo
from repro.kernels.sddmm.ref import sddmm_blockcoo_ref


@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (256, 256, 256, 64, 128, 128),
    (128, 256, 512, 64, 64, 256),
    (64, 128, 128, 64, 128, 128),
])
@pytest.mark.parametrize("density", [0.05, 0.5])
def test_sddmm_kernel_matches_ref(rng, m, n, k, bm, bn, bk, density):
    maskd = (rng.random((m, n)) < density).astype(np.float32)
    coo = BlockCOO.from_dense(maskd, bm, bn)
    b = rng.normal(size=(m, k)).astype(np.float32)
    c = rng.normal(size=(k, n)).astype(np.float32)
    ref = sddmm_blockcoo_ref(coo, jnp.asarray(b), jnp.asarray(c))
    out = sddmm_blockcoo(coo, jnp.asarray(b), jnp.asarray(c), bk=bk,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out.blocks),
                               np.asarray(ref.blocks), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ref.to_dense(), maskd * (b @ c),
                               rtol=3e-4, atol=3e-4)


def test_sddmm_weighted_mask(rng):
    """A carries values (not just 0/1): Y = A ⊙ (B C)."""
    m = n = k = 128
    a = np.where(rng.random((m, n)) < 0.2, rng.normal(size=(m, n)), 0.0) \
        .astype(np.float32)
    coo = BlockCOO.from_dense(a, 64, 64)
    b = rng.normal(size=(m, k)).astype(np.float32)
    c = rng.normal(size=(k, n)).astype(np.float32)
    out = sddmm_blockcoo(coo, jnp.asarray(b), jnp.asarray(c), interpret=True)
    np.testing.assert_allclose(out.to_dense(), a * (b @ c),
                               rtol=3e-4, atol=3e-4)


def test_sddmm_padded_blocks(rng):
    maskd = (rng.random((128, 128)) < 0.1).astype(np.float32)
    coo = BlockCOO.from_dense(maskd, 64, 64, pad_to=8)
    b = rng.normal(size=(128, 128)).astype(np.float32)
    c = rng.normal(size=(128, 128)).astype(np.float32)
    out = sddmm_blockcoo(coo, jnp.asarray(b), jnp.asarray(c), interpret=True)
    np.testing.assert_allclose(out.to_dense(), maskd * (b @ c),
                               rtol=3e-4, atol=3e-4)


def test_sddmm_coo_elementwise_small_k(rng):
    """The paper's GAT case: K=2."""
    m = n = 64
    mask = rng.random((m, n)) < 0.2
    rows, cols = np.nonzero(mask)
    b = rng.normal(size=(m, 2)).astype(np.float32)
    c = rng.normal(size=(2, n)).astype(np.float32)
    vals = sddmm_coo(jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(b), jnp.asarray(c))
    expected = (b @ c)[rows, cols]
    np.testing.assert_allclose(np.asarray(vals), expected, rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(nr=st.integers(1, 3), nc=st.integers(1, 3),
       density=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1))
def test_sddmm_property(nr, nc, density, seed):
    rng = np.random.default_rng(seed)
    m, n, k = nr * 64, nc * 128, 128
    maskd = (rng.random((m, n)) < density).astype(np.float32)
    coo = BlockCOO.from_dense(maskd, 64, 128)
    b = rng.normal(size=(m, k)).astype(np.float32)
    c = rng.normal(size=(k, n)).astype(np.float32)
    out = sddmm_blockcoo(coo, jnp.asarray(b), jnp.asarray(c), interpret=True)
    np.testing.assert_allclose(out.to_dense(), maskd * (b @ c),
                               rtol=5e-4, atol=5e-4)
