"""Sparsity-adaptive dispatch layer: path agreement + the paper's crossover.

The sweep asserts two things the paper measures:
  (a) every execution path computes the same product (dense oracle,
      Pallas kernel validated in interpret mode), and
  (b) the cost model reproduces the crossover — the Block-ELL streaming
      path at 90% sparsity, the element-level CSR path at >=99%.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import BlockCOO, BlockELL
from repro.core.sddmm import sddmm
from repro.core.spmm import spmm
from repro.dispatch import (AutotuneCache, CostModel, MatrixStats,
                            SparseOperand, last_plan, normalize_policy,
                            plan_sddmm, plan_spmm, sparsity_bucket)
from repro.dispatch.autotune import make_key
from repro.dispatch.dispatcher import dispatch_sddmm, dispatch_spmm

SWEEP = [0.5, 0.9, 0.99, 0.999]
N, D = 512, 64
BLOCK = 4  # small blocks keep block-granularity honest at uniform sparsity


def _uniform_sparse(rng, n, sparsity):
    mask = rng.random((n, n)) < (1.0 - sparsity)
    return np.where(mask, rng.normal(size=(n, n)), 0.0).astype(np.float32)


@pytest.fixture(scope="module")
def sweep_operands():
    rng = np.random.default_rng(42)
    out = {}
    for s in SWEEP:
        dense = _uniform_sparse(rng, N, s)
        out[s] = (dense, SparseOperand.from_dense(
            dense, block_m=BLOCK, block_n=BLOCK))
    return out


# ---------------------------------------------------------------------------
# (a) all paths agree with the dense oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("path", ["ell", "csr", "dense"])
def test_spmm_paths_match_dense_oracle(sweep_operands, path, sparsity):
    dense, op = sweep_operands[sparsity]
    rng = np.random.default_rng(7)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    y = spmm(op, h, policy=path)
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    assert last_plan("spmm").path == path


@pytest.mark.parametrize("sparsity", [0.9, 0.999])
def test_spmm_kernel_path_interpret_matches_oracle(sparsity):
    """The Pallas kernel route through the dispatcher (interpret mode)."""
    rng = np.random.default_rng(3)
    dense = _uniform_sparse(rng, 256, sparsity)
    ell = BlockELL.from_dense(dense, 64, 128)
    h = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    y = spmm(ell, h, policy="ell", use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=5e-4, atol=5e-4)
    plan = last_plan("spmm")
    assert plan.path == "ell" and plan.interpret


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("path", ["ell", "csr", "dense"])
def test_sddmm_paths_match_dense_oracle(path, sparsity):
    rng = np.random.default_rng(11)
    n, k = 256, 2
    mask = (rng.random((n, n)) < (1.0 - sparsity)).astype(np.float32)
    coo = BlockCOO.from_dense(mask, 16, 16)
    b = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    out = sddmm(coo, b, c, policy=path)
    oracle = mask * (np.asarray(b) @ np.asarray(c))
    np.testing.assert_allclose(out.to_dense()[:n, :n], oracle,
                               rtol=2e-4, atol=2e-4)
    assert last_plan("sddmm").path == path


def test_sddmm_kernel_path_interpret_matches_oracle():
    rng = np.random.default_rng(5)
    n, k = 256, 128
    mask = (rng.random((n, n)) < 0.1).astype(np.float32)
    coo = BlockCOO.from_dense(mask, 64, 64)
    b = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    out = sddmm(coo, b, c, policy="ell", use_kernel=True, interpret=True)
    oracle = mask * (np.asarray(b) @ np.asarray(c))
    np.testing.assert_allclose(out.to_dense()[:n, :n], oracle,
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# (b) the crossover: ELL at 90% sparsity, CSR at >=99% (legacy paths),
#     SELL taking over the hyper-sparse side when its form is carried
# ---------------------------------------------------------------------------


# among the legacy-executable paths (no sell packing carried)
EXPECTED_PATH = {0.5: "dense", 0.9: "ell", 0.99: "csr", 0.999: "csr"}
# with every path priceable, SELL-C-σ owns the hyper-sparse side
EXPECTED_PATH_FULL = {0.5: "dense", 0.9: "ell", 0.99: "sell",
                      0.999: "sell"}


@pytest.mark.parametrize("sparsity", SWEEP)
def test_cost_model_reproduces_paper_crossover(sweep_operands, sparsity):
    """The paper's crossover among the three original paths is intact;
    unrestricted, the sell path replaces csr past the padding cliff."""
    _, op = sweep_operands[sparsity]
    legacy = plan_spmm(op.stats(), D, policy="auto",
                       candidates=("ell", "csr", "dense"))
    assert legacy.path == EXPECTED_PATH[sparsity], legacy.describe()
    full = plan_spmm(op.stats(), D, policy="auto")
    assert full.path == EXPECTED_PATH_FULL[sparsity], full.describe()


@pytest.mark.parametrize("sparsity", SWEEP)
def test_spmm_auto_dispatch_switches_paths(sweep_operands, sparsity):
    """spmm(..., policy="auto") routes ELL at 90%, CSR at >=99%."""
    dense, op = sweep_operands[sparsity]
    rng = np.random.default_rng(9)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    y = spmm(op, h, policy="auto")
    plan = last_plan("spmm")
    assert plan.path == EXPECTED_PATH[sparsity], plan.describe()
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_sddmm_auto_dispatch_crossover():
    rng = np.random.default_rng(13)
    n, k = 256, 2
    b = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    for sparsity, expected in ((0.9, "ell"), (0.999, "csr")):
        mask = (rng.random((n, n)) < (1.0 - sparsity)).astype(np.float32)
        coo = BlockCOO.from_dense(mask, 4, 4)
        sddmm(coo, b, c, policy="auto")
        plan = last_plan("sddmm")
        assert plan.path == expected, plan.describe()


def test_padded_stream_blowup_drives_the_crossover(sweep_operands):
    """The mechanism, not just the outcome: the blow-up is monotone in
    sparsity and crosses c_csr/c_ell between 0.9 and 0.99."""
    cm = CostModel()
    blowups = [sweep_operands[s][1].stats().padded_stream_blowup
               for s in SWEEP]
    assert blowups == sorted(blowups)
    ratio = cm.c_csr / cm.c_ell
    assert blowups[SWEEP.index(0.9)] < ratio < blowups[SWEEP.index(0.99)]


# ---------------------------------------------------------------------------
# the sell path at extreme sparsity (the tentpole crossover)
# ---------------------------------------------------------------------------


def _sell_capable(dense):
    from repro.sparse import SparseMatrix

    return SparseMatrix.from_dense(dense, formats=("ell", "csr", "sell"),
                                   block=(BLOCK, BLOCK))


@pytest.mark.parametrize("sparsity,expected", [
    (0.9, "ell"),       # moderate sparsity: blocked streaming still wins
    (0.995, "sell"),    # past the padding cliff: sell takes over
    (0.999, "sell"),
])
def test_auto_routes_sell_past_the_cliff(sparsity, expected):
    """policy=auto picks sell at >=99.5% sparsity, ell at 90%."""
    from repro.dispatch.dispatcher import clear_log, dispatch_log
    from repro.sparse import matmul

    rng = np.random.default_rng(51)
    dense = _uniform_sparse(rng, N, sparsity)
    op = _sell_capable(dense)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    clear_log()
    y = matmul(op, h, policy="auto")
    plan = last_plan("spmm")
    assert plan.path == expected, plan.describe()
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    # the dispatch log records the decision AND the predicted costs
    logged = [p for p in dispatch_log() if p.op == "spmm"]
    assert logged and logged[-1].path == expected
    assert logged[-1].costs is not None
    assert set(logged[-1].costs) == {"ell", "sell", "csr", "dense"}
    assert logged[-1].costs[expected] == min(logged[-1].costs.values())
    assert "cost model" in logged[-1].reason


@pytest.mark.parametrize("sparsity", [0.9, 0.995])
def test_sell_dispatch_log_records_predicted_cost_sddmm(sparsity):
    from repro.sparse import SparseMatrix, sddmm

    rng = np.random.default_rng(53)
    mask = (rng.random((N, N)) < (1.0 - sparsity)).astype(np.float32)
    op = SparseMatrix.from_dense(mask, formats=("coo", "csr", "sell"),
                                 block=(BLOCK, BLOCK))
    b = jnp.asarray(rng.normal(size=(N, 2)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(2, N)).astype(np.float32))
    sddmm(op, b, c, policy="auto")
    plan = last_plan("sddmm")
    assert plan.costs is not None and "sell" in plan.costs
    if sparsity >= 0.995:
        assert plan.path == "sell", plan.describe()


def test_sell_not_a_candidate_without_the_form():
    """A matrix that never packed sell cannot be routed to it."""
    from repro.sparse import SparseMatrix, matmul

    rng = np.random.default_rng(57)
    dense = _uniform_sparse(rng, 128, 0.999)
    op = SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                 block=(BLOCK, BLOCK))
    h = jnp.asarray(rng.normal(size=(128, 8)).astype(np.float32))
    with pytest.raises(ValueError, match="not among available paths"):
        matmul(op, h, policy="sell")
    matmul(op, h, policy="auto")
    assert last_plan("spmm").path in ("ell", "csr", "dense")


def test_with_form_makes_sell_routable():
    """Lazy conversion: adding the sell form turns the path on."""
    from repro.sparse import SparseMatrix, matmul

    rng = np.random.default_rng(59)
    dense = _uniform_sparse(rng, 256, 0.995)
    op = SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                 block=(BLOCK, BLOCK))
    both = op.with_form("sell")
    assert both.formats == ("ell", "csr", "sell")
    assert op.with_form("ell") is op  # no-op when already carried
    h = jnp.asarray(rng.normal(size=(256, D)).astype(np.float32))
    matmul(both, h, policy="auto")
    assert last_plan("spmm").path == "sell"


# ---------------------------------------------------------------------------
# policy plumbing
# ---------------------------------------------------------------------------


def test_policy_normalization_and_errors():
    assert normalize_policy("BLOCK") == "ell"
    assert normalize_policy("coo") == "csr"
    assert normalize_policy("auto") == "auto"
    with pytest.raises(ValueError):
        normalize_policy("fastest")


def test_forced_policy_outside_candidates_raises(sweep_operands):
    _, op = sweep_operands[0.9]
    with pytest.raises(ValueError):
        plan_spmm(op.stats(), D, policy="dense", candidates=("ell", "csr"))


def test_explicit_kernel_args_force_ell_path(sweep_operands):
    """Legacy spmm(ell, h, use_kernel=False) semantics survive dispatch."""
    dense, op = sweep_operands[0.999]  # auto would pick csr here
    rng = np.random.default_rng(17)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    spmm(op, h, use_kernel=False)
    assert last_plan("spmm").path == "ell"


def test_dispatch_spmm_accepts_blockell_and_dense():
    rng = np.random.default_rng(19)
    dense = _uniform_sparse(rng, 128, 0.9)
    h = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    y1 = dispatch_spmm(BlockELL.from_dense(dense, 16, 16), h, policy="ell")
    y2 = dispatch_spmm(dense, h, policy="csr")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("path", ["ell", "csr", "dense", "auto"])
def test_spmm_mismatched_h_rows_raises(path):
    """H with the wrong row count must raise, not silently pad/truncate."""
    with pytest.raises(ValueError, match="60 rows but A has 64"):
        spmm(np.eye(64, dtype=np.float32), jnp.ones((60, 4)), policy=path)


def test_spmm_non_divisible_shapes_trim_correctly():
    """Dense operand whose shape is not a block multiple: ell path pads
    internally and the output is trimmed back to the logical shape."""
    rng = np.random.default_rng(23)
    m, n, d = 100, 70, 16
    mask = rng.random((m, n)) < 0.1
    dense = np.where(mask, rng.normal(size=(m, n)), 0.0).astype(np.float32)
    op = SparseOperand.from_dense(dense, block_m=16, block_n=16)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    for path in ("ell", "csr", "dense"):
        y = spmm(op, h, policy=path)
        assert y.shape == (m, d)
        np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("path", ["ell", "csr", "dense", "auto"])
def test_sddmm_non_divisible_shapes_all_paths(path):
    """A 100x100 mask block-pads to 128x128; B/C are padded to match."""
    rng = np.random.default_rng(31)
    mask = (rng.random((100, 100)) < 0.5).astype(np.float32)
    b = rng.normal(size=(100, 2)).astype(np.float32)
    c = rng.normal(size=(2, 100)).astype(np.float32)
    out = sddmm(mask, jnp.asarray(b), jnp.asarray(c), policy=path)
    np.testing.assert_allclose(out.to_dense()[:100, :100],
                               mask * (b @ c), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("path", ["ell", "csr", "dense", "auto"])
def test_spmm_1d_h_all_paths(path):
    rng = np.random.default_rng(37)
    dense = np.where(rng.random((64, 64)) < 0.1, 1.0, 0.0) \
        .astype(np.float32)
    hv = rng.normal(size=64).astype(np.float32)
    op = SparseOperand.from_dense(dense, block_m=4, block_n=4)
    y = spmm(op, jnp.asarray(hv), policy=path)
    assert y.shape == (64,)
    np.testing.assert_allclose(np.asarray(y), dense @ hv,
                               rtol=2e-4, atol=2e-4)


def test_pure_plan_never_claims_autotune(sweep_operands):
    """plan_* cannot time candidates, so the plan must not say it did."""
    _, op = sweep_operands[0.9]
    plan = plan_spmm(op.stats(), D, policy="autotune")
    assert plan.policy == "auto" and plan.timings_us is None


def test_traced_operand_forced_host_policy_raises():
    """Under jit a forced csr/dense policy must raise, not silently run
    the blocked path."""
    rng = np.random.default_rng(41)
    dense = _uniform_sparse(rng, 64, 0.9)
    ell = BlockELL.from_dense(dense, 16, 16)
    h = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))

    ok = jax.jit(lambda e, hh: spmm(e, hh, policy="auto"))(ell, h)
    np.testing.assert_allclose(np.asarray(ok), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    with pytest.raises(TypeError, match="traced"):
        jax.jit(lambda e, hh: spmm(e, hh, policy="csr"))(ell, h)


def test_graph_without_stats_raises_clearly():
    from repro.models.gnn import Graph, graph_spmm
    from repro.sparse import SparseMatrix

    rng = np.random.default_rng(43)
    dense = _uniform_sparse(rng, 32, 0.9)
    ell = BlockELL.from_dense(dense, 16, 16)
    # stats-less adjacency (e.g. wrapped from traced arrays): policy
    # routing must fail loudly, not silently pick a path
    adj = SparseMatrix({"ell": ell}, ell.shape, None)
    g = Graph(adj=adj, n_nodes=32)
    with pytest.raises(ValueError, match="build_graph"):
        graph_spmm(g, jnp.ones((32, 4)))


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------


def test_autotune_caches_per_sparsity_bucket(sweep_operands, tmp_path):
    dense, op = sweep_operands[0.99]
    rng = np.random.default_rng(29)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    cache = AutotuneCache()
    y = dispatch_spmm(op, h, policy="autotune", cache=cache)
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    assert len(cache) == 1
    first = last_plan("spmm")
    assert first.timings_us and len(first.timings_us) == 3

    # second dispatch in the same bucket: cache hit, no re-measurement
    misses = cache.misses
    dispatch_spmm(op, h, policy="autotune", cache=cache)
    assert cache.misses == misses
    assert "cached" in last_plan("spmm").reason

    # persistence round-trip
    p = tmp_path / "autotune.json"
    cache.save(str(p))
    cache2 = AutotuneCache()
    cache2.load(str(p))
    assert len(cache2) == 1
    key = make_key("spmm", op.stats().shape, D, h.dtype,
                   op.stats().density)
    assert cache2.get(key).path == first.path


def test_sparsity_bucket_groups_decades():
    assert sparsity_bucket(0.5) == sparsity_bucket(0.4)
    assert sparsity_bucket(0.1) != sparsity_bucket(0.001)
    # density 0 lands in the hyper-sparse cap bucket
    assert sparsity_bucket(0.0) == sparsity_bucket(1e-12)
    b1, b2 = sparsity_bucket(0.01), sparsity_bucket(0.009)
    assert b1 == b2  # same half-decade


# ---------------------------------------------------------------------------
# consumers: GNN + serving engine
# ---------------------------------------------------------------------------


def test_gcn_policy_paths_agree():
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gcn_forward, init_gcn

    rng = np.random.default_rng(0)
    adj = random_graph(48, avg_degree=4, seed=1, clustered=False)
    g = build_graph(adj, GCFG)
    assert isinstance(g.stats, MatrixStats) and g.stats.nnz > 0
    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(48, GCFG.in_features))
                    .astype(np.float32))
    outs = {p: np.asarray(gcn_forward(params, g, x, policy=p))
            for p in ("auto", "ell", "csr")}
    np.testing.assert_allclose(outs["ell"], outs["csr"],
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs["auto"], outs["ell"],
                               rtol=2e-4, atol=2e-4)
    # plans are static metadata: the forward works under jit
    f = jax.jit(lambda p, gg, xx: gcn_forward(p, gg, xx, policy="auto"))
    np.testing.assert_allclose(np.asarray(f(params, g, x)), outs["auto"],
                               rtol=2e-4, atol=2e-4)


def test_gnn_serving_engine_dispatch_report():
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gcn_forward, init_gcn
    from repro.serve.engine import GNNServeConfig, GNNServingEngine

    rng = np.random.default_rng(1)
    adj = random_graph(48, avg_degree=4, seed=2, clustered=False)
    g = build_graph(adj, GCFG)
    params = init_gcn(jax.random.PRNGKey(1), GCFG)
    x = rng.normal(size=(48, GCFG.in_features)).astype(np.float32)

    eng = GNNServingEngine(params, g)
    logits = eng.infer(x)
    assert logits.shape == (48, GCFG.n_classes)
    report = eng.dispatch_report()
    assert report["path"] in ("ell", "csr")
    assert report["n_requests"] == 1
    np.testing.assert_allclose(
        logits, np.asarray(gcn_forward(params, g, jnp.asarray(x),
                                       policy=report["path"])),
        rtol=2e-4, atol=2e-4)

    # forcing the other path still serves correct logits
    other = "csr" if report["path"] == "ell" else "ell"
    eng2 = GNNServingEngine(params, g, GNNServeConfig(policy=other))
    np.testing.assert_allclose(eng2.infer(x), logits, rtol=2e-4, atol=2e-4)
    assert eng2.dispatch_report()["path"] == other
