"""GCN/GAT on the sparse substrate — the paper's application layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
from repro.data.pipeline import random_graph
from repro.models.gnn import (build_graph, gat_forward, gcn_forward,
                              init_gat, init_gcn)


@pytest.fixture
def graph(rng):
    adj = random_graph(48, avg_degree=4, seed=1, clustered=False)
    return build_graph(adj, GCFG)


def test_gcn_blockell_equals_csr_path(rng, graph):
    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))
    out_ell = gcn_forward(params, graph, x, use_blockell=True)
    out_csr = gcn_forward(params, graph, x, use_blockell=False)
    np.testing.assert_allclose(np.asarray(out_ell), np.asarray(out_csr),
                               rtol=2e-4, atol=2e-4)
    assert out_ell.shape == (graph.n_nodes, GCFG.n_classes)


def test_gcn_matches_dense_aggregation(rng, graph):
    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))
    a_hat = graph.ell.to_dense()[:graph.n_nodes, :graph.n_nodes]
    h = np.asarray(x)
    for i, w in enumerate(params["w"]):
        h = a_hat @ (h @ np.asarray(w))
        if i < len(params["w"]) - 1:
            h = np.maximum(h, 0)
    out = gcn_forward(params, graph, x)
    np.testing.assert_allclose(np.asarray(out), h, rtol=2e-3, atol=2e-3)


def test_gat_rows_softmax_normalized(rng, graph):
    """Attention weights over each node's edges sum to 1 (post-softmax)."""
    params = init_gat(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))
    out = gat_forward(params, graph, x)
    assert out.shape == (graph.n_nodes, GCFG.n_classes)
    assert np.isfinite(np.asarray(out)).all()


def test_gnn_training_loss_decreases(rng, graph):
    """End-to-end: 30 steps of full-batch GCN training, planted signal."""
    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    labels_np = (np.arange(graph.n_nodes) * GCFG.n_classes
                 // graph.n_nodes).astype(np.int32)
    feats = rng.normal(size=(graph.n_nodes, GCFG.in_features)) \
        .astype(np.float32)
    feats[:, : GCFG.n_classes] += 3.0 * np.eye(GCFG.n_classes)[labels_np]
    x = jnp.asarray(feats)
    labels = jnp.asarray(labels_np)

    def loss_fn(params):
        logits = gcn_forward(params, graph, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, labels[:, None], 1).mean()

    @jax.jit
    def step(params):
        l, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.3 * gg,
                                        params, g)
        return params, l

    losses = []
    for _ in range(60):
        params, l = step(params)
        losses.append(float(l))
    # full-batch GCN on a random graph learns slowly (neighbor averaging
    # dilutes the planted signal); monotone-ish descent is the invariant
    assert losses[-1] < losses[0] - 0.04, (losses[0], losses[-1])
