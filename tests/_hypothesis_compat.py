"""Import shim for ``hypothesis``: property tests skip cleanly without it.

Test modules import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly.  When hypothesis is installed the real
objects are re-exported unchanged; when it is absent, stand-ins are
provided so that

  * module import (and therefore pytest collection) succeeds,
  * strategy construction at module scope (``st.integers(...)``,
    ``@st.composite``, …) is a no-op,
  * every ``@given``-decorated test reports SKIPPED (not ERROR), and
  * plain pytest tests in the same module still run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert strategy stub: any call/attribute yields another stub."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __repr__(self):  # pragma: no cover - debugging aid
            return "<hypothesis strategy stub>"

    class _Strategies:
        """Stub of the ``hypothesis.strategies`` module."""

        @staticmethod
        def composite(fn):
            return lambda *a, **k: _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def settings(*a, **k):
        """Decorator factory: pass the (already wrapped) test through."""
        if a and callable(a[0]) and not k:  # bare @settings
            return a[0]
        return lambda fn: fn

    def given(*a, **k):
        """Replace the property test with a zero-arg skipper.

        The replacement takes no parameters on purpose: keeping the
        original signature would make pytest resolve the hypothesis-
        drawn arguments as (missing) fixtures and error instead of skip.
        """

        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return deco
