"""Property test: checkpoint round-trips arbitrary nested pytrees."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.ft.checkpoint import Checkpointer

_dtypes = st.sampled_from([np.float32, np.int32, np.float16, np.bool_])


@st.composite
def leaf(draw):
    shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
    dt = draw(_dtypes)
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dt == np.bool_:
        return jnp.asarray(rng.random(shape) < 0.5)
    return jnp.asarray(rng.normal(size=shape).astype(dt)
                       if np.issubdtype(dt, np.floating)
                       else rng.integers(-5, 5, shape).astype(dt))


@st.composite
def tree(draw, depth=2):
    if depth == 0:
        return draw(leaf())
    keys = draw(st.lists(
        st.text(alphabet="abcdefg_", min_size=1, max_size=6),
        min_size=1, max_size=3, unique=True))
    return {k: draw(tree(depth=depth - 1)) for k in keys}


@settings(max_examples=15, deadline=None)
@given(t=tree())
def test_checkpoint_roundtrip_arbitrary_tree(tmp_path_factory, t):
    d = tmp_path_factory.mktemp("ck")
    ck = Checkpointer(str(d), async_save=False)
    ck.save(1, t)
    out = ck.restore(t)
    flat_a = jnp.broadcast_shapes  # noqa: F841 (quiet linters)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
