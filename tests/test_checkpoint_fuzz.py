"""Property tests: checkpoint round-trips arbitrary nested pytrees, and
restore survives arbitrarily corrupted step directories (crash-mid-write
fuzzing) by falling back to the newest intact step."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ft.checkpoint import Checkpointer
from repro.resilience import FaultPlan, FaultSpec, chaos

_dtypes = st.sampled_from([np.float32, np.int32, np.float16, np.bool_])


@st.composite
def leaf(draw):
    shape = tuple(draw(st.lists(st.integers(1, 5), min_size=0, max_size=3)))
    dt = draw(_dtypes)
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dt == np.bool_:
        return jnp.asarray(rng.random(shape) < 0.5)
    return jnp.asarray(rng.normal(size=shape).astype(dt)
                       if np.issubdtype(dt, np.floating)
                       else rng.integers(-5, 5, shape).astype(dt))


@st.composite
def tree(draw, depth=2):
    if depth == 0:
        return draw(leaf())
    keys = draw(st.lists(
        st.text(alphabet="abcdefg_", min_size=1, max_size=6),
        min_size=1, max_size=3, unique=True))
    return {k: draw(tree(depth=depth - 1)) for k in keys}


@settings(max_examples=15, deadline=None)
@given(t=tree())
def test_checkpoint_roundtrip_arbitrary_tree(tmp_path_factory, t):
    d = tmp_path_factory.mktemp("ck")
    ck = Checkpointer(str(d), async_save=False)
    ck.save(1, t)
    out = ck.restore(t)
    flat_a = jnp.broadcast_shapes  # noqa: F841 (quiet linters)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# crash-mid-write + corrupt-directory fuzzing (resilience satellite)
# ---------------------------------------------------------------------------

_TREE = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
         "nested": {"b": jnp.ones((5,), jnp.float32)}}


def _step_dir(ck, step):
    return os.path.join(ck.directory, f"step_{step:08d}")


def _corrupt(ck, step, how):
    d = _step_dir(ck, step)
    if how == "truncated_metadata":
        with open(os.path.join(d, "metadata.json"), "w") as f:
            f.write('{"step":')  # cut mid-object
    elif how == "partial_npy":
        name = next(n for n in os.listdir(d) if n.endswith(".npy"))
        with open(os.path.join(d, name), "wb") as f:
            f.write(b"\x93NUMPY")  # header cut short
    elif how == "missing_leaf":
        name = next(n for n in os.listdir(d) if n.endswith(".npy"))
        os.remove(os.path.join(d, name))
    elif how == "shape_drift":
        name = next(n for n in os.listdir(d) if n.endswith(".npy"))
        np.save(os.path.join(d, name), np.zeros((2, 2), np.float32))
    else:
        raise AssertionError(how)


def test_crash_mid_write_keeps_previous_step(tmp_path):
    """A chaos crash between the temp write and the atomic rename loses
    only the in-flight save; the previous step keeps serving restores
    and no temp litter is published as a step."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _TREE)
    plan = FaultPlan([FaultSpec(site="checkpoint.write", kind="raise",
                                at=1, times=1)])
    with chaos.active(plan):
        with pytest.raises(RuntimeError):
            ck.save(2, _TREE)
    assert ck.all_steps() == [1]
    out = ck.restore(_TREE)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(_TREE["w"]))
    # the crashed save left no temp directory behind
    assert not [n for n in os.listdir(str(tmp_path))
                if n.startswith(".tmp_")]


def test_async_crash_mid_write_is_recorded_not_silent(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, _TREE)
    ck.wait()
    plan = FaultPlan([FaultSpec(site="checkpoint.write", kind="raise",
                                at=1, times=1)])
    with chaos.active(plan):
        ck.save(2, _TREE)
        ck.wait()
    assert ck.failed_saves == 1
    assert ck.last_error is not None
    assert ck.all_steps() == [1]


@pytest.mark.parametrize("how", ["truncated_metadata", "partial_npy",
                                 "missing_leaf", "shape_drift"])
def test_restore_skips_corrupt_newest_step(tmp_path, how):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    tree1 = {"w": jnp.full((3, 4), 1.0), "nested": {"b": jnp.ones((5,))}}
    tree2 = {"w": jnp.full((3, 4), 2.0), "nested": {"b": jnp.ones((5,))}}
    ck.save(1, tree1)
    ck.save(2, tree2)
    _corrupt(ck, 2, how)
    out = ck.restore(_TREE)  # newest (2) is corrupt: falls back to 1
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.full((3, 4), 1.0, np.float32))
    # an explicit step= request still surfaces the corruption
    with pytest.raises(Exception):
        ck.restore(_TREE, step=2)


def test_restore_all_corrupt_raises_structured(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _TREE)
    ck.save(2, _TREE)
    _corrupt(ck, 1, "partial_npy")
    _corrupt(ck, 2, "truncated_metadata")
    with pytest.raises(FileNotFoundError, match="no intact checkpoint"):
        ck.restore(_TREE)


@settings(max_examples=10, deadline=None)
@given(plan=st.lists(
    st.tuples(st.integers(1, 4),  # step to corrupt
              st.sampled_from(["truncated_metadata", "partial_npy",
                               "missing_leaf", "shape_drift"])),
    min_size=0, max_size=3, unique_by=lambda t: t[0]))
def test_restore_fuzz_falls_back_to_newest_intact(tmp_path_factory, plan):
    """Whatever subset of steps a fuzzer corrupts, restore returns the
    newest *intact* step's values (or raises when none survive)."""
    d = tmp_path_factory.mktemp("ckfuzz")
    ck = Checkpointer(str(d), keep=4, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"w": jnp.full((3, 4), float(s)),
                    "nested": {"b": jnp.ones((5,))}})
    corrupted = {s for s, _ in plan}
    for s, how in plan:
        _corrupt(ck, s, how)
    intact = [s for s in (1, 2, 3, 4) if s not in corrupted]
    if not intact:
        with pytest.raises(FileNotFoundError):
            ck.restore(_TREE)
        return
    out = ck.restore(_TREE)
    np.testing.assert_array_equal(
        np.asarray(out["w"]),
        np.full((3, 4), float(max(intact)), np.float32))
