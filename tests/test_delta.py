"""Dynamic-graph delta updates: ``repro.serve.runtime.DeltaGraph``.

Covers the acceptance contract of the overlay:
  * **exact parity** — any interleaved sequence of edge inserts,
    updates, and deletes produces the same SpMM (and SDDMM) results as
    a from-scratch rebuild of the final graph, within 1e-6, on the csr
    and sell overlays at 0.9/0.99 sparsity;
  * **retrace stability** — a jitted consumer traces exactly once
    across >= 1000 mixed deltas (capacity stats + constant array
    shapes), with zero repacks in between;
  * slack exhaustion triggers an automatic repack around the pending
    edge (consumers retrace once, parity holds);
  * tombstoned slots contribute exactly zero (delete-all == zero
    output);
  * delta application invalidates exact stats (the planner's repack
    signal) while the served capacity stats stay constant;
  * the background repack overlaps serving and replays the delta
    journal on swap.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dispatch.stats import MatrixStats
from repro.serve.runtime import DeltaGraph
from repro.sparse import SparseMatrix, sddmm, spmm

BLOCK = (8, 8)
N = 64
D = 8
SWEEP = [0.9, 0.99]


def _dense(rng, n=N, sparsity=0.9):
    a = np.where(rng.random((n, n)) < (1.0 - sparsity),
                 rng.normal(size=(n, n)), 0.0).astype(np.float32)
    if not a.any():
        a[0, 0] = 1.0
    return a


def _make(rng, form, sparsity, **kw):
    dense = _dense(rng, sparsity=sparsity)
    kw.setdefault("block", BLOCK)
    if form == "sell":
        kw.setdefault("c", 16)
    return dense, DeltaGraph(dense, form=form, **kw)


def _random_deltas(rng, dg, dense, n_deltas):
    """Apply a mixed insert/update/delete stream; return the live dense."""
    live = {(int(r), int(c)): float(dense[r, c])
            for r, c in zip(*np.nonzero(dense))}
    for _ in range(n_deltas):
        op = rng.random()
        if op < 0.4 and len(live) > 1:            # delete an existing edge
            r, c = list(live)[rng.integers(len(live))]
            dg.delete(r, c)
            del live[(r, c)]
        elif op < 0.7:                            # update in place
            r, c = list(live)[rng.integers(len(live))]
            v = float(rng.normal())
            while v == 0.0:
                v = float(rng.normal())
            dg.insert(r, c, v)
            live[(r, c)] = v
        else:                                     # insert a fresh edge
            r, c = int(rng.integers(N)), int(rng.integers(N))
            v = float(rng.normal())
            while v == 0.0 or (r, c) in live:
                r, c = int(rng.integers(N)), int(rng.integers(N))
                v = float(rng.normal())
            dg.insert(r, c, v)
            live[(r, c)] = v
    out = np.zeros((N, N), np.float32)
    for (r, c), v in live.items():
        out[r, c] = v
    return out


# ---------------------------------------------------------------------------
# parity: deltas == from-scratch rebuild
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("form", ["csr", "sell"])
def test_delta_sequence_matches_rebuild(rng, form, sparsity):
    dense, dg = _make(rng, form, sparsity)
    final = _random_deltas(rng, dg, dense, 120)
    np.testing.assert_allclose(np.asarray(dg.matrix.densify()), final,
                               rtol=0, atol=0)
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    rebuild = SparseMatrix.from_dense(final, formats=(form,), block=BLOCK)
    got = spmm(dg.matrix, h, policy=form)
    want = spmm(rebuild, h, policy=form)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    assert dg.live_nnz == int((final != 0).sum())


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("form", ["csr", "sell"])
def test_delta_sddmm_matches_rebuild(rng, form, sparsity):
    dense, dg = _make(rng, form, sparsity)
    final = _random_deltas(rng, dg, dense, 80)
    b = jnp.asarray(rng.normal(size=(N, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4, N)).astype(np.float32))
    rebuild = SparseMatrix.from_dense(final, formats=(form,), block=BLOCK)
    got = sddmm(dg.matrix, b, c, policy=form).densify()
    want = sddmm(rebuild, b, c, policy=form).densify()
    # tombstones sample to exactly zero — parity is dense-wide
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_delete_all_is_zero(rng):
    dense, dg = _make(rng, "csr", 0.99)
    for r, c in zip(*np.nonzero(dense)):
        dg.delete(int(r), int(c))
    assert dg.live_nnz == 0
    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(spmm(dg.matrix, h, policy="csr")), np.zeros((N, D)))


# ---------------------------------------------------------------------------
# retrace stability
# ---------------------------------------------------------------------------


def test_thousand_deltas_zero_retrace(rng):
    dense, dg = _make(rng, "csr", 0.9, slack=4.0)
    traces = []

    @jax.jit
    def consume(m, h):
        traces.append(1)  # runs at trace time only
        return m @ h

    h = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    consume(dg.matrix, h)
    final = dense
    for _ in range(10):
        final = _random_deltas(rng, dg, final, 110)
        consume(dg.matrix, h)
    assert dg.deltas_applied >= 1000
    assert dg.repacks == 0
    assert len(traces) == 1  # capacity stats + fixed shapes: no retrace
    np.testing.assert_allclose(np.asarray(consume(dg.matrix, h)),
                               final.astype(np.float64) @ np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_sell_value_churn_zero_repack(rng):
    dense, dg = _make(rng, "sell", 0.9)
    edges = list(zip(*np.nonzero(dense)))
    for i in range(300):
        r, c = edges[i % len(edges)]
        dg.delete(int(r), int(c))
        dg.insert(int(r), int(c), float(i + 1))
    assert dg.repacks == 0
    assert dg.deltas_applied == 600


def test_slack_exhaustion_auto_repacks(rng):
    dense, dg = _make(rng, "csr", 0.99, slack=0.0)
    free0 = dg.free_slots()
    k = 0
    while dg.repacks == 0:  # keep inserting until the pool runs dry
        r, c = divmod(k, N)
        if dense[r, c] == 0:
            dg.insert(r, c, 1.0)
            dense[r, c] = 1.0
        k += 1
        assert k < N * N, "slack never exhausted"
    assert dg.repacks == 1 and dg.free_slots() > 0
    # the edge that overflowed the pool is live after the repack
    np.testing.assert_allclose(np.asarray(dg.matrix.densify()), dense)
    assert dg.capacity >= free0


def test_sell_out_of_structure_insert_repacks(rng):
    dense, dg = _make(rng, "sell", 0.9, width_slack=1)
    # overflow one row's slack: insert into fresh columns until repack
    r = int(np.argmax((dense != 0).sum(axis=1)))
    # the width ladder quantizes slice widths up, so the row starts with
    # some headroom beyond width_slack — keep inserting until it runs out
    empty_cols = np.flatnonzero(dense[r] == 0)
    for j, c in enumerate(empty_cols):
        dg.insert(r, int(c), float(j + 1))
        dense[r, c] = float(j + 1)
        if dg.repacks:
            break
    assert dg.repacks >= 1
    np.testing.assert_allclose(np.asarray(dg.matrix.densify()), dense)


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------


def test_capacity_stats_constant_exact_stats_track(rng):
    dense, dg = _make(rng, "csr", 0.9)
    served0 = dg.matrix.stats
    assert served0.nnz == dg.capacity  # priced at capacity, not live
    r, c = next(zip(*np.nonzero(dense)))
    dg.delete(int(r), int(c))
    assert dg.stats_invalidations == 1
    assert dg.matrix.stats == served0          # served aux unchanged
    assert dg.exact_stats.nnz == dg.live_nnz   # true structure tracks
    dg.repack()
    assert dg.matrix.stats != served0          # repack re-prices


def test_with_capacity_validates():
    s = MatrixStats.from_coords((8, 8), np.arange(4), np.arange(4))
    assert s.with_capacity(10).nnz == 10
    with pytest.raises(ValueError):
        s.with_capacity(2)


def test_insert_zero_and_missing_delete_raise(rng):
    dense, dg = _make(rng, "csr", 0.9)
    with pytest.raises(ValueError):
        dg.insert(0, 0, 0.0)
    r, c = np.nonzero(dense == 0)
    with pytest.raises(KeyError):
        dg.delete(int(r[0]), int(c[0]))


# ---------------------------------------------------------------------------
# background repack
# ---------------------------------------------------------------------------


def test_background_repack_swaps_and_replays(rng):
    dense, dg = _make(rng, "csr", 0.9, slack=0.5)
    final = _random_deltas(rng, dg, dense, 60)
    assert dg.maybe_repack_async(low_water=1.0)  # force a rebuild start
    # deltas during the rebuild land in the journal and replay on swap
    r, c = next(zip(*np.nonzero(final)))
    dg.delete(int(r), int(c))
    final[r, c] = 0
    assert dg.poll_repack(timeout=30.0)
    assert dg.repacks == 1
    np.testing.assert_allclose(np.asarray(dg.matrix.densify()), final)
    assert dg.matrix.stats.nnz == dg.capacity
