# NOTE: deliberately does NOT set --xla_force_host_platform_device_count.
# Unit/smoke tests run on the single real CPU device; distributed tests
# spawn subprocesses with their own XLA_FLAGS (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
