# NOTE: deliberately does NOT set --xla_force_host_platform_device_count.
# Unit/smoke tests run on the single real CPU device; distributed tests
# spawn subprocesses with their own XLA_FLAGS (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (subprocess / multi-device)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
