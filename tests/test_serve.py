"""Serving correctness: prefill + decode == full teacher-forced forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models.transformer import (decode_step, forward_hidden, init_lm,
                                      prefill)
from repro.serve.engine import ServeConfig, ServingEngine

LM_ARCHS = [a for a in ARCHS if a != "paper-gnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_equals_full_forward(rng, arch):
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        capacity_factor=float(max(cfg.n_experts, 1)))  # no MoE drops
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S, EXTRA = 2, 32, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + EXTRA)),
                       jnp.int32)
    kw = {}
    if cfg.vision_tokens:
        kw["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.encoder_layers:
        kw["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    hid, _, _ = forward_hidden(params, cfg, toks, mode="train", remat=False,
                               **kw)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    full = hid.astype(jnp.float32) @ head.astype(jnp.float32)
    off = cfg.vision_tokens

    logits, cache = prefill(params, cfg, toks[:, :S],
                            max_len=S + EXTRA + cfg.vision_tokens, **kw)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, off + S - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(EXTRA):
        logits, cache = decode_step(params, cfg, toks[:, S + t:S + t + 1],
                                    cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, off + S + t]),
                                   rtol=5e-3, atol=5e-3)


def test_engine_greedy_generation_deterministic(rng):
    cfg = dataclasses.replace(get_smoke_config("granite-20b"),
                              dtype="float32")
    params = init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=64))
    prompts = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    out1 = eng.generate(prompts, n_new=8)
    out2 = eng.generate(prompts, n_new=8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)


def test_local_ring_cache_decode(rng):
    """Local-attention ring cache (window < seq) stays correct past wrap."""
    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"), dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S, EXTRA = 1, 96, 16  # window=64 -> ring wraps during decode
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + EXTRA)),
                       jnp.int32)
    hid, _, _ = forward_hidden(params, cfg, toks, mode="train", remat=False)
    head = params["embed"].T
    full = hid.astype(jnp.float32) @ head.astype(jnp.float32)
    logits, cache = prefill(params, cfg, toks[:, :S], max_len=S + EXTRA)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(EXTRA):
        logits, cache = decode_step(params, cfg, toks[:, S + t:S + t + 1],
                                    cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, S + t]),
                                   rtol=5e-3, atol=5e-3)
