"""Roofline extraction: HLO collective parsing + extrapolation algebra."""
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.roofline import (RooflineTerms, collective_bytes, costs_of,
                                   extrapolate, model_flops_for,
                                   weighted_collective_bytes)

HLO = """
ENTRY %main {
  %ag = f32[128,512]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = bf16[1024]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64,64]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%w)
  %cp = bf16[32]{0} collective-permute(%v)
  %agd = f32[9,9]{1,0} all-gather-done(%h)
  %ags = (f32[10]{0}, f32[10]{0}) all-gather-start(%g)
}
"""


def test_collective_bytes_parses_result_shapes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 128 * 512 * 4 + 10 * 4  # + start tuple / 2
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 64 * 64 * 4
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["collective-permute"] == 32 * 2


def test_weighted_bytes_doubles_allreduce():
    w = weighted_collective_bytes({"all-reduce": 10, "all-gather": 4})
    assert w == 24


def test_extrapolation_linear():
    c1 = {"flops": 10.0, "bytes": 100.0, "coll": {"all-reduce": 1.0}}
    c2 = {"flops": 16.0, "bytes": 130.0, "coll": {"all-reduce": 1.5,
                                                  "all-gather": 2.0}}
    out = extrapolate(c1, c2, n_periods=5)
    assert out["flops"] == 10 + 4 * 6
    assert out["bytes"] == 100 + 4 * 30
    assert out["coll"]["all-reduce"] == 1.0 + 4 * 0.5
    assert out["coll"]["all-gather"] == 8.0  # 0 + 4*2


def test_terms_and_bottleneck():
    t = RooflineTerms(
        flops_per_chip=197e12, bytes_per_chip=819e9 * 2,
        collective_bytes_per_chip=50e9 * 0.5,
        per_op_collectives={}, chips=256, model_flops=197e12 * 256 * 0.5)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 0.5) < 1e-9
    assert t.bottleneck == "memory"
    assert abs(t.roofline_fraction - 0.25) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = get_config("gemma3-4b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    dec = model_flops_for(cfg, SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert tr == pytest.approx(6.0 * n * 256 * 4096)
    assert dec == pytest.approx(2.0 * n * 128)
