"""Pallas Block-ELL SpMM kernel vs pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.formats import BlockELL
from repro.kernels.spmm.ops import spmm_blockell
from repro.kernels.spmm.ref import spmm_blockell_ref


def _make(rng, m, n, density, bm, bn, dtype=np.float32):
    mask = rng.random((m, n)) < density
    dense = np.where(mask, rng.normal(size=(m, n)), 0.0).astype(dtype)
    return dense, BlockELL.from_dense(dense, bm, bn)


@pytest.mark.parametrize("m,n,d,bm,bn,bd", [
    (256, 256, 256, 64, 128, 128),
    (128, 512, 256, 64, 128, 256),
    (512, 128, 128, 128, 128, 128),
    (64, 128, 512, 64, 128, 512),
])
@pytest.mark.parametrize("density", [0.02, 0.2, 0.9])
def test_spmm_kernel_matches_ref(rng, m, n, d, bm, bn, bd, density):
    dense, ell = _make(rng, m, n, density, bm, bn)
    h = rng.normal(size=(n, d)).astype(np.float32)
    ref = spmm_blockell_ref(ell, jnp.asarray(h))
    out = spmm_blockell(ell, jnp.asarray(h), bd=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ref), dense @ h,
                               rtol=1e-3, atol=1e-3)


def test_spmm_kernel_bf16(rng):
    dense, ell = _make(rng, 128, 256, 0.2, 64, 128)
    ell = BlockELL(indices=ell.indices,
                   blocks=ell.blocks.astype(jnp.bfloat16),
                   nblocks=ell.nblocks, shape=ell.shape)
    h = jnp.asarray(rng.normal(size=(256, 128)), jnp.bfloat16)
    ref = spmm_blockell_ref(ell, h, out_dtype=jnp.float32)
    out = spmm_blockell(ell, h, out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_spmm_empty_rows(rng):
    """Block-rows with zero nonzero blocks (pure padding slots)."""
    dense = np.zeros((256, 256), np.float32)
    dense[:64] = rng.normal(size=(64, 256))  # only the first block-row
    ell = BlockELL.from_dense(dense, 64, 128)
    h = rng.normal(size=(256, 128)).astype(np.float32)
    out = spmm_blockell(ell, jnp.asarray(h), interpret=True)
    np.testing.assert_allclose(np.asarray(out), dense @ h,
                               rtol=3e-4, atol=3e-4)
    assert np.all(np.asarray(out)[64:] == 0.0)


@pytest.mark.parametrize("m", [100, 96, 65])
def test_spmm_ragged_all_padding_final_block_row(rng, m):
    """Regression: ragged n_rows % bm != 0 whose *final* block-row is
    pure padding (all-zero slot indices, nblocks == 0).

    The accumulator scratch is revisited across grid steps; a flush bug
    would leak the previous block-row's accumulator into the padded
    tail instead of zeros.  Pin the exact contract: padded output rows
    are written and are exactly zero, real rows match the oracle.
    """
    n, d = 256, 128
    dense = np.zeros((m, n), np.float32)
    live = min(64, m)  # all nonzeros in the first block-row
    dense[:live] = np.where(rng.random((live, n)) < 0.3,
                            rng.normal(size=(live, n)), 0)
    ell = BlockELL.from_dense(dense, 64, 128)
    assert ell.shape[0] % 64 == 0 and ell.shape[0] > m
    # the final block-row is pure padding: no blocks, clipped indices
    assert int(np.asarray(ell.nblocks)[-1]) == 0
    assert np.all(np.asarray(ell.indices)[-1] == 0)
    h = rng.normal(size=(n, d)).astype(np.float32)
    out = np.asarray(spmm_blockell(ell, jnp.asarray(h), interpret=True))
    oracle = np.zeros((ell.shape[0], d), np.float32)
    oracle[:m] = dense @ h
    np.testing.assert_allclose(out, oracle, rtol=3e-4, atol=3e-4)
    assert np.all(out[live:] == 0.0), "stale accumulator leaked into " \
        "the all-padding block-row"


@settings(max_examples=10, deadline=None)
@given(
    nbr=st.integers(1, 4), nbc=st.integers(1, 4),
    dblk=st.sampled_from([1, 2]),
    density=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1),
)
def test_spmm_kernel_property(nbr, nbc, dblk, density, seed):
    rng = np.random.default_rng(seed)
    m, n, d = nbr * 64, nbc * 128, dblk * 128
    mask = rng.random((m, n)) < density
    dense = np.where(mask, rng.normal(size=(m, n)), 0.0).astype(np.float32)
    ell = BlockELL.from_dense(dense, 64, 128)
    h = rng.normal(size=(n, d)).astype(np.float32)
    out = spmm_blockell(ell, jnp.asarray(h), interpret=True)
    np.testing.assert_allclose(np.asarray(out), dense @ h,
                               rtol=5e-4, atol=5e-4)
