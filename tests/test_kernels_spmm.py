"""Pallas Block-ELL SpMM kernel vs pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.formats import BlockELL
from repro.kernels.spmm.ops import spmm_blockell
from repro.kernels.spmm.ref import spmm_blockell_ref


def _make(rng, m, n, density, bm, bn, dtype=np.float32):
    mask = rng.random((m, n)) < density
    dense = np.where(mask, rng.normal(size=(m, n)), 0.0).astype(dtype)
    return dense, BlockELL.from_dense(dense, bm, bn)


@pytest.mark.parametrize("m,n,d,bm,bn,bd", [
    (256, 256, 256, 64, 128, 128),
    (128, 512, 256, 64, 128, 256),
    (512, 128, 128, 128, 128, 128),
    (64, 128, 512, 64, 128, 512),
])
@pytest.mark.parametrize("density", [0.02, 0.2, 0.9])
def test_spmm_kernel_matches_ref(rng, m, n, d, bm, bn, bd, density):
    dense, ell = _make(rng, m, n, density, bm, bn)
    h = rng.normal(size=(n, d)).astype(np.float32)
    ref = spmm_blockell_ref(ell, jnp.asarray(h))
    out = spmm_blockell(ell, jnp.asarray(h), bd=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ref), dense @ h,
                               rtol=1e-3, atol=1e-3)


def test_spmm_kernel_bf16(rng):
    dense, ell = _make(rng, 128, 256, 0.2, 64, 128)
    ell = BlockELL(indices=ell.indices,
                   blocks=ell.blocks.astype(jnp.bfloat16),
                   nblocks=ell.nblocks, shape=ell.shape)
    h = jnp.asarray(rng.normal(size=(256, 128)), jnp.bfloat16)
    ref = spmm_blockell_ref(ell, h, out_dtype=jnp.float32)
    out = spmm_blockell(ell, h, out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_spmm_empty_rows(rng):
    """Block-rows with zero nonzero blocks (pure padding slots)."""
    dense = np.zeros((256, 256), np.float32)
    dense[:64] = rng.normal(size=(64, 256))  # only the first block-row
    ell = BlockELL.from_dense(dense, 64, 128)
    h = rng.normal(size=(256, 128)).astype(np.float32)
    out = spmm_blockell(ell, jnp.asarray(h), interpret=True)
    np.testing.assert_allclose(np.asarray(out), dense @ h,
                               rtol=3e-4, atol=3e-4)
    assert np.all(np.asarray(out)[64:] == 0.0)


@settings(max_examples=10, deadline=None)
@given(
    nbr=st.integers(1, 4), nbc=st.integers(1, 4),
    dblk=st.sampled_from([1, 2]),
    density=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1),
)
def test_spmm_kernel_property(nbr, nbc, dblk, density, seed):
    rng = np.random.default_rng(seed)
    m, n, d = nbr * 64, nbc * 128, dblk * 128
    mask = rng.random((m, n)) < density
    dense = np.where(mask, rng.normal(size=(m, n)), 0.0).astype(np.float32)
    ell = BlockELL.from_dense(dense, 64, 128)
    h = rng.normal(size=(n, d)).astype(np.float32)
    out = spmm_blockell(ell, jnp.asarray(h), interpret=True)
    np.testing.assert_allclose(np.asarray(out), dense @ h,
                               rtol=5e-4, atol=5e-4)
