"""Batched multi-graph execution: block-diagonal composition, shape
bucketing, the bucketed compilation cache, and the micro-batching
serving engine.

Covers the acceptance contract of the batching subsystem:
  * block-diagonal ``B @ H`` equals per-graph ``A_i @ H_i`` stacking at
    0.5/0.9/0.99 sparsity for both the element (csr) and blocked (ell)
    forms;
  * ``unbatch`` round-trips; batched SDDMM equals per-graph SDDMM;
  * gradients through the batched product match per-graph gradients;
  * >= 100 mixed-shape requests compile at most O(#buckets) executors
    (trace-count pin);
  * the serving engine returns per-request results identical to the
    unbatched forward, and reports latency/throughput/padding counters.
"""
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch import (BatchedSparseMatrix, Bucket, BucketingConfig,
                         BucketedExecutor, batch_matmul, batch_sddmm,
                         bucket_for, canonical_stats, empty_in_bucket,
                         pad_to_bucket, quantize_up)
from repro.sparse import SparseMatrix

SWEEP = [0.5, 0.9, 0.99]
BLOCK = (16, 16)
SIZES = [48, 80, 33]  # deliberately not block-aligned (33)
D = 8


def _uniform_sparse(rng, n, sparsity):
    mask = rng.random((n, n)) < (1.0 - sparsity)
    dense = np.where(mask, rng.normal(size=(n, n)), 0.0).astype(np.float32)
    if not dense.any():  # keep at least one nonzero at 0.99 sparsity
        dense[0, 0] = 1.0
    return dense


def _family(rng, sparsity, formats=("ell", "csr")):
    denses = [_uniform_sparse(rng, n, sparsity) for n in SIZES]
    mats = [SparseMatrix.from_dense(a, formats=formats, block=BLOCK)
            for a in denses]
    hs = [jnp.asarray(rng.normal(size=(a.shape[1], D)).astype(np.float32))
          for a in denses]
    return denses, mats, hs


# ---------------------------------------------------------------------------
# block-diagonal composition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("fmt", ["csr", "ell"])
def test_blockdiag_matmul_matches_pergraph(rng, sparsity, fmt):
    denses, mats, hs = _family(rng, sparsity)
    ys = batch_matmul(mats, hs, formats=(fmt,), policy=fmt)
    for y, a, h in zip(ys, denses, hs):
        np.testing.assert_allclose(np.asarray(y), a @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


def test_blockdiag_multiform_auto_policy(rng):
    denses, mats, hs = _family(rng, 0.9)
    B = BatchedSparseMatrix.from_matrices(mats)
    assert B.formats == ("ell", "csr") and B.n_graphs == 3
    # offsets are padded (block-aligned), so both forms agree on them
    assert all(seg.rows % BLOCK[0] == 0 for seg in B.segments)
    ys = B.unbatch(B @ B.batch_features(hs))
    for y, a, h in zip(ys, denses, hs):
        np.testing.assert_allclose(np.asarray(y), a @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


def test_unbatch_roundtrip(rng):
    _, mats, hs = _family(rng, 0.9)
    B = BatchedSparseMatrix.from_matrices(mats)
    got = B.unbatch(B.batch_features(hs), space="cols")
    for h, back in zip(hs, got):
        np.testing.assert_array_equal(np.asarray(h), np.asarray(back))
    # values split recovers each graph's stored values (both forms)
    for fmt in ("csr", "ell"):
        Bf = BatchedSparseMatrix.from_matrices(mats, formats=(fmt,))
        parts = Bf.unbatch_values(Bf.matrix.data, form=fmt)
        for m, part in zip(mats, parts):
            vals = m.form(fmt)[2] if fmt == "csr" else m.form(fmt).blocks
            np.testing.assert_array_equal(np.asarray(vals),
                                          np.asarray(part))


@pytest.mark.parametrize("fmt", ["csr", "ell"])
def test_batch_sddmm_matches_pergraph(rng, fmt):
    denses, mats, hs = _family(rng, 0.9)
    bs = [jnp.asarray(rng.normal(size=(a.shape[0], 4)).astype(np.float32))
          for a in denses]
    cs = [jnp.asarray(rng.normal(size=(4, a.shape[1])).astype(np.float32))
          for a in denses]
    B = BatchedSparseMatrix.from_matrices(mats, formats=(fmt,))
    got = batch_sddmm(B, bs, cs, policy=fmt)
    for v, m, b, c in zip(got, mats, bs, cs):
        ref = m.to(fmt).sddmm(b, c, policy=fmt).data
        np.testing.assert_allclose(np.asarray(v), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_blockdiag_gradients_match_pergraph(rng):
    denses, mats, hs = _family(rng, 0.9, formats=("csr",))
    B = BatchedSparseMatrix.from_matrices(mats)

    def batched_loss(vals, flat_h):
        y = B.matrix.with_data(vals) @ flat_h
        return jnp.sum(jnp.tanh(y))

    H = B.batch_features(hs)
    gv, gh = jax.grad(batched_loss, argnums=(0, 1))(B.matrix.data, H)
    gv_parts = B.unbatch_values(gv)
    gh_parts = B.unbatch(gh, space="cols")
    for m, h, gvp, ghp in zip(mats, hs, gv_parts, gh_parts):
        def loss(vals, hh, m=m):
            return jnp.sum(jnp.tanh(m.with_data(vals) @ hh))

        rv, rh = jax.grad(loss, argnums=(0, 1))(m.data, h)
        np.testing.assert_allclose(np.asarray(gvp), np.asarray(rv),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(ghp), np.asarray(rh),
                                   rtol=2e-4, atol=2e-4)


def test_from_matrices_rejects_mismatches(rng):
    _, mats, _ = _family(rng, 0.9)
    with pytest.raises(ValueError, match="at least one matrix"):
        BatchedSparseMatrix.from_matrices([])
    with pytest.raises(ValueError, match="carry no 'ell'"):
        BatchedSparseMatrix.from_matrices(
            [mats[0], mats[1].to("csr")], formats=("ell",))
    B = BatchedSparseMatrix.from_matrices(mats)
    with pytest.raises(ValueError, match="feature blocks"):
        B.batch_features([jnp.zeros((SIZES[0], D))])


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_quantize_up_grid():
    assert quantize_up(1, 32, 2.0) == 32
    assert quantize_up(32, 32, 2.0) == 32
    assert quantize_up(33, 32, 2.0) == 64
    assert quantize_up(129, 32, 2.0) == 256
    # monotone and always covering
    prev = 0
    for x in range(1, 2000, 7):
        q = quantize_up(x, 32, 2.0)
        assert q >= x and q >= prev
        prev = q


def test_bucket_padding_preserves_product_and_canonical_stats(rng):
    a = _uniform_sparse(rng, 70, 0.9)
    A = SparseMatrix.from_dense(a, formats=("ell", "csr"), block=BLOCK)
    h = jnp.asarray(rng.normal(size=(70, D)).astype(np.float32))
    bucket = bucket_for(A.stats)
    assert bucket.rows >= A.stats.shape[0]
    assert bucket.rows % BLOCK[0] == 0
    for form in ("csr", "ell"):
        P = pad_to_bucket(A, bucket, form=form)
        assert P.shape == (bucket.rows, bucket.cols)
        assert P.stats == canonical_stats(bucket)
        hp = jnp.zeros((bucket.cols, D), h.dtype).at[:70].set(h)
        y = np.asarray(P @ hp)[:70]
        np.testing.assert_allclose(y, a @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
        # the all-zero batch filler is harmless under the product
        E = empty_in_bucket(bucket, form=form)
        assert np.asarray(E @ hp).max() == 0.0


def test_executor_trace_count_pin_100_mixed_requests(rng):
    """>= 100 mixed-shape requests compile O(#buckets) executors."""
    ex = BucketedExecutor(max_batch=16,
                          bucketing=BucketingConfig(growth=2.0))
    mats, hs, refs = [], [], []
    for i in range(104):
        n = int(rng.integers(20, 150))
        a = _uniform_sparse(rng, n, 0.92)
        mats.append(SparseMatrix.from_dense(a, formats=("ell", "csr"),
                                            block=BLOCK))
        h = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
        hs.append(h)
        refs.append(a @ np.asarray(h))
    for lo in range(0, len(mats), 16):  # serve in micro-batches of 16
        outs = ex.run(mats[lo:lo + 16], hs[lo:lo + 16])
        for o, r in zip(outs, refs[lo:lo + 16]):
            np.testing.assert_allclose(o, r, rtol=2e-4, atol=2e-4)
    rep = ex.report()
    assert rep["requests"] == 104
    # the pin: compiles bounded by the bucket grid (7 buckets x a few
    # quantized batch sizes for this seed), not by the traffic
    assert rep["compiles"] == rep["executors_cached"] <= 22
    assert rep["compiles"] < rep["requests"] // 4
    assert rep["buckets"] <= 8
    # identical traffic replay: zero new compiles
    before = ex.compiles
    ex.run(mats[:16], hs[:16])
    assert ex.compiles == before
    waste = rep["padding"]
    assert waste["padded_nnz"] >= waste["real_nnz"] > 0
    assert 0.0 <= waste["waste_fraction"] < 1.0


def test_executor_lru_eviction(rng):
    ex = BucketedExecutor(max_batch=1, max_executors=2)
    for i, n in enumerate([30, 60, 120, 240]):
        a = _uniform_sparse(rng, n, 0.9)
        m = SparseMatrix.from_dense(a, formats=("ell", "csr"), block=BLOCK)
        ex.run([m], [jnp.zeros((n, D), jnp.float32)])
    rep = ex.report()
    assert rep["executors_cached"] <= 2
    assert rep["evictions"] >= 2


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gcn_setup():
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gcn

    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    graphs = [build_graph(random_graph(n, avg_degree=4, seed=n), GCFG)
              for n in (48, 80, 33)]
    return GCFG, params, graphs


def test_gcn_forward_batched_matches_pergraph(rng, gcn_setup):
    from repro.models.gnn import batch_graphs, gcn_forward, \
        gcn_forward_batched

    cfg, params, graphs = gcn_setup
    xs = [jnp.asarray(rng.normal(size=(g.n_nodes, cfg.in_features))
                      .astype(np.float32)) for g in graphs]
    B = batch_graphs(graphs)
    outs = gcn_forward_batched(params, B, xs)
    for o, g, x in zip(outs, graphs, xs):
        ref = gcn_forward(params, g, x, policy="csr")
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


def test_batch_serving_engine_end_to_end(rng, gcn_setup):
    from repro.models.gnn import gcn_forward
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=8,
                                          max_delay_ms=2.0)) as eng:
        futs, reqs = [], []
        for i in range(24):
            g = graphs[i % len(graphs)]
            x = jnp.asarray(rng.normal(size=(g.n_nodes, cfg.in_features))
                            .astype(np.float32))
            reqs.append((g, x))
            futs.append(eng.submit(g, x))
        for f, (g, x) in zip(futs, reqs):
            y = f.result(timeout=300)
            assert y.shape == (g.n_nodes, cfg.n_classes)
            ref = gcn_forward(params, g, x, policy="csr")
            np.testing.assert_allclose(y, np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)
        eng.drain()
        rep = eng.report()
    assert rep["completed"] == rep["submitted"] == 24
    assert rep["req_per_s"] > 0
    assert rep["latency_ms_p99"] >= rep["latency_ms_p50"] > 0
    assert sum(rep["flushes"].values()) >= 1
    ex = rep["executor"]
    assert ex["compiles"] <= ex["calls"] <= rep["completed"]
    assert 0.0 <= ex["padding"]["waste_fraction"] < 1.0


def test_batch_serving_engine_error_propagates(gcn_setup):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=4,
                                          max_delay_ms=1.0)) as eng:
        bad = jnp.zeros((graphs[0].n_nodes + 1, cfg.in_features),
                        jnp.float32)  # wrong node count
        with pytest.raises(ValueError, match="do not match"):
            eng.submit(graphs[0], bad).result(timeout=60)
        # failed requests still count as resolved: drain must not hang
        eng.drain(timeout=60)
        assert eng.report()["failed"] == 1
        # the engine keeps serving after a failed flush
        good = jnp.zeros((graphs[0].n_nodes, cfg.in_features), jnp.float32)
        y = eng.infer(graphs[0], good)
        assert y.shape == (graphs[0].n_nodes, cfg.n_classes)
        eng.drain(timeout=60)
        eng.reset_metrics()
        rep = eng.report()
        assert rep["submitted"] == rep["completed"] == rep["failed"] == 0


# ---------------------------------------------------------------------------
# per-engine plan-cache reporting (no cross-engine aliasing)
# ---------------------------------------------------------------------------


def test_batch_serving_engine_close_fails_queued_futures(gcn_setup):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    eng = BatchServingEngine.for_gcn(
        params, scfg=BatchServeConfig(max_batch=4, max_delay_ms=1.0))
    x = jnp.zeros((graphs[0].n_nodes, cfg.in_features), jnp.float32)
    futs = [eng.submit(graphs[0], x) for _ in range(6)]
    eng.close()
    # every future resolves: with a result (flushed before close) or
    # with the engine-closed error — never left hanging
    for f in futs:
        try:
            y = f.result(timeout=60)
            assert y.shape == (graphs[0].n_nodes, cfg.n_classes)
        except RuntimeError as exc:
            assert "engine closed" in str(exc)
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(graphs[0], x)


def test_per_engine_plan_cache_not_aliased(rng, gcn_setup):
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gcn
    from repro.serve.engine import GNNServingEngine

    cfg, params, _ = gcn_setup
    g1 = build_graph(random_graph(48, avg_degree=4, seed=91), cfg)
    g2 = build_graph(random_graph(64, avg_degree=4, seed=92), cfg)
    e1 = GNNServingEngine(params, g1)
    e2 = GNNServingEngine(params, g2)
    x1 = rng.normal(size=(48, cfg.in_features)).astype(np.float32)
    e1.infer(x1)
    s1 = e1.dispatch_report()["plan_cache"]
    assert s1["misses"] > 0  # the jitted forward planned on this graph
    # traffic on engine 2 must not move engine 1's counters
    for _ in range(3):
        e2.infer(rng.normal(size=(64, cfg.in_features)).astype(np.float32))
    assert e1.dispatch_report()["plan_cache"] == s1
    s2 = e2.dispatch_report()["plan_cache"]
    assert s2["misses"] > 0
    # the global aggregate still counts both engines
    g = e1.dispatch_report()["plan_cache_global"]
    assert g["misses"] >= s1["misses"] + s2["misses"]


def test_gnn_serving_engine_width_inference(gcn_setup):
    from repro.models.gnn import init_gat
    from repro.serve.engine import (GNNServeConfig, GNNServingEngine,
                                    _infer_planning_width)

    cfg, params, graphs = gcn_setup
    # GAT-style params (extra per-layer attention leaves) infer cleanly
    gat_params = init_gat(jax.random.PRNGKey(1), cfg)
    assert _infer_planning_width(gat_params) == cfg.hidden
    eng = GNNServingEngine(gat_params, graphs[0])
    assert eng.plan.path in ("ell", "csr")
    # a single weight array under "w" (no list wrapper) works too
    single = {"w": np.ones((cfg.in_features, 7), np.float32)}
    assert _infer_planning_width(single) == 7
    # layouts without the {"w": ...} convention fall back to leaf scan
    odd = {"weights": [np.ones((cfg.in_features, 5), np.float32)]}
    assert _infer_planning_width(odd) == 5
    assert GNNServingEngine(odd, graphs[0]).plan.path in ("ell", "csr")
    # no 2-D leaf at all: explicit override required and honored
    with pytest.raises(ValueError, match="planning feature width"):
        _infer_planning_width({"bias": np.ones((3,), np.float32)})
    eng3 = GNNServingEngine({"bias": np.ones((3,), np.float32)}, graphs[0],
                            GNNServeConfig(d=64))
    assert eng3.plan.path in ("ell", "csr")


def test_block_diag_sell_composition(rng):
    """Sell forms compose block-diagonally: one planned SpMM over the
    batch equals per-graph products, on both execution routes."""
    from repro.sparse import matmul

    mats, denses, hs = [], [], []
    for n, s in ((40, 0.97), (64, 0.99), (24, 0.9)):
        dense = np.where(rng.random((n, n)) < (1 - s),
                         rng.normal(size=(n, n)), 0).astype(np.float32)
        denses.append(dense)
        mats.append(SparseMatrix.from_dense(
            dense, formats=("sell", "csr"), block=(8, 8)))
        hs.append(rng.normal(size=(n, 6)).astype(np.float32))
    B = BatchedSparseMatrix.from_matrices(mats)
    assert "sell" in B.formats
    H = B.batch_features(hs)
    for kwargs in ({"policy": "sell"},
                   {"policy": "sell", "use_kernel": True,
                    "interpret": True}):
        outs = B.unbatch(matmul(B.matrix, H, **kwargs))
        for o, d, h in zip(outs, denses, hs):
            np.testing.assert_allclose(np.asarray(o), d @ h,
                                       rtol=5e-4, atol=5e-4)
    # sell values split back per graph by slot count
    splits = B.unbatch_values(B.matrix.form("sell").slot_vals,
                              form="sell")
    assert [int(v.shape[0]) for v in splits] == \
        [m.form("sell").n_slots for m in mats]
    # composed stats price the sell path (sum of per-graph slot volumes)
    assert B.stats.sell_stored_elements == \
        sum(m.stats.sell_stored_elements for m in mats)


# ---------------------------------------------------------------------------
# serving-engine worker-loop hardening (deadline clamp regressions)
# ---------------------------------------------------------------------------


def _inject(eng, graph, x, t_submit):
    """Enqueue a request with a forged submit timestamp, bypassing
    ``submit`` — the only way to exercise the worker loop's handling of
    requests whose window math is already skewed when they arrive."""
    from concurrent.futures import Future

    from repro.serve.engine import _Request

    req = _Request(matrix=graph.adj, features=x, future=Future(),
                   t_submit=t_submit)
    if eng._t_first is None:
        eng._t_first = req.t_submit
    eng._submitted += 1
    eng._queue.put(req)
    return req.future


def test_slow_request_flushes_on_deadline_immediately(gcn_setup):
    """A request that sat queued past its whole window (stale t_submit)
    must flush *now* via the deadline path — the worker must not wait
    another window for company — and the engine keeps serving after."""
    import time

    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    g = graphs[0]
    x = jnp.zeros((g.n_nodes, cfg.in_features), jnp.float32)
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=8,
                                          max_delay_ms=50.0)) as eng:
        eng.infer(g, x)  # warm the executor so compile time is gone
        eng.drain(timeout=60)
        before = eng.report()["flushes"]
        fut = _inject(eng, g, x, time.perf_counter() - 1.0)  # long stale
        y = fut.result(timeout=60)
        assert y.shape == (g.n_nodes, cfg.n_classes)
        eng.drain(timeout=60)
        after = eng.report()["flushes"]
        # exactly one new flush, on the deadline path (1 req < max_batch)
        assert after["deadline"] == before["deadline"] + 1
        assert after["full"] == before["full"]
        # worker alive and serving
        assert eng._worker.is_alive()
        eng.infer(g, x)


def test_skewed_future_timestamp_wait_is_bounded(gcn_setup):
    """A forged *future* t_submit (clock skew, replayed request) must
    not stall the worker for the skew: any single wait is clamped to
    one delay window.  Pre-clamp the worker slept ~30 s here."""
    import time

    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    g = graphs[0]
    x = jnp.zeros((g.n_nodes, cfg.in_features), jnp.float32)
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=8,
                                          max_delay_ms=5.0)) as eng:
        eng.infer(g, x)  # warm executor
        fut = _inject(eng, g, x, time.perf_counter() + 30.0)
        y = fut.result(timeout=10)  # pre-fix: stuck ~30 s, times out
        assert y.shape == (g.n_nodes, cfg.n_classes)
        assert eng._worker.is_alive()


@pytest.mark.parametrize("delay_ms", [0.0, -3.0])
def test_non_positive_delay_degrades_to_greedy_flushing(gcn_setup,
                                                        delay_ms):
    """max_delay_ms <= 0 means greedy flushing: every request resolves,
    the worker thread survives (a negative Queue.get timeout would
    raise ValueError and strand every queued future)."""
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    g = graphs[0]
    x = jnp.zeros((g.n_nodes, cfg.in_features), jnp.float32)
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=4,
                                          max_delay_ms=delay_ms)) as eng:
        futs = [eng.submit(g, x) for _ in range(6)]
        for f in futs:
            y = f.result(timeout=60)
            assert y.shape == (g.n_nodes, cfg.n_classes)
        eng.drain(timeout=60)
        rep = eng.report()
        assert rep["completed"] == rep["submitted"] == 6
        assert rep["failed"] == 0
        assert eng._worker.is_alive()
