"""Training substrate: optimizer, schedule, compression, loss descent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, lm_data_iter, make_lm_batch
from repro.train.grad_compress import (compress_int8, compress_topk_ef,
                                       init_residual, int8_roundtrip)
from repro.train.loop import TrainConfig, init_train_state, make_train_step
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, global_norm,
                                   init_opt_state, schedule)


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    s = lambda t: float(schedule(jnp.asarray(t), cfg))  # noqa: E731
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert s(50) < 1.0
    assert abs(s(100) - 0.1) < 1e-6
    assert s(100) <= s(60) <= s(20)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 10.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_adamw_moves_towards_minimum():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.5, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=1e9)
    for _ in range(200):
        grads = {"w": params["w"]}  # d/dw (w^2/2)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_int8_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    out = int8_roundtrip(g)
    scale = float(jnp.abs(g).max()) / 127.0
    assert float(jnp.abs(out - g).max()) <= scale * 0.5 + 1e-7


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.floats(0.01, 0.5))
def test_topk_error_feedback_conserves_mass(seed, k):
    """sent + residual == grad + old residual (nothing lost)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    res = init_residual(g)
    sent, new_res = compress_topk_ef(g, res, k_frac=k)
    np.testing.assert_allclose(
        np.asarray(sent["w"] + new_res["w"]), np.asarray(g["w"]),
        rtol=1e-6, atol=1e-6)
    # sparsity: at most ceil(k*64)+ties entries sent
    nz = int((np.asarray(sent["w"]) != 0).sum())
    assert nz <= 64


def test_loss_decreases_on_structured_stream(rng):
    cfg = dataclasses.replace(get_smoke_config("granite-20b"),
                              dtype="float32")
    shape = ShapeConfig("tiny", 64, 8, "train")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup_steps=5,
                                     total_steps=50))
    params = jax.jit(lambda k: __import__(
        "repro.models.transformer", fromlist=["init_lm"]).init_lm(k, cfg))(
        jax.random.PRNGKey(0))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    it = lm_data_iter(cfg, shape, DataConfig(seed=3))
    losses = []
    for i in range(30):
        params, state, m = step(params, state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_microbatching_matches_full_batch(rng):
    """Grad accumulation over microbatches == one big batch (linear loss)."""
    cfg = dataclasses.replace(get_smoke_config("nemotron-4-15b"),
                              dtype="float32")
    from repro.models.transformer import init_lm
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = make_lm_batch(cfg, 32, 8, 0, DataConfig(seed=0))
    t1 = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0, total_steps=10),
                     microbatches=1)
    t2 = dataclasses.replace(t1, microbatches=4)
    s1 = init_train_state(params, t1)
    s2 = init_train_state(params, t2)
    p1, _, m1 = jax.jit(make_train_step(cfg, t1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, t2))(params, s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree_util.tree_leaves(diff)) < 5e-3


def test_deterministic_data_pipeline():
    cfg = get_smoke_config("granite-20b")
    b1 = make_lm_batch(cfg, 32, 8, step=7, dcfg=DataConfig(seed=5))
    b2 = make_lm_batch(cfg, 32, 8, step=7, dcfg=DataConfig(seed=5))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_lm_batch(cfg, 32, 8, step=8, dcfg=DataConfig(seed=5))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
