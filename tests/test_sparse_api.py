"""Unified `repro.sparse.SparseMatrix` API: operators, pytree/jit
behavior, plan caching, and the SpMM <-> SDDMM gradient duality.

This file must stay clean under ``-W error::DeprecationWarning`` (CI
runs it that way): everything here goes through the new surface, so a
regression that routes in-repo code back through the deprecated free
functions fails loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dispatch.dispatcher import clear_log, dispatch_log, last_plan
from repro.sparse import (SparseMatrix, matmul, plan_cache_stats, sample,
                          sddmm, spmv)

SWEEP = [0.5, 0.9, 0.99]
N, D = 128, 16
BLOCK = (16, 16)

# (dispatch path, format that can execute it) — covers all four paths
PATH_FORMATS = [("ell", "ell"), ("ell", "coo"), ("csr", "csr"),
                ("sell", "sell"), ("dense", "ell"), ("dense", "csr"),
                ("dense", "sell")]


def _uniform_sparse(rng, n, sparsity):
    mask = rng.random((n, n)) < (1.0 - sparsity)
    return np.where(mask, rng.normal(size=(n, n)), 0.0).astype(np.float32)


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(7)
    out = {}
    for s in SWEEP:
        dense = _uniform_sparse(rng, N, s)
        out[s] = dense
    return out


@pytest.fixture
def h(rng):
    return jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))


# ---------------------------------------------------------------------------
# construction, conversion, operators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["ell", "sell", "coo", "csr"])
def test_roundtrip_and_matmul_every_format(operands, h, fmt):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, format=fmt, block=BLOCK)
    assert A.format == fmt and A.shape == (N, N)
    np.testing.assert_array_equal(A.to_dense(), dense)
    y = A @ h
    np.testing.assert_allclose(np.asarray(y), dense @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_auto_format_follows_measured_structure(operands):
    # moderate sparsity -> blocked form; hyper-sparsity -> sell packing
    assert SparseMatrix.from_dense(operands[0.5], block=BLOCK).format \
        == "ell"
    rng = np.random.default_rng(3)
    hyper = _uniform_sparse(rng, 256, 0.999)
    assert SparseMatrix.from_dense(hyper, block=(4, 4)).format == "sell"


def test_conversion_table(operands):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, format="ell", block=BLOCK)
    for fmt in ("ell", "sell", "coo", "csr"):
        B = A.to(fmt)
        assert B.format == fmt
        np.testing.assert_array_equal(B.to_dense(), dense)
    np.testing.assert_array_equal(np.asarray(A.to("dense")), dense)


def test_multiform_carries_both_paths(operands, h):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, formats=("ell", "csr"), block=BLOCK)
    assert A.formats == ("ell", "csr")
    ys = {p: np.asarray(matmul(A, h, policy=p))
          for p in ("ell", "csr", "dense")}
    for y in ys.values():
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


def test_transpose_and_rmatmul(operands, h):
    dense = operands[0.9]
    for fmt in ("ell", "sell", "csr", "coo"):
        A = SparseMatrix.from_dense(dense, format=fmt, block=BLOCK)
        np.testing.assert_allclose(np.asarray(A.T @ h),
                                   dense.T @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
        x = np.asarray(h).T  # [D, N]
        np.testing.assert_allclose(np.asarray(x @ A), x @ dense,
                                   rtol=2e-4, atol=2e-4)
    assert A.T.T is A  # transpose is memoized and involutive


def test_matmul_1d_and_shape_errors(operands):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, format="ell", block=BLOCK)
    v = np.ones(N, np.float32)
    np.testing.assert_allclose(np.asarray(A @ v), dense @ v,
                               rtol=2e-4, atol=2e-4)
    with pytest.raises(ValueError, match="rows but A has"):
        A @ np.ones((N - 4, D), np.float32)
    with pytest.raises(ValueError, match="not among available paths"):
        matmul(SparseMatrix.from_dense(dense, format="csr"), v,
               policy="ell")


def test_sddmm_operator(operands, rng):
    dense = operands[0.9]
    mask = (dense != 0).astype(np.float32)
    b = jnp.asarray(rng.normal(size=(N, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4, N)).astype(np.float32))
    oracle = mask * np.asarray(b @ c)
    for fmt, path in (("coo", "ell"), ("csr", "csr"), ("ell", "dense")):
        M = SparseMatrix.from_dense(mask, format=fmt, block=BLOCK)
        S = sddmm(M, b, c, policy=path)
        np.testing.assert_allclose(S.to_dense(), oracle,
                                   rtol=2e-4, atol=2e-4)
        assert last_plan("sddmm").path == path
    # weighted sampling: values multiply the product (A ⊙ (B C))
    W = SparseMatrix.from_dense(dense, format="csr")
    np.testing.assert_allclose(W.sddmm(b, c).to_dense(),
                               dense * np.asarray(b @ c),
                               rtol=2e-4, atol=2e-4)


def test_csr_indices_are_int32_end_to_end(operands):
    from repro.core.formats import CSR

    dense = operands[0.9]
    csr = CSR.from_dense(dense)
    assert csr.indptr.dtype == np.int32
    assert csr.indices.dtype == np.int32
    A = SparseMatrix.from_dense(dense, formats=("ell", "coo", "csr"),
                                block=BLOCK)
    r, c, _ = A.form("csr")
    assert r.dtype == jnp.int32 and c.dtype == jnp.int32
    assert A.form("ell").indices.dtype == jnp.int32
    assert A.form("coo").rows.dtype == jnp.int32


# ---------------------------------------------------------------------------
# pytree / jit behavior
# ---------------------------------------------------------------------------


def test_pytree_roundtrip(operands):
    A = SparseMatrix.from_dense(operands[0.9], formats=("ell", "csr"),
                                block=BLOCK)
    leaves, treedef = jax.tree_util.tree_flatten(A)
    B = jax.tree_util.tree_unflatten(treedef, leaves)
    assert B.formats == A.formats and B.shape == A.shape
    assert B.stats == A.stats
    np.testing.assert_array_equal(B.to_dense(), A.to_dense())


def test_jit_retraces_only_on_shape_or_format_change(operands, h):
    traces = []

    def f(A, H):
        traces.append(1)
        return A @ H

    jf = jax.jit(f)
    A = SparseMatrix.from_dense(operands[0.9], format="ell", block=BLOCK)
    y1 = jf(A, h)
    jf(A, h)
    assert len(traces) == 1, "same instance must not retrace per call"
    # same structure (equal stats), fresh instance: still no retrace
    A2 = SparseMatrix.from_dense(operands[0.9].copy(), format="ell",
                                 block=BLOCK)
    jf(A2, h)
    assert len(traces) == 1, "equal-structure operand must reuse the trace"
    np.testing.assert_allclose(np.asarray(y1),
                               operands[0.9] @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    # format change -> retrace
    jf(A.to("csr"), h)
    assert len(traces) == 2
    # shape change -> retrace
    rng = np.random.default_rng(5)
    small = _uniform_sparse(rng, 64, 0.9)
    jf(SparseMatrix.from_dense(small, format="ell", block=BLOCK),
       jnp.asarray(np.ones((64, D), np.float32)))
    assert len(traces) == 3


def test_jit_matmul_matches_eager(operands, h):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, formats=("ell", "csr"), block=BLOCK)
    jf = jax.jit(lambda a, hh: matmul(a, hh, policy="auto"))
    np.testing.assert_allclose(np.asarray(jf(A, h)),
                               np.asarray(matmul(A, h, policy="auto")),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# plan caching
# ---------------------------------------------------------------------------


def test_plan_cache_hits_on_repeated_calls(operands, h):
    A = SparseMatrix.from_dense(operands[0.9], format="ell", block=BLOCK)
    before = plan_cache_stats()
    A @ h
    mid = plan_cache_stats()
    assert mid["misses"] == before["misses"] + 1
    for _ in range(3):
        A @ h
    after = plan_cache_stats()
    assert after["hits"] >= mid["hits"] + 3
    assert after["misses"] == mid["misses"], "re-planned on a cached call"
    # width change is a different key -> one more planning pass
    A @ jnp.ones((N, 2 * D), jnp.float32)
    assert plan_cache_stats()["misses"] == after["misses"] + 1


def test_plan_cache_shared_through_with_data(operands, h):
    A = SparseMatrix.from_dense(operands[0.9], format="csr")
    A @ h
    stats0 = plan_cache_stats()
    A.with_data(A.data * 2.0) @ h  # same topology -> plan memo reused
    stats1 = plan_cache_stats()
    assert stats1["misses"] == stats0["misses"]
    assert stats1["hits"] == stats0["hits"] + 1


def test_plan_cache_per_instance_counters(operands, h):
    A = SparseMatrix.from_dense(operands[0.9], format="csr")
    B = SparseMatrix.from_dense(operands[0.5], format="csr")
    A @ h
    for _ in range(3):
        A @ h
    sa = A.plan_cache.stats()
    assert sa["misses"] == 1 and sa["hits"] == 3 and sa["entries"] == 1
    # another instance's traffic never moves this instance's counters
    B @ h
    assert A.plan_cache.stats() == sa
    assert B.plan_cache.stats()["misses"] == 1


# ---------------------------------------------------------------------------
# densified-form memo: weakref eviction
# ---------------------------------------------------------------------------


def test_dense_memo_entry_dies_with_values_array(operands, h):
    import gc

    from repro.sparse import matrix as matrix_mod

    A = SparseMatrix.from_dense(operands[0.9], format="csr")
    key = id(A.data)
    d1 = A.densify()
    assert key in matrix_mod._DENSE_MEMO
    assert A.densify() is d1, "second densify must hit the memo"
    del A, d1
    gc.collect()
    assert key not in matrix_mod._DENSE_MEMO, \
        "memo entry must die with its values array"


def test_dense_memo_no_growth_across_build_drop_cycles(operands, h):
    import gc

    from repro.sparse import matrix as matrix_mod

    gc.collect()
    base = len(matrix_mod._DENSE_MEMO)
    for fmt in ("csr", "ell", "coo"):
        for _ in range(4):
            A = SparseMatrix.from_dense(operands[0.9], format=fmt,
                                        block=BLOCK)
            np.testing.assert_allclose(
                np.asarray(A.densify() @ h),
                operands[0.9] @ np.asarray(h), rtol=2e-4, atol=2e-4)
            del A
            gc.collect()
    assert len(matrix_mod._DENSE_MEMO) == base, \
        "repeated from_dense/densify/drop cycles must not grow the memo"


# ---------------------------------------------------------------------------
# gradients: the kernels are each other's backward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("path,fmt", PATH_FORMATS)
def test_spmm_grads_match_dense_autodiff(operands, h, sparsity, path, fmt):
    dense = operands[sparsity]
    A = SparseMatrix.from_dense(dense, format=fmt, block=BLOCK)
    w = jnp.asarray(np.linspace(-1, 1, D, dtype=np.float32))

    def sparse_loss(vals, hh):
        return jnp.sum(jnp.tanh(matmul(A.with_data(vals), hh,
                                       policy=path)) * w)

    def dense_loss(ad, hh):
        return jnp.sum(jnp.tanh(ad @ hh) * w)

    gv, gh = jax.grad(sparse_loss, argnums=(0, 1))(A.data, h)
    g_ad, g_hd = jax.grad(dense_loss, argnums=(0, 1))(jnp.asarray(dense), h)
    # dH agrees everywhere
    np.testing.assert_allclose(np.asarray(gh), np.asarray(g_hd),
                               rtol=1e-5, atol=1e-5)
    # dA agrees at the true nonzeros (structural zeros stay zero)
    g_sparse = A.with_data(gv).to_dense()
    mask = dense != 0
    np.testing.assert_allclose(g_sparse[mask], np.asarray(g_ad)[mask],
                               rtol=1e-5, atol=1e-5)
    assert (g_sparse[~mask] == 0).all(), "gradient resurrected a zero"


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("path,fmt", [("ell", "coo"), ("csr", "csr"),
                                      ("sell", "sell"), ("dense", "coo")])
def test_sddmm_grads_match_dense_autodiff(operands, rng, sparsity, path,
                                          fmt):
    dense = operands[sparsity]
    mask = (dense != 0).astype(np.float32)
    M = SparseMatrix.from_dense(mask, format=fmt, block=BLOCK)
    b = jnp.asarray(rng.normal(size=(N, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4, N)).astype(np.float32))

    def sparse_loss(bb, cc):
        return jnp.sum(jnp.sin(sddmm(M, bb, cc, policy=path).densify()))

    def dense_loss(bb, cc):
        return jnp.sum(jnp.sin(jnp.asarray(mask) * (bb @ cc)))

    gb, gc = jax.grad(sparse_loss, argnums=(0, 1))(b, c)
    gb_d, gc_d = jax.grad(dense_loss, argnums=(0, 1))(b, c)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_d),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gc_d),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("path", ["ell", "csr", "dense"])
def test_gcn_loss_grad_matches_dense_reference(operands, rng, path):
    """Acceptance: jax.grad of a GCN loss through A @ H matches the
    dense reference to 1e-5 on every dispatch path."""
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, formats=("ell", "csr"), block=BLOCK)
    f_in, f_hid, f_out = 8, 12, 4
    w1 = jnp.asarray(rng.normal(size=(f_in, f_hid)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(size=(f_hid, f_out)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(N, f_in)).astype(np.float32))
    labels = jnp.asarray((np.arange(N) % f_out).astype(np.int32))

    def gcn_loss(params, agg):
        h = agg(x @ params[0])
        h = jax.nn.relu(h)
        logits = agg(h @ params[1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, labels[:, None], 1).mean()

    g_sparse = jax.grad(gcn_loss)(
        (w1, w2), lambda t: matmul(A, t, policy=path))
    g_dense = jax.grad(gcn_loss)(
        (w1, w2), lambda t: jnp.asarray(dense) @ t)
    for gs, gd in zip(g_sparse, g_dense):
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gd),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("path", ["ell", "sell", "csr", "dense"])
def test_spmm_backward_routes_through_sddmm_dispatcher(operands, h, path):
    """Acceptance: the SpMM backward provably runs as an SDDMM (and the
    dH half as an SpMM on Aᵀ), visible in the dispatch log."""
    fmt = {"csr": "csr", "sell": "sell"}.get(path, "ell")
    A = SparseMatrix.from_dense(operands[0.9], format=fmt, block=BLOCK)
    clear_log()
    jax.grad(lambda v, hh: jnp.sum(matmul(A.with_data(v), hh,
                                          policy=path) ** 2),
             argnums=(0, 1))(A.data, h)
    vjp = [(p.op, p.path) for p in dispatch_log() if p.policy == "vjp"]
    assert ("sddmm", path) in vjp, vjp  # dA = pattern(A) ⊙ (ḡ Hᵀ)
    assert ("spmm", path) in vjp, vjp   # dH = Aᵀ @ ḡ


def test_sddmm_backward_routes_through_spmm_dispatcher(operands, rng):
    mask = (operands[0.9] != 0).astype(np.float32)
    M = SparseMatrix.from_dense(mask, format="csr")
    b = jnp.asarray(rng.normal(size=(N, 2)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(2, N)).astype(np.float32))
    clear_log()
    jax.grad(lambda bb: jnp.sum(sample(M, bb, c, policy="csr").data ** 2))(b)
    vjp = [(p.op, p.path) for p in dispatch_log() if p.policy == "vjp"]
    assert vjp.count(("spmm", "csr")) == 2, vjp  # dB and dC


def test_jit_grad_traces_cleanly(operands, h):
    """Acceptance: jax.jit(jax.grad(...)) through the custom_vjp."""
    A = SparseMatrix.from_dense(operands[0.9], formats=("ell", "csr"),
                                block=BLOCK)

    @jax.jit
    def gstep(vals, hh):
        return jax.grad(
            lambda v, x: jnp.sum(matmul(A.with_data(v), x) ** 2),
            argnums=(0, 1))(vals, hh)

    gv, gh = gstep(A.data, h)
    assert gv.shape == A.data.shape and gh.shape == h.shape
    assert np.isfinite(np.asarray(gv)).all()
    assert np.isfinite(np.asarray(gh)).all()
    # second call reuses the trace (plan memoized; nothing re-planned)
    gv2, _ = gstep(A.data, h)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(gv2))


def test_grad_through_gat_attention(rng):
    """End-to-end: GAT's SDDMM -> softmax -> SpMM chain differentiates
    (its backward mixes both duality rules)."""
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gat_forward, init_gat

    adj = random_graph(48, avg_degree=4, seed=2, clustered=False)
    g = build_graph(adj, GCFG)
    params = init_gat(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(48, GCFG.in_features))
                    .astype(np.float32))

    def loss(p):
        return jnp.sum(gat_forward(p, g, x) ** 2)

    grads = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l)).all() for l in flat)
    assert any(float(jnp.abs(l).sum()) > 0 for l in flat)


# ---------------------------------------------------------------------------
# deprecated surfaces still work but warn
# ---------------------------------------------------------------------------


def test_legacy_spmm_warns_and_forwards(operands, h):
    from repro.core.spmm import spmm

    with pytest.warns(DeprecationWarning, match="repro.sparse"):
        y = spmm(operands[0.9], h, policy="csr")
    np.testing.assert_allclose(np.asarray(y),
                               operands[0.9] @ np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_legacy_sddmm_warns_and_forwards(operands, rng):
    from repro.core.sddmm import sddmm as legacy_sddmm

    mask = (operands[0.9] != 0).astype(np.float32)
    b = jnp.asarray(rng.normal(size=(N, 2)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(2, N)).astype(np.float32))
    with pytest.warns(DeprecationWarning, match="repro.sparse"):
        out = legacy_sddmm(mask, b, c, policy="csr")
    np.testing.assert_allclose(out.to_dense()[:N, :N],
                               mask * np.asarray(b @ c),
                               rtol=2e-4, atol=2e-4)


def test_legacy_operand_warns(operands):
    from repro.dispatch import SparseOperand

    with pytest.warns(DeprecationWarning, match="SparseMatrix"):
        SparseOperand.from_dense(operands[0.9])


def test_sell_kernel_route_grads_match_dense(operands, h):
    """The tile-pruned Pallas route (interpret mode) differentiates to
    the same gradients as dense autodiff."""
    dense = operands[0.99]
    A = SparseMatrix.from_dense(dense, format="sell", block=BLOCK)
    w = jnp.asarray(np.linspace(-1, 1, D, dtype=np.float32))

    def sparse_loss(vals, hh):
        y = matmul(A.with_data(vals), hh, policy="sell",
                   use_kernel=True, interpret=True)
        return jnp.sum(jnp.tanh(y) * w)

    def dense_loss(ad, hh):
        return jnp.sum(jnp.tanh(ad @ hh) * w)

    gv, gh = jax.grad(sparse_loss, argnums=(0, 1))(A.data, h)
    g_ad, g_hd = jax.grad(dense_loss, argnums=(0, 1))(jnp.asarray(dense), h)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(g_hd),
                               rtol=1e-5, atol=1e-5)
    mask = dense != 0
    g_sparse = A.with_data(gv).to_dense()
    np.testing.assert_allclose(g_sparse[mask], np.asarray(g_ad)[mask],
                               rtol=1e-5, atol=1e-5)
    assert (g_sparse[~mask] == 0).all()


# ---------------------------------------------------------------------------
# SpMV: the d = 1 fast lane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SWEEP)
@pytest.mark.parametrize("path,fmt", PATH_FORMATS)
def test_spmv_every_path_matches_dense(operands, sparsity, path, fmt):
    dense = operands[sparsity]
    A = SparseMatrix.from_dense(dense, format=fmt, block=BLOCK)
    v = np.linspace(-1, 1, N, dtype=np.float32)
    y = spmv(A, v, policy=path)
    assert y.shape == (N,)
    np.testing.assert_allclose(np.asarray(y), dense @ v,
                               rtol=2e-4, atol=2e-4)
    # transpose rides the same lane (auto policy: the transposed carrier
    # may expose a different path set, e.g. sell.T falls back to csr)
    np.testing.assert_allclose(np.asarray(spmv(A.T, v)),
                               dense.T @ v, rtol=2e-4, atol=2e-4)


def test_matmul_1d_delegates_to_spmv_op(operands):
    """``A @ v`` plans on the dedicated unit-width surface — the plan
    is tagged ``spmv``, not an ``spmm`` with d = 1."""
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, format="ell", block=BLOCK)
    v = np.ones(N, np.float32)
    clear_log()
    A @ v
    ops = [p.op for p in dispatch_log()]
    assert "spmv" in ops and "spmm" not in ops
    assert last_plan().op == "spmv"
    # the 2-D product still plans as spmm
    clear_log()
    A @ np.ones((N, D), np.float32)
    assert last_plan().op == "spmm"


def test_spmv_rejects_matrix_rhs_and_unavailable_path(operands):
    A = SparseMatrix.from_dense(operands[0.9], format="csr")
    with pytest.raises(ValueError, match="rows but A has"):
        spmv(A, np.ones(N - 4, np.float32))
    with pytest.raises(ValueError, match="not among available paths"):
        spmv(A, np.ones(N, np.float32), policy="ell")


@pytest.mark.parametrize("path,fmt", PATH_FORMATS)
def test_spmv_grads_match_dense_autodiff(operands, path, fmt):
    dense = operands[0.9]
    A = SparseMatrix.from_dense(dense, format=fmt, block=BLOCK)
    v = jnp.asarray(np.linspace(-1, 1, N, dtype=np.float32))
    w = jnp.asarray(np.linspace(1, 2, N, dtype=np.float32))

    def sparse_loss(vals, x):
        return jnp.sum(jnp.tanh(spmv(A.with_data(vals), x,
                                     policy=path)) * w)

    def dense_loss(ad, x):
        return jnp.sum(jnp.tanh(ad @ x) * w)

    gv, gx = jax.grad(sparse_loss, argnums=(0, 1))(A.data, v)
    g_ad, g_xd = jax.grad(dense_loss, argnums=(0, 1))(jnp.asarray(dense), v)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(g_xd),
                               rtol=1e-5, atol=1e-5)
    mask = dense != 0
    g_sparse = A.with_data(gv).to_dense()
    np.testing.assert_allclose(g_sparse[mask], np.asarray(g_ad)[mask],
                               rtol=1e-5, atol=1e-5)
    assert (g_sparse[~mask] == 0).all(), "gradient resurrected a zero"


def test_spmv_jit_matches_eager(operands):
    dense = operands[0.99]
    A = SparseMatrix.from_dense(dense, formats=("sell", "csr"),
                                block=BLOCK)
    v = jnp.asarray(np.linspace(-1, 1, N, dtype=np.float32))
    eager = spmv(A, v)
    jitted = jax.jit(lambda a, x: spmv(a, x))(A, v)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(eager), dense @ np.asarray(v),
                               rtol=2e-4, atol=2e-4)
