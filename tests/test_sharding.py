"""Sharding policy unit tests (no multi-device needed: specs only)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.inputs import abstract_cache, abstract_params
from repro.sharding import ctx as shard_ctx
from repro.sharding.specs import cache_spec, make_mesh, param_spec


@pytest.fixture
def mesh():
    # a 1x1 mesh carries the axis names without needing fake devices
    return make_mesh((1, 1), ("data", "model"))


def _spec_of(tree, keypath, mesh):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        keys = [p.key for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        if keys[-len(keypath):] == list(keypath):
            return param_spec(path, leaf, mesh), leaf
    raise KeyError(keypath)


def test_param_specs_follow_rules(mesh):
    cfg = get_config("granite-20b")
    params = abstract_params(cfg)
    spec, leaf = _spec_of(params, ["embed"], mesh)
    assert spec == P("model", "data")  # vocab 49152 % 1 == 0 trivially
    spec, leaf = _spec_of(params, ["attn", "wq"], mesh)
    # period-stacked [n_periods, d, H*hd]: leading None + rules
    assert spec == P(None, "data", "model")
    spec, leaf = _spec_of(params, ["mlp", "wo"], mesh)
    assert spec == P(None, "model", "data")
    spec, _ = _spec_of(params, ["final_ln"], mesh)
    assert spec == P(None)


def test_param_specs_drop_non_divisible_axes():
    mesh16 = make_mesh((1, 1), ("data", "model"))
    # simulate the 16x16 divisibility rule with a fake mesh via _fit
    from repro.sharding.specs import _fit

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    fm = FakeMesh()
    assert _fit(51865, ("model",), fm) is None  # whisper vocab (odd)
    assert _fit(202048, ("model",), fm) == "model"
    assert _fit(8, ("model",), fm) is None  # kv=8 heads < 16 shards
    del mesh16


def test_cache_specs(mesh):
    cfg = get_config("gemma3-4b")
    shape = SHAPES["decode_32k"]
    cache = abstract_cache(cfg, shape)
    flat, _ = jax.tree_util.tree_flatten_with_path(cache)
    seen = set()
    for path, leaf in flat:
        keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        spec = cache_spec(path, leaf, mesh, cfg, shape)
        if keys and keys[-1] in ("k", "v"):
            assert spec[-2:] == (None, None)  # heads/hd unsharded
            seen.add("kv")
        if keys and keys[-1] == "pos":
            assert spec == P()
            seen.add("pos")
    assert {"kv", "pos"} <= seen


def test_logical_dedup():
    mesh = make_mesh((1, 1), ("data", "model"))
    shard_ctx.set_mesh(mesh, {"seq": "model", "heads": "model",
                              "batch": ("data",)})
    try:
        spec = shard_ctx.logical_to_spec(("batch", "seq", "heads", None))
        assert spec == P(("data",), "model", None, None)
    finally:
        shard_ctx.clear_mesh()


def test_shard_hint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_ctx.shard_hint(x, "batch", "embed")
    assert y is x
