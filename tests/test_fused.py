"""Fused sparse pipelines: SpMM+epilogue and one-pass graph attention.

Parity contract: the fused ops must match the unfused compositions (and
the dense autodiff oracle) at 1e-5, forward and gradient, at sparsity
0.5 / 0.9 / 0.99 across the ell / sell / csr paths; the online-softmax
two-sweep must match ``_segment_softmax``; and fusion must not add jit
retraces nor E-length intermediates to the blocked path's jaxpr.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dispatch import (AutotuneCache, PATH_FUSED_ATTN, calibrate,
                            clear_log, dispatch_log, last_plan,
                            plan_fused_attention)
from repro.models.gnn import _segment_softmax
from repro.sparse import SparseMatrix, fused_graph_attention, matmul, sample

SPARSITIES = (0.5, 0.9, 0.99)
PATHS3 = ("ell", "sell", "csr")


def _rand_adj(rng, n, sparsity):
    dense = np.where(rng.random((n, n)) < (1.0 - sparsity),
                     rng.normal(size=(n, n)), 0.0).astype(np.float32)
    # keep at least one edge so segment softmax has work to do
    if not dense.any():
        dense[0, 1] = 1.0
    return dense


def _matrix(dense, block=(16, 16)):
    return SparseMatrix.from_dense(dense, formats=("ell", "sell", "csr"),
                                   block=block)


def _attn_inputs(rng, n, d=8):
    q = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return q, k, v


def _dense_attention(dense, q, k, v, slope=0.2):
    """Dense oracle of the whole pipeline (jnp, fully differentiable)."""
    s = q @ k.T
    mask = jnp.asarray(dense != 0)
    e = jnp.where(s >= 0, s, slope * s)
    e = jnp.where(mask, e, -1e30)
    mx = e.max(axis=1, keepdims=True)
    p = jnp.where(mask, jnp.exp(e - mx), 0.0)
    den = jnp.maximum(p.sum(axis=1, keepdims=True), 1e-12)
    return (p / den) @ v


# ---------------------------------------------------------------------------
# SpMM + epilogue
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("path", PATHS3)
def test_epilogue_matmul_matches_unfused(rng, path, sparsity):
    n, d = 64, 8
    dense = _rand_adj(rng, n, sparsity)
    a = _matrix(dense)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    fused = matmul(a, h, policy=path, epilogue="relu", bias=b, residual=r)
    unfused = jax.nn.relu(matmul(a, h, policy=path) + b + r)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)
    oracle = jax.nn.relu(jnp.asarray(dense) @ h + b + r)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("path", PATHS3)
def test_epilogue_grads_match_dense_autodiff(rng, path, sparsity):
    n, d = 48, 8
    dense = _rand_adj(rng, n, sparsity)
    a = _matrix(dense)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    def fused(h, b, r):
        y = matmul(a, h, policy=path, epilogue="relu", bias=b, residual=r)
        return (y * w).sum()

    def oracle(h, b, r):
        return (jax.nn.relu(jnp.asarray(dense) @ h + b + r) * w).sum()

    gf = jax.grad(fused, argnums=(0, 1, 2))(h, b, r)
    go = jax.grad(oracle, argnums=(0, 1, 2))(h, b, r)
    for name, x, y in zip(("dh", "dbias", "dresidual"), gf, go):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("path", ("ell", "sell"))
def test_epilogue_kernel_interpret_parity(rng, path):
    """The in-register epilogue kernels == reference composition."""
    n, d = 64, 16
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    kernel = matmul(a, h, policy=path, epilogue="leaky_relu", bias=b,
                    residual=r, interpret=True)
    ref = matmul(a, h, policy=path, epilogue="leaky_relu", bias=b,
                 residual=r, use_kernel=False)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_epilogue_sell_kernel_restores_pruned_rows(rng):
    """Rows with no nonzeros still owe act(bias + residual): the sell
    kernel never computes them, the epilogue gather re-inserts them."""
    n, d = 64, 16
    dense = np.zeros((n, n), np.float32)
    dense[: n // 4] = _rand_adj(rng, n, 0.5)[: n // 4]  # 3/4 rows empty
    a = SparseMatrix.from_dense(dense, formats=("sell",), block=(8, 8))
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    out = matmul(a, h, policy="sell", epilogue="relu", bias=b, residual=r,
                 interpret=True)
    oracle = jax.nn.relu(jnp.asarray(dense) @ h + b + r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_epilogue_scalar_and_python_bias(rng):
    """Scalar / raw-Python bias is canonicalized to [D]: works on the
    kernel routes and is differentiable (regression: reshape crash)."""
    n, d = 32, 8
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense, block=(8, 8))
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    oracle = jax.nn.relu(jnp.asarray(dense) @ h + 0.5)
    for path in ("ell", "sell"):
        out = matmul(a, h, policy=path, epilogue="relu", bias=0.5,
                     interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=1e-4, atol=1e-4)
    g = jax.grad(lambda b: matmul(a, h, policy="csr", epilogue="relu",
                                  bias=b).sum())(jnp.float32(0.5))
    assert np.shape(np.asarray(g)) == ()
    with pytest.raises(ValueError, match="bias"):
        matmul(a, h, policy="csr", epilogue="relu",
               bias=jnp.zeros((1, d)))
    with pytest.raises(ValueError, match="residual"):
        matmul(a, h, policy="csr", epilogue="relu",
               residual=jnp.zeros((n + 1, d)))


def test_epilogue_plan_recorded_as_fused(rng):
    dense = _rand_adj(rng, 32, 0.9)
    a = _matrix(dense)
    h = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
    clear_log()
    matmul(a, h, policy="csr", epilogue="relu", bias=b)
    plan = last_plan("spmm")
    assert plan.fused == "relu+bias"


# ---------------------------------------------------------------------------
# Fused graph attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("path", PATHS3)
def test_fused_attention_matches_unfused_composition(rng, path, sparsity):
    n = 64
    dense = _rand_adj(rng, n, sparsity)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n)

    fused = fused_graph_attention(a, q, k, v, policy=path)

    patt = a.to("csr").pattern()
    row_ids = patt.form("csr")[0]
    e = sample(patt, q, k.T, policy="csr").data
    e = jax.nn.leaky_relu(e, 0.2)
    alpha = _segment_softmax(e, row_ids, n)
    unfused = matmul(patt.with_data(alpha), v, policy="csr")

    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sparsity", SPARSITIES)
@pytest.mark.parametrize("path", PATHS3)
def test_fused_attention_grads_match_dense_autodiff(rng, path, sparsity):
    n = 48
    dense = _rand_adj(rng, n, sparsity)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n)
    w = jnp.asarray(rng.normal(size=(n, v.shape[1])).astype(np.float32))

    def fused(q, k, v):
        return (fused_graph_attention(a, q, k, v, policy=path) * w).sum()

    def oracle(q, k, v):
        return (_dense_attention(dense, q, k, v) * w).sum()

    gf = jax.grad(fused, argnums=(0, 1, 2))(q, k, v)
    go = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    for name, x, y in zip(("dq", "dk", "dv"), gf, go):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.parametrize("path", ("ell", "sell"))
def test_fused_attention_kernel_interpret_parity(rng, path):
    """Flash-statistics kernels == two-sweep jnp references."""
    n = 64
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n, d=16)
    kernel = fused_graph_attention(a, q, k, v, policy=path, interpret=True)
    ref = fused_graph_attention(a, q, k, v, policy=path, use_kernel=False)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_attention_empty_rows_are_zero(rng):
    """Edge-less rows aggregate nothing (matching segment-softmax/SpMM)."""
    n = 32
    dense = _rand_adj(rng, n, 0.8)
    dense[5] = 0.0
    dense[17] = 0.0
    a = _matrix(dense, block=(8, 8))
    q, k, v = _attn_inputs(rng, n)
    for path in PATHS3 + ("dense",):
        out = np.asarray(fused_graph_attention(a, q, k, v, policy=path))
        np.testing.assert_allclose(out[5], 0.0, atol=1e-6)
        np.testing.assert_allclose(out[17], 0.0, atol=1e-6)


def test_online_softmax_two_sweep_matches_segment_softmax(rng):
    """The blocked two-sweep (what the kernels stream) == the E-length
    segment softmax the unfused path runs, via identical-score inputs."""
    from repro.kernels.fused.attention import fused_attn_blockell_ref

    n = 64
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n)
    # identity edge-act isolates the softmax algebra itself
    blocked = fused_attn_blockell_ref(a.form("ell"), q, k.T, v,
                                      act="identity")[:n]
    patt = a.to("csr").pattern()
    row_ids, col_ids, _ = patt.form("csr")
    scores = (q @ k.T)[row_ids, col_ids]
    alpha = _segment_softmax(scores, row_ids, n)
    seg = matmul(patt.with_data(alpha), v, policy="csr")
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(seg),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Dispatch integration
# ---------------------------------------------------------------------------


def test_fused_attention_single_plan_in_dispatch_log(rng):
    n = 48
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n)
    clear_log()
    fused_graph_attention(a, q, k, v, policy="auto")
    plans = dispatch_log()
    assert len(plans) == 1, [p.describe() for p in plans]
    assert plans[0].op == PATH_FUSED_ATTN
    assert plans[0].fused == "attn"
    assert plans[0].path in ("ell", "sell", "csr", "dense")


def test_plan_fused_attention_prices_one_stream(rng):
    """The fused cost entry: each path priced at one topology stream of
    combined width k + d (vs three separate streams unfused)."""
    dense = _rand_adj(rng, 64, 0.9)
    a = _matrix(dense)
    plan = plan_fused_attention(a.stats, 2, 16, policy="auto")
    assert plan.op == PATH_FUSED_ATTN and plan.fused == "attn"
    # one-stream pricing at combined width == spmm costs at k + d
    from repro.dispatch import DEFAULT_COST_MODEL

    spmm_costs = DEFAULT_COST_MODEL.spmm_costs(a.stats, 2 + 16)
    for p, c in plan.costs.items():
        assert c == pytest.approx(spmm_costs[p])


def test_fused_attention_vjp_duality_in_dispatch_log(rng):
    """Backward reuses the SpMM/SDDMM duality — visible in the log."""
    n = 32
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    q, k, v = _attn_inputs(rng, n)
    clear_log()
    jax.grad(lambda v: fused_graph_attention(a, q, k, v,
                                             policy="csr").sum())(v)
    vjp = [(p.op, p.policy) for p in dispatch_log() if p.policy == "vjp"]
    ops = [op for op, _ in vjp]
    assert ops.count("sddmm") == 2, vjp  # score recompute + dα
    assert ops.count("spmm") == 3, vjp   # dq, dk, dV


def test_fusion_adds_no_retraces(rng):
    """Trace-count pin: the fused layer retraces once, then replays."""
    n = 48
    dense = _rand_adj(rng, n, 0.9)
    a = _matrix(dense)
    traces = []

    @jax.jit
    def layer(q, k, v, h, b):
        traces.append(1)
        y = fused_graph_attention(a, q, k, v, policy="ell")
        return matmul(a, y + 0 * h, policy="ell", epilogue="relu", bias=b)

    q, k, v = _attn_inputs(rng, n)
    b = jnp.asarray(rng.normal(size=(v.shape[1],)).astype(np.float32))
    layer(q, k, v, v, b)
    layer(q + 1, k + 1, v + 1, v, b)
    layer(q * 2, k, v, v, b)
    assert len(traces) == 1, "fused pipeline must not retrace per call"


def test_gat_forward_fused_one_dispatch_per_layer(rng):
    """gat_forward(fuse=True): exactly one plan per layer, and the
    blocked path's jaxpr carries no E-length intermediate."""
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gat_forward, init_gat

    adj = random_graph(48, avg_degree=4, seed=1, clustered=False)
    graph = build_graph(adj, GCFG)
    params = init_gat(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))

    clear_log()
    out = gat_forward(params, graph, x, policy="ell", fuse=True)
    assert out.shape == (graph.n_nodes, GCFG.n_classes)
    assert np.isfinite(np.asarray(out)).all()
    plans = dispatch_log()
    assert len(plans) == GCFG.n_layers, [p.describe() for p in plans]
    assert all(p.op == PATH_FUSED_ATTN for p in plans)

    # no E-length (edge-count) array anywhere in the traced program
    from benchmarks.bench_fused import count_length_intermediates

    nnz = graph.adj.stats.nnz
    jaxpr = jax.make_jaxpr(
        lambda x: gat_forward(params, graph, x, policy="ell", fuse=True))(x)
    assert count_length_intermediates(jaxpr, nnz) == 0


@pytest.mark.parametrize("sparsity", (0.9, 0.99))
def test_gat_forward_fused_matches_unfused(rng, sparsity):
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.models.gnn import Graph, gat_forward, init_gat

    n = 48
    dense = _rand_adj(rng, n, sparsity)
    graph = Graph(adj=_matrix(np.abs(dense)), n_nodes=n)
    params = init_gat(jax.random.PRNGKey(1), GCFG)
    x = jnp.asarray(rng.normal(size=(n, GCFG.in_features))
                    .astype(np.float32))
    fused = gat_forward(params, graph, x, fuse=True)
    unfused = gat_forward(params, graph, x, fuse=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-5)


def test_gcn_forward_fused_matches_unfused_with_bias(rng):
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gcn_forward, init_gcn

    adj = random_graph(48, avg_degree=4, seed=3, clustered=False)
    graph = build_graph(adj, GCFG)
    params = init_gcn(jax.random.PRNGKey(0), GCFG, bias=True)
    params["b"] = [b + 0.1 * i for i, b in enumerate(params["b"])]
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))
    fused = gcn_forward(params, graph, x, policy="auto", fuse=True)
    unfused = gcn_forward(params, graph, x, policy="auto", fuse=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through the fused epilogue into the bias params
    def loss(p):
        return gcn_forward(p, graph, x, policy="auto", fuse=True).sum()

    g = jax.grad(loss)(params)
    assert any(float(jnp.abs(b).sum()) > 0 for b in g["b"])


def test_gat_forward_unfused_consults_dispatcher(rng):
    """The unfused oracle now routes through the cost model and logs."""
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, gat_forward, init_gat

    adj = random_graph(48, avg_degree=4, seed=1, clustered=False)
    graph = build_graph(adj, GCFG)
    params = init_gat(jax.random.PRNGKey(0), GCFG)
    x = jnp.asarray(rng.normal(size=(graph.n_nodes, GCFG.in_features))
                    .astype(np.float32))
    clear_log()
    gat_forward(params, graph, x, fuse=False)
    plans = [p for p in dispatch_log() if p.policy != "vjp"]
    # sddmm + spmm per layer, each carrying a cost-model decision
    assert len(plans) == 2 * GCFG.n_layers
    assert all(p.policy == "auto" and p.costs is not None for p in plans)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def test_gnn_serving_engine_plans_fused_gat(rng):
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gat
    from repro.serve.engine import GNNServeConfig, GNNServingEngine

    adj = random_graph(48, avg_degree=4, seed=2, clustered=False)
    graph = build_graph(adj, GCFG)
    params = init_gat(jax.random.PRNGKey(0), GCFG)
    eng = GNNServingEngine(params, graph,
                           GNNServeConfig(model="gat", fuse=True))
    x = rng.normal(size=(graph.n_nodes, GCFG.in_features)) \
        .astype(np.float32)
    out = eng.infer(x)
    assert out.shape == (graph.n_nodes, GCFG.n_classes)
    rep = eng.dispatch_report()
    assert rep["model"] == "gat" and rep["fused"] is True
    assert rep["plan_op"] == PATH_FUSED_ATTN


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


def test_calibrate_returns_positive_constants():
    cm = calibrate(n=128, d=16, densities=(0.3, 0.02), iters=1)
    assert cm.c_ell > 0 and cm.c_sell > 0 and cm.c_csr > 0
    assert cm.c_dense == 1.0


def test_autotune_cache_roundtrips_calibration(tmp_path):
    from repro.dispatch import CostModel
    from repro.dispatch.autotune import Measurement

    cache = AutotuneCache()
    cache.cost_model = CostModel(c_ell=2.5, c_csr=31.0, c_sell=7.5)
    cache.put(("spmm", 64, 64, 16, "float32", 1), Measurement(
        path="ell", timings_us={"ell": 10.0, "csr": 20.0}))
    p = tmp_path / "autotune.json"
    cache.save(str(p))
    fresh = AutotuneCache()
    fresh.load(str(p))
    assert fresh.cost_model == cache.cost_model
    hit = fresh.get(("spmm", 64, 64, 16, "float32", 1))
    assert hit is not None and hit.path == "ell"


def test_autotune_cache_loads_legacy_payload(tmp_path):
    """Pre-calibration caches were a bare entry list; still loadable."""
    import json

    p = tmp_path / "legacy.json"
    p.write_text(json.dumps([
        {"key": ["spmm", 8, 8, 4, "float32", 0], "path": "csr",
         "timings_us": {"csr": 5.0}},
    ]))
    cache = AutotuneCache()
    cache.load(str(p))
    assert cache.cost_model is None
    assert cache.get(("spmm", 8, 8, 4, "float32", 0)).path == "csr"


@pytest.mark.slow
def test_fused_kernels_mxu_shaped_parity(rng):
    """Interpret-mode parity at MXU-shaped sizes (nightly kernel job)."""
    n, d = 512, 256
    dense = _rand_adj(rng, n, 0.98)
    a = SparseMatrix.from_dense(dense, formats=("ell", "sell", "csr"),
                                block=(64, 64))
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    oracle = jax.nn.relu(jnp.asarray(dense) @ h + b + r)
    for path in ("ell", "sell"):
        out = matmul(a, h, policy=path, epilogue="relu", bias=b,
                     residual=r, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=5e-4, atol=5e-4)
    q, k, v = _attn_inputs(rng, n, d=128)
    att_oracle = _dense_attention(dense, q, k, v)
    for path in ("ell", "sell"):
        out = fused_graph_attention(a, q, k, v, policy=path,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(att_oracle),
                                   rtol=5e-4, atol=5e-4)


def test_epilogue_spec_is_hashable_plan_key():
    from repro.kernels.fused import Epilogue, normalize_epilogue

    e1 = normalize_epilogue("relu", jnp.zeros((4,)), None)
    e2 = normalize_epilogue("relu", jnp.ones((4,)), None)
    assert e1 == e2 and hash(e1) == hash(e2)  # arrays stay out of the key
    assert e1.has_bias and not e1.has_residual
    assert isinstance(e1, Epilogue)
    with pytest.raises(ValueError):
        Epilogue(act="tanh")
