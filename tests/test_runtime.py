"""Adaptive serving runtime: the online bucket ladder and the
continuous batching engine.

Covers the acceptance contract of the runtime subsystem:
  * the ladder serves the fixed geometric grid until it has enough
    observations, then parks rungs on the observed shapes (no geometric
    inflation for hot sizes), refits on drift, and snaps stable rungs
    so warm executors carry over;
  * the continuous engine returns per-request results identical to the
    dense forward, compiles exactly one executor per lane (occupancy is
    data, never shape), recycles freed slots, runs multi-step requests,
    and resolves every future on close();
  * the per-bucket waste ledger sums back to the aggregate;
  * ``BatchServeConfig(adaptive=True)`` routes the micro-batching
    engine through the ladder, and ``close()`` drains in-flight work
    instead of stranding futures.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.batch.bucketing import DEFAULT_BUCKETING, bucket_for
from repro.dispatch.stats import MatrixStats
from repro.serve.runtime import (AdaptiveBucketLadder, ContinuousBatchEngine,
                                 ContinuousConfig, LadderConfig)
from repro.sparse import SparseMatrix

BLOCK = (16, 16)
D = 8


def _stats(n: int, nnz: int, width: int = 4) -> MatrixStats:
    rng = np.random.default_rng(nnz)
    r = rng.integers(0, n, size=nnz)
    c = rng.integers(0, n, size=nnz)
    s = MatrixStats.from_coords((n, n), r, c, *BLOCK)
    return s


def _graph(rng, n: int, sparsity: float = 0.9):
    dense = np.where(rng.random((n, n)) < (1.0 - sparsity),
                     rng.normal(size=(n, n)), 0.0).astype(np.float32)
    if not dense.any():
        dense[0, 0] = 1.0
    return dense, SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                          block=BLOCK)


# ---------------------------------------------------------------------------
# AdaptiveBucketLadder
# ---------------------------------------------------------------------------


def test_ladder_prefit_serves_geometric_fallback():
    lad = AdaptiveBucketLadder(LadderConfig(min_fit=16))
    s = _stats(100, 400)
    assert not lad.fitted
    assert lad.bucket_for(s) == bucket_for(s, DEFAULT_BUCKETING)
    assert lad.report()["fallbacks"] == 1


def test_ladder_parks_rungs_on_hot_shapes():
    lad = AdaptiveBucketLadder(LadderConfig(min_fit=8, n_rungs=4))
    hot = _stats(100, 400)
    for _ in range(12):
        lad.observe(hot)
    assert lad.fitted
    b = lad.bucket_for(hot)
    # the learned rung sits on the observed size (block-rounded), not a
    # geometric growth step above it
    assert b.rows == 112  # 100 rounded up to the 16-block
    assert b.rows <= bucket_for(hot, DEFAULT_BUCKETING).rows
    assert b.nnz >= hot.nnz
    rungs = lad.rungs()
    assert all(len(rungs[d]) >= 1 for d in ("rows", "nnz", "width"))


def test_ladder_never_truncates_above_top_rung():
    lad = AdaptiveBucketLadder(LadderConfig(min_fit=8))
    for _ in range(10):
        lad.observe(_stats(64, 200))
    big = _stats(500, 3000)
    b = lad.bucket_for(big)
    assert b.rows >= 500 and b.nnz >= big.nnz
    assert b.rows % BLOCK[0] == 0


def test_ladder_refits_on_drift_and_snaps_stable_rungs():
    cfg = LadderConfig(min_fit=8, refit_interval=8, window=64,
                       drift_threshold=0.1)
    lad = AdaptiveBucketLadder(cfg)
    for _ in range(16):
        lad.observe(_stats(64, 200))
    fits0 = lad.refits
    assert fits0 >= 1
    # same distribution: drift stays under threshold, no refit
    for _ in range(16):
        lad.observe(_stats(64, 200))
    assert lad.refits == fits0
    # drifted distribution: the ladder must refit within a window
    for _ in range(64):
        lad.observe(_stats(512, 4000))
    rep = lad.report()
    assert rep["refits"] > fits0
    assert rep["drift_checks"] >= 1
    b = lad.bucket_for(_stats(512, 4000))
    assert b.rows == 512
    # a refit over an unchanged window lands on the same quantiles, and
    # every rung snaps back — warm executors survive the refit
    before = rep["snapped_rungs"]
    lad.refit()
    assert lad.report()["snapped_rungs"] > before


def test_ladder_forced_refit():
    lad = AdaptiveBucketLadder(LadderConfig(min_fit=1024))
    lad.observe(_stats(96, 300))
    assert not lad.fitted
    lad.refit()
    assert lad.fitted


# ---------------------------------------------------------------------------
# ContinuousBatchEngine
# ---------------------------------------------------------------------------


def _cfg(**kw) -> ContinuousConfig:
    kw.setdefault("slots", 4)
    kw.setdefault("adaptive", False)
    kw.setdefault("max_wait_ms", 0.0)  # tests step deterministically
    return ContinuousConfig(**kw)


def test_continuous_parity_and_trace_pin(rng):
    with ContinuousBatchEngine(cfg=_cfg()) as eng:
        futs, refs = [], []
        for n in (48, 48, 80, 48, 80, 48, 80, 48):
            dense, mat = _graph(rng, n)
            h = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
            futs.append(eng.submit(mat, h))
            refs.append(dense @ np.asarray(h))
        eng.drain()
        for f, ref in zip(futs, refs):
            np.testing.assert_allclose(f.result(), ref,
                                       rtol=2e-4, atol=2e-4)
        rep = eng.report()
        # occupancy is data, not shape: exactly one compile per lane
        assert rep["executor"]["compiles"] == len(rep["lanes"])
        assert rep["completed"] == 8 and rep["failed"] == 0


def test_continuous_slot_recycling_and_occupancy(rng):
    with ContinuousBatchEngine(cfg=_cfg(slots=2)) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        futs = [eng.submit(mat, h) for _ in range(7)]
        lane = next(iter(eng._lanes.values()))
        assert lane.occupancy == 2 and len(lane.queue) == 5
        # each step completes the seated pair and recycles queued work
        assert eng.step(force=True) == 2
        assert lane.occupancy == 2 and len(lane.queue) == 3
        eng.drain()
        assert all(f.done() for f in futs)
        rep = eng.report()
        (lane_rep,) = rep["lanes"].values()
        assert lane_rep["steps"] == 4            # ceil(7 / 2)
        assert lane_rep["occupancy"] == pytest.approx(7 / 8)


def test_continuous_multistep_propagation(rng):
    with ContinuousBatchEngine(cfg=_cfg()) as eng:
        dense, mat = _graph(rng, 48)
        h = rng.normal(size=(48, D)).astype(np.float32)
        y = eng.infer(mat, jnp.asarray(h), steps=3)
        ref = dense @ (dense @ (dense @ h))
        np.testing.assert_allclose(y, ref, rtol=5e-4, atol=5e-4)


def test_continuous_batching_window_holds_partial_lanes(rng):
    # under max_wait_ms a partially-filled lane is not ready; force runs it
    with ContinuousBatchEngine(cfg=_cfg(max_wait_ms=60_000.0)) as eng:
        _, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        fut = eng.submit(mat, h)
        assert eng.step() == 0
        assert eng.step(force=True) == 1
        assert fut.done()


def test_continuous_close_resolves_everything(rng):
    eng = ContinuousBatchEngine(cfg=_cfg())
    dense, mat = _graph(rng, 48)
    h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
    futs = [eng.submit(mat, h) for _ in range(6)]
    eng.close()
    # close drains: every admitted future resolves with its result
    for f in futs:
        np.testing.assert_allclose(f.result(timeout=1.0),
                                   dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
    with pytest.raises(RuntimeError):
        eng.submit(mat, h)


def test_continuous_rejects_stat_less_and_mismatched(rng):
    with ContinuousBatchEngine(cfg=_cfg()) as eng:
        _, mat = _graph(rng, 48)
        with pytest.raises(ValueError):
            eng.submit(mat, jnp.zeros((40, D), jnp.float32))
        with pytest.raises(ValueError):
            eng.submit(mat, jnp.zeros((48, D), jnp.float32), steps=0)


def test_continuous_adaptive_ladder_feeds_executor(rng):
    cfg = _cfg(adaptive=True,
               ladder=LadderConfig(min_fit=4, n_rungs=4))
    with ContinuousBatchEngine(cfg=cfg) as eng:
        _, mat = _graph(rng, 100)
        h = jnp.asarray(rng.normal(size=(100, D)).astype(np.float32))
        for _ in range(6):
            eng.infer(mat, h)
        rep = eng.report()["executor"]
        assert rep["ladder"]["fitted"]
        # post-fit traffic lands on a learned rung, not a geometric step
        assert any(k.startswith("r112x") for k in rep["padding"]
                   .get("per_bucket", {}))


def test_per_bucket_waste_sums_to_aggregate(rng):
    with ContinuousBatchEngine(cfg=_cfg()) as eng:
        for n in (48, 80, 48, 130):
            _, mat = _graph(rng, n)
            h = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
            eng.submit(mat, h)
        eng.drain()
        padding = eng.report()["executor"]["padding"]
        per = padding["per_bucket"]
        assert len(per) >= 2
        for field in ("real_rows", "padded_rows", "real_nnz", "padded_nnz"):
            assert sum(v[field] for v in per.values()) == padding[field]


# ---------------------------------------------------------------------------
# BatchServingEngine integration (adaptive opt-in + close regression)
# ---------------------------------------------------------------------------


def test_micro_engine_adaptive_opt_in(rng):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    scfg = BatchServeConfig(max_batch=8, max_delay_ms=2.0, adaptive=True,
                            ladder=LadderConfig(min_fit=4, n_rungs=4))
    with BatchServingEngine(scfg=scfg) as eng:
        dense, mat = _graph(rng, 100)
        h = jnp.asarray(rng.normal(size=(100, D)).astype(np.float32))
        futs = [eng.submit(mat, h) for _ in range(12)]
        eng.drain()
        for f in futs:
            np.testing.assert_allclose(f.result(), dense @ np.asarray(h),
                                       rtol=2e-4, atol=2e-4)
        assert eng.report()["executor"]["ladder"]["fitted"]


def test_micro_engine_close_drains_inflight(rng):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    scfg = BatchServeConfig(max_batch=4, max_delay_ms=1.0)
    eng = BatchServingEngine(scfg=scfg)
    dense, mat = _graph(rng, 48)
    h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
    futs = [eng.submit(mat, h) for _ in range(10)]
    eng.close()  # must drain, not strand
    for f in futs:
        assert f.done()
        np.testing.assert_allclose(f.result(timeout=1.0),
                                   dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
    with pytest.raises(RuntimeError):
        eng.submit(mat, h)
