"""Elastic scaling end-to-end: checkpoint on mesh A, resume on mesh B.

The scenario a 1000-node deployment hits when a pod is lost: training
state saved under one mesh must restore onto a different mesh and produce
the same training trajectory (checkpoints are mesh-independent because
leaves are gathered on save — ft/checkpoint.py).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig, make_lm_batch
    from repro.ft.checkpoint import Checkpointer
    from repro.models.transformer import init_lm
    from repro.sharding import ctx as shard_ctx
    from repro.sharding.specs import param_sharding_tree, data_sharding_tree
    from repro.train.loop import TrainConfig, init_train_state, \\
        make_train_step
    from repro.train.optimizer import OptConfig

    cfg = dataclasses.replace(get_smoke_config("granite-20b"),
                              dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                     total_steps=20))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)

    def run_steps(params, state, mesh, start, n):
        # fresh step fn per mesh: jit caches the traced jaxpr per function
        # object, and the jaxpr bakes in shard_hint's mesh constraints
        step = make_train_step(cfg, tcfg)
        p_sh = param_sharding_tree(params, mesh)
        s_sh = param_sharding_tree(state, mesh)
        params = jax.device_put(params, p_sh)
        state = jax.device_put(state, s_sh)
        shard_ctx.set_mesh(mesh)
        fn = jax.jit(step, in_shardings=(p_sh, s_sh, None),
                     out_shardings=(p_sh, s_sh, None))
        for i in range(n):
            batch = make_lm_batch(cfg, 32, 8, start + i, DataConfig(seed=4))
            params, state, m = fn(params, state, batch)
        shard_ctx.clear_mesh()
        return params, state, float(m["loss"])

    from repro.sharding.specs import make_mesh
    mesh_a = make_mesh((4, 2), ("data", "model"))
    # "lost half the fleet": 2x2 mesh
    mesh_b = make_mesh((2, 2), ("data", "model"),
                       devices=jax.devices()[:4])

    # reference: 6 steps all on mesh A
    p_ref, s_ref, loss_ref = run_steps(params, state, mesh_a, 0, 6)

    # elastic: 3 steps on A -> checkpoint -> restore on B -> 3 more steps
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        p1, s1, _ = run_steps(params, state, mesh_a, 0, 3)
        ck.save(3, {"params": p1, "state": s1},
                meta={"mesh": "4x2"})
        restored = ck.restore({"params": params, "state": state})
        p2, s2, loss_b = run_steps(restored["params"], restored["state"],
                                   mesh_b, 3, 3)

    import jax.tree_util as jtu
    diff = jtu.tree_map(
        lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))),
        p_ref, p2)  # host-side compare: the two live on different meshes
    worst = max(jtu.tree_leaves(diff))
    assert worst < 1e-4, worst
    assert abs(loss_ref - loss_b) < 1e-4
    print("elastic rescale OK", worst)
""")


@pytest.mark.slow
def test_elastic_rescale_preserves_trajectory():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "elastic rescale OK" in out.stdout
