"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment deliverable (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models.transformer import forward_hidden, init_lm, lm_loss
from repro.train.loop import TrainConfig, init_train_state, make_train_step
from repro.train.optimizer import OptConfig

LM_ARCHS = [a for a in ARCHS if a != "paper-gnn"]


def _batch(rng, cfg, b=2, s=32):
    n_text = s - cfg.vision_tokens
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, n_text)), jnp.int32),
        "targets": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, n_text)), jnp.int32),
        "mask": jnp.ones((b, n_text), jnp.float32),
    }
    if cfg.vision_tokens:
        out["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.encoder_layers:
        out["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finiteness(rng, arch):
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(rng, cfg)
    hidden, _, aux = forward_hidden(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        enc_embeds=batch.get("enc_embeds"), mode="train", remat=False)
    b, s = batch["tokens"].shape
    assert hidden.shape == (b, s + cfg.vision_tokens, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_one_train_step(rng, arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(rng, cfg)
    new_params, new_state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_param_count_matches_init(arch):
    """The analytic card param count must equal the initialized count."""
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert actual == cfg.param_count(), (arch, actual, cfg.param_count())


def test_sparse_weight_inference_matches_dense_reference(rng):
    """Magnitude-pruned MLP weights carried as ``SparseMatrix`` run the
    whole inference surface — forward, prefill, decode — and match the
    same pruned weights densified back (the dense oracle)."""
    from repro.models.pruning import (dense_reference, sparsify_lm,
                                      weight_sparsity_report)
    from repro.models.transformer import decode_step, prefill

    cfg = dataclasses.replace(get_smoke_config("nemotron-4-15b"),
                              dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    sp = sparsify_lm(params, cfg, sparsity=0.8, prune_block=(4, 4),
                     formats=("ell", "csr"), block=(8, 8))
    rep = weight_sparsity_report(sp)
    assert rep["n_sparse"] >= 1
    assert 0.75 <= rep["sparsity"] <= 0.85  # realized ~ requested
    dense = dense_reference(sp)

    batch = _batch(rng, cfg)
    hs, _, _ = forward_hidden(sp, cfg, batch["tokens"], mode="train",
                              remat=False)
    hd, _, _ = forward_hidden(dense, cfg, batch["tokens"], mode="train",
                              remat=False)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hd),
                               rtol=2e-3, atol=2e-3)

    B, S, EXTRA = 2, 16, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + EXTRA)),
                       jnp.int32)
    ls, cs = prefill(sp, cfg, toks[:, :S], max_len=S + EXTRA)
    ld, cd = prefill(dense, cfg, toks[:, :S], max_len=S + EXTRA)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(ld),
                               rtol=3e-3, atol=3e-3)
    for t in range(EXTRA):
        tok = toks[:, S + t:S + t + 1]
        ls, cs = decode_step(sp, cfg, tok, cs)
        ld, cd = decode_step(dense, cfg, tok, cd)
        np.testing.assert_allclose(np.asarray(ls), np.asarray(ld),
                                   rtol=3e-3, atol=3e-3)


def test_magnitude_prune_keeps_largest_tiles():
    from repro.models.pruning import magnitude_prune

    w = np.arange(1, 65, dtype=np.float32).reshape(8, 8)
    p = np.asarray(magnitude_prune(jnp.asarray(w), 0.75, block=(4, 4)))
    # exactly one of four 4x4 tiles survives: the largest-norm one
    assert np.count_nonzero(p) == 16
    np.testing.assert_array_equal(p[4:, 4:], w[4:, 4:])
    assert (p[:4, :] == 0).all() and (p[4:, :4] == 0).all()
    with pytest.raises(ValueError):
        magnitude_prune(jnp.asarray(w), 1.0)
    with pytest.raises(ValueError):
        magnitude_prune(jnp.asarray(w), 0.5, block=(3, 3))
