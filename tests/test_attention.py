"""Attention variants vs the dense masked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.core.attention import (decode_attention, decode_attention_partial,
                                  flash_attention, local_block_attention,
                                  merge_partials, mha_reference)


def _qkv(rng, b=2, s=256, hq=8, hkv=2, d=32):
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [64, 128])
def test_flash_matches_reference(rng, causal, chunk):
    q, k, v = _qkv(rng)
    ref = mha_reference(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, q_chunk=chunk,
                          kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_unrolled_with_causal_skip(rng):
    """Cost-mode unrolled flash (static causal skip) is numerically exact."""
    q, k, v = _qkv(rng)
    ref = mha_reference(q, k, v, causal=True)
    with runtime.cost_mode(causal_skip=True):
        out = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # and it is differentiable (static slices only)
    with runtime.cost_mode(causal_skip=True):
        g = jax.grad(lambda q: flash_attention(
            q, k, v, causal=True, q_chunk=64, kv_chunk=64).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("window,block", [(64, 32), (128, 64), (64, 64)])
def test_local_block_attention(rng, window, block):
    q, k, v = _qkv(rng)
    ref = mha_reference(q, k, v, causal=True, window=window)
    out = local_block_attention(q, k, v, window=window, block=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_last_position(rng):
    q, k, v = _qkv(rng)
    ref = mha_reference(q, k, v, causal=True)
    dec = decode_attention(q[:, -1:], k, v, length=q.shape[1])
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(ref[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_window(rng):
    q, k, v = _qkv(rng)
    ref = mha_reference(q, k, v, causal=True, window=64)
    dec = decode_attention(q[:, -1:], k, v, length=q.shape[1], window=64)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(ref[:, -1]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_flash_decode_partial_merge(rng, n_shards):
    """Sequence-parallel decode: per-shard partials merge exactly."""
    q, k, v = _qkv(rng)
    b, s = q.shape[0], q.shape[1]
    full = decode_attention(q[:, -1:], k, v, length=s)
    per = s // n_shards
    parts = []
    for i in range(n_shards):
        sl = slice(i * per, (i + 1) * per)
        mask = jnp.ones((b, per), bool)
        parts.append(decode_attention_partial(
            q[:, -1:], k[:, sl], v[:, sl], mask))
    acc = parts[0]
    for p in parts[1:]:
        acc = merge_partials(acc, p)
    n, l, _ = acc
    out = (n / l[..., None]).reshape(full.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_merge_partials_associative(rng):
    """Property: merge is associative (required for psum-tree folding)."""
    q, k, v = _qkv(rng, s=96)
    b = q.shape[0]
    ps = []
    for i in range(3):
        sl = slice(i * 32, (i + 1) * 32)
        ps.append(decode_attention_partial(
            q[:, -1:], k[:, sl], v[:, sl], jnp.ones((b, 32), bool)))
    left = merge_partials(merge_partials(ps[0], ps[1]), ps[2])
    right = merge_partials(ps[0], merge_partials(ps[1], ps[2]))
    for a, bb in zip(left, right):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-5, atol=1e-5)
