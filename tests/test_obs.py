"""Unified observability layer: ``repro.obs`` and its integrations.

Pins the contracts the obs layer exports to the rest of the repo:

  * the metrics registry (counters/gauges/histograms with label sets,
    snapshot schema, Prometheus/JSON-lines exporters, thread safety);
  * span tracing (parent propagation, summary, bounded ring);
  * the retrace sentry (warmup budget, unexpected-retrace flagging,
    eviction forgiveness) — including an **injected shape-drift
    retrace** through a real jitted executor, and zero unexpected
    retraces across a steady-state continuous-batching run;
  * the cost-model audit (stats buckets, predicted-vs-measured rows,
    misprediction detection);
  * the thread-safe bounded dispatch ring log;
  * the deprecation shim for renamed report keys, and the **schema
    pins** for every ``report()`` and for ``obs.snapshot()`` — these
    are the keys dashboards consume; renaming one is a breaking change
    that must show up here;
  * the one-snapshot acceptance contract: a single adaptive serving
    run surfaces dispatcher plan counts, per-lane compiles/calls,
    padding waste, latency histograms, and audit rows.
"""
import json
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.batch.executor import BucketedExecutor, ExecutorKey
from repro.obs.audit import CostAudit, stats_bucket
from repro.obs.compat import renamed_keys
from repro.obs.registry import MetricsRegistry
from repro.obs.sentry import RetraceSentry, instrumented_jit
from repro.obs.tracing import Tracer
from repro.sparse import SparseMatrix

BLOCK = (16, 16)
D = 8


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test sees empty process-wide instruments."""
    obs.reset()
    yield
    obs.reset()


def _graph(rng, n: int, sparsity: float = 0.9):
    dense = np.where(rng.random((n, n)) < (1.0 - sparsity),
                     rng.normal(size=(n, n)), 0.0).astype(np.float32)
    if not dense.any():
        dense[0, 0] = 1.0
    return dense, SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                          block=BLOCK)


def _requests(rng, sizes):
    mats, hs = [], []
    for n in sizes:
        _, m = _graph(rng, n)
        mats.append(m)
        hs.append(jnp.asarray(rng.normal(size=(n, D)).astype(np.float32)))
    return mats, hs


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("reqs", engine="a").inc()
    reg.counter("reqs", engine="a").inc(4)
    reg.counter("reqs", engine="b").inc()
    reg.gauge("depth").set(3.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("lat_ms").observe(v)
    assert reg.value("reqs", engine="a") == 5
    assert reg.value("reqs", engine="b") == 1
    assert reg.total("reqs") == 6
    assert reg.value("depth") == 3.5
    snap = reg.snapshot()
    assert snap["counters"]["reqs"] == {"engine=a": 5, "engine=b": 1}
    h = snap["histograms"]["lat_ms"][""]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] <= h["p90"] <= h["p99"]


def test_registry_counter_rejects_negative_and_kind_conflict():
    reg = MetricsRegistry()
    reg.counter("x").inc()
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)
    with pytest.raises(ValueError):
        reg.gauge("x")  # same name, different kind


def test_registry_exporters_and_reset():
    reg = MetricsRegistry()
    reg.counter("hits", route="spmm").inc(2)
    reg.histogram("ms").observe(1.5)
    prom = reg.to_prometheus()
    assert "# TYPE hits counter" in prom
    assert 'hits{route="spmm"} 2' in prom
    assert "ms_count 1" in prom
    lines = [json.loads(ln) for ln in reg.to_jsonl().splitlines()]
    assert any(ln["name"] == "hits" and ln["value"] == 2 for ln in lines)
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_registry_thread_safety():
    reg = MetricsRegistry()

    def work():
        for _ in range(1000):
            reg.counter("n").inc()

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.value("n") == 8000


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_span_nesting_and_parent_propagation():
    tr = Tracer()
    with tr.span("outer", job="x"):
        with tr.span("inner"):
            pass
    outer = tr.spans("outer")[0]
    inner = tr.spans("inner")[0]
    assert inner.parent_id == outer.span_id
    assert inner.trace_id == outer.trace_id
    assert outer.parent_id is None
    assert outer.dur_ms >= inner.dur_ms >= 0.0
    summ = tr.summary()
    assert set(summ) == {"outer", "inner"}
    assert set(summ["outer"]) == {"count", "total_ms", "p50_ms", "max_ms"}


def test_span_ring_is_bounded():
    tr = Tracer(capacity=8)
    for i in range(32):
        with tr.span("s", i=i):
            pass
    assert len(tr.spans()) == 8


def test_span_feeds_registry_histogram():
    with obs.span("unit.test"):
        pass
    hists = obs.REGISTRY.snapshot()["histograms"]
    assert hists["span_ms"]["span=unit.test"]["count"] == 1


# ---------------------------------------------------------------------------
# RetraceSentry
# ---------------------------------------------------------------------------


def test_sentry_warmup_then_flags():
    reg = MetricsRegistry()
    sen = RetraceSentry(registry=reg, warmup=1)
    assert sen.record_compile("lane-a") is False      # warmup
    sen.record_call("lane-a")
    assert sen.record_compile("lane-a") is True       # past budget
    rep = sen.report()
    assert rep["compiles"] == 2 and rep["calls"] == 1
    assert rep["unexpected_retraces"] == 1
    assert rep["events"][0]["lane"] == "lane-a"
    assert reg.value("unexpected_retrace_total", lane="lane-a") == 1


def test_sentry_forget_forgives_post_eviction_recompile():
    sen = RetraceSentry(registry=MetricsRegistry(), warmup=1)
    sen.record_compile("lane-a")
    sen.forget("lane-a")              # evicted from the LRU
    assert sen.record_compile("lane-a") is False   # legitimate recompile
    assert sen.record_compile("lane-a") is True    # but only one


def test_instrumented_jit_counts_compiles_and_calls():
    sen = RetraceSentry(registry=MetricsRegistry(), warmup=1)
    fn = instrumented_jit(lambda x: x * 2, "lane-j", sentry=sen)
    np.testing.assert_allclose(fn(jnp.ones((4,))), 2 * np.ones(4))
    fn(jnp.ones((4,)))                     # same shape: no retrace
    assert sen.report()["unexpected_retraces"] == 0
    fn(jnp.ones((8,)))                     # shape drift: retrace
    rep = sen.report()
    assert rep["compiles"] == 2
    assert rep["unexpected_retraces"] == 1


# ---------------------------------------------------------------------------
# CostAudit
# ---------------------------------------------------------------------------


def test_stats_bucket_is_coarse_and_stable():
    from repro.dispatch.stats import MatrixStats

    rng = np.random.default_rng(3)
    r = rng.integers(0, 100, 300)
    c = rng.integers(0, 100, 300)
    s1 = MatrixStats.from_coords((100, 100), r, c)
    s2 = MatrixStats.from_coords((120, 120), r, c)
    assert stats_bucket(s1) == stats_bucket(s2)  # same pow2 / decade
    assert stats_bucket(s1).startswith("n128/")


def test_audit_rows_summary_and_mispredictions():
    aud = CostAudit(registry=MetricsRegistry())
    # model says csr is cheaper, but measured says ell won: that is a
    # misprediction once both paths have run in the same bucket
    for _ in range(3):
        aud.record_raw(op="spmm", path="csr", measured_ms=5.0, bucket="b0",
                       costs={"csr": 1.0, "ell": 2.0}, policy="auto")
        aud.record_raw(op="spmm", path="ell", measured_ms=1.0, bucket="b0",
                       costs={"csr": 1.0, "ell": 2.0}, policy="auto")
    assert len(aud.rows()) == 6
    summ = aud.summary()
    assert summ["spmm/csr/b0"]["n"] == 3
    assert summ["spmm/csr/b0"]["measured_ms_mean"] == pytest.approx(5.0)
    assert summ["spmm/csr/b0"]["predicted_mean"] == pytest.approx(1.0)
    mis = aud.mispredictions()
    assert len(mis) == 1
    assert mis[0]["predicted_best"] == "csr"
    assert mis[0]["measured_best"] == "ell"


def test_audit_filters_non_finite_and_is_bounded():
    aud = CostAudit(registry=MetricsRegistry(), capacity=4)
    for i in range(10):
        aud.record_raw(op="spmm", path="csr", measured_ms=1.0, bucket="b",
                       costs={"csr": float("inf"), "ell": 1.0},
                       policy="auto")
    rows = aud.rows()
    assert len(rows) == 4                       # ring capacity
    assert "csr" not in dict(rows[0].costs)     # inf filtered
    assert rows[0].predicted is None            # chosen path's cost was inf


# ---------------------------------------------------------------------------
# Dispatch ring log (satellite 1)
# ---------------------------------------------------------------------------


def test_dispatch_log_ring_capacity_and_clear(rng):
    from repro import dispatch
    from repro.sparse import ops

    dispatch.clear_log()
    old = dispatch.log_capacity()
    try:
        dispatch.set_log_capacity(3)
        _, m = _graph(rng, 32)
        h = jnp.asarray(rng.normal(size=(32, D)).astype(np.float32))
        for _ in range(5):
            ops.matmul(m, h, policy="csr", candidates=("csr",))
        log = dispatch.dispatch_log()
        assert len(log) == 3                    # bounded, newest kept
        assert dispatch.last_plan() is log[-1]
        dispatch.clear_log()
        assert not dispatch.dispatch_log()
        with pytest.raises(ValueError):
            dispatch.set_log_capacity(0)
    finally:
        dispatch.set_log_capacity(old)


def test_dispatch_log_concurrent_records():
    from repro import dispatch
    from repro.dispatch.dispatcher import Plan, record_plan

    dispatch.clear_log()
    old = dispatch.log_capacity()
    try:
        dispatch.set_log_capacity(64)

        def record():
            for _ in range(100):
                record_plan(Plan(op="spmm", path="csr", policy="auto",
                                 reason="test", use_kernel=False,
                                 interpret=False))

        threads = [threading.Thread(target=record) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(dispatch.dispatch_log()) == 64   # capacity, no tears
        assert obs.REGISTRY.total("dispatch_plans_total") == 600
    finally:
        dispatch.set_log_capacity(old)
        dispatch.clear_log()


# ---------------------------------------------------------------------------
# Deprecation shim (satellite 2)
# ---------------------------------------------------------------------------


def test_renamed_keys_alias_warns_and_canonical_is_silent():
    rep = renamed_keys({"p50_ms": 1.0, "other": 2},
                       {"latency_ms_p50": "p50_ms"})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert rep["p50_ms"] == 1.0            # canonical: no warning
        assert rep["other"] == 2
    with pytest.warns(DeprecationWarning, match="latency_ms_p50"):
        assert rep["latency_ms_p50"] == 1.0
    with pytest.warns(DeprecationWarning):
        assert rep.get("latency_ms_p50") == 1.0
    assert "latency_ms_p50" in rep and "p50_ms" in rep
    # json serialization sees only canonical keys
    assert "latency_ms_p50" not in json.loads(json.dumps(rep))


def test_renamed_keys_rejects_dangling_alias():
    with pytest.raises(KeyError):
        renamed_keys({"a": 1}, {"old_b": "b"})


# ---------------------------------------------------------------------------
# Report schema pins (satellite 3)
# ---------------------------------------------------------------------------


def test_snapshot_schema():
    snap = obs.snapshot()
    assert set(snap) == {"metrics", "spans", "sentry", "audit"}
    assert set(snap["metrics"]) == {"counters", "gauges", "histograms"}
    assert set(snap["sentry"]) == {"lanes", "compiles", "calls",
                                   "unexpected_retraces", "events"}
    assert set(snap["audit"]) == {"rows", "summary", "mispredictions"}
    json.dumps(snap)                            # always serializable


def test_executor_report_schema(rng):
    ex = BucketedExecutor(policy="csr")
    mats, hs = _requests(rng, (32, 48))
    ex.run(mats, hs)
    rep = ex.report()
    assert {"requests", "calls", "compiles", "executors_cached",
            "evictions", "buckets", "waste"} <= set(rep)
    assert {"real_rows", "padded_rows", "real_nnz", "padded_nnz",
            "row_blowup", "nnz_blowup",
            "waste_fraction"} <= set(rep["waste"])
    with pytest.warns(DeprecationWarning):
        assert rep["padding"] is rep["waste"]


def test_engine_reports_use_canonical_latency_keys(rng):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine
    from repro.serve.runtime import ContinuousBatchEngine, ContinuousConfig

    mats, hs = _requests(rng, (32, 48, 32))
    with BatchServingEngine(
            scfg=BatchServeConfig(max_batch=4, adaptive=True)) as eng:
        futs = [eng.submit(m, h) for m, h in zip(mats, hs)]
        eng.drain()
        [f.result(timeout=60) for f in futs]
        rep = eng.report()
    assert {"completed", "p50_ms", "p99_ms", "executor"} <= set(rep)
    with pytest.warns(DeprecationWarning):
        assert rep["latency_ms_p50"] == rep["p50_ms"]

    with ContinuousBatchEngine(cfg=ContinuousConfig(
            slots=2, adaptive=False, max_wait_ms=0.0)) as ceng:
        futs = [ceng.submit(m, h) for m, h in zip(mats, hs)]
        ceng.drain()
        [f.result(timeout=60) for f in futs]
        rep = ceng.report()
    assert {"submitted", "completed", "p50_ms", "p99_ms", "lanes",
            "executor"} <= set(rep)
    with pytest.warns(DeprecationWarning):
        assert rep["latency_ms_p99"] == rep["p99_ms"]


def test_ladder_and_delta_report_schemas(rng):
    from repro.serve.runtime import AdaptiveBucketLadder, DeltaGraph

    lad = AdaptiveBucketLadder()
    mats, _ = _requests(rng, (32,))
    lad.observe(mats[0].stats)
    assert {"fitted", "observed", "refits", "drift_checks", "last_drift",
            "fallbacks", "snapped_rungs", "rungs"} <= set(lad.report())
    assert obs.REGISTRY.total("ladder_observed_total") == 1

    dense, _ = _graph(rng, 32)
    dg = DeltaGraph(dense, form="csr")
    r, c = np.nonzero(dense)
    dg.delete(int(r[0]), int(c[0]))
    assert {"form", "live_nnz", "capacity", "free_slots", "deltas_applied",
            "repacks", "stats_invalidations",
            "background_repack_running"} <= set(dg.report())
    assert obs.REGISTRY.value("graph_deltas_total", op="delete") == 1


# ---------------------------------------------------------------------------
# Acceptance: one snapshot from one adaptive serving run
# ---------------------------------------------------------------------------


def test_single_adaptive_run_populates_snapshot(rng):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    with BatchServingEngine(
            scfg=BatchServeConfig(max_batch=4, adaptive=True)) as eng:
        mats, hs = _requests(rng, (32, 48, 64, 32, 48, 32, 96, 64))
        futs = [eng.submit(m, h) for m, h in zip(mats, hs)]
        eng.drain(timeout=120.0)
        [f.result(timeout=60) for f in futs]

    snap = obs.snapshot()
    counters = snap["metrics"]["counters"]
    # dispatcher plan counts, per-lane compiles/calls, padding waste
    assert sum(counters["dispatch_plans_total"].values()) > 0
    assert sum(counters["executor_compiles_total"].values()) > 0
    assert sum(counters["executor_calls_total"].values()) > 0
    assert counters["padding_rows_padded_total"][""] \
        >= counters["padding_rows_real_total"][""] > 0
    assert sum(counters["ladder_observed_total"].values()) == 8
    # serve latency histogram
    lat = snap["metrics"]["histograms"]["serve_latency_ms"]["engine=batch"]
    assert lat["count"] == 8 and lat["p50"] > 0
    # the serve path traced end to end
    assert {"serve.admit", "serve.bucket", "serve.flush", "serve.compose",
            "serve.execute", "serve.complete"} <= set(snap["spans"])
    # predicted-vs-measured audit rows from the serving executors
    rows = snap["audit"]["rows"]
    assert rows and all(r["op"] == "spmm" and r["measured_ms"] > 0
                        for r in rows)
    assert any(r["predicted"] is not None for r in rows)
    # a clean run never flags a retrace
    assert snap["sentry"]["unexpected_retraces"] == 0
    # sentry lanes agree with the executor's own counter
    assert snap["sentry"]["compiles"] > 0


# ---------------------------------------------------------------------------
# Retrace sentry through the real serve path
# ---------------------------------------------------------------------------


def test_injected_shape_drift_flags_unexpected_retrace(rng):
    ex = BucketedExecutor(policy="csr")
    mats, hs = _requests(rng, (32, 32))
    ex.run(mats, hs)
    assert obs.SENTRY.report()["unexpected_retraces"] == 0
    key = next(iter(ex._executors))
    exe = ex.executor_for(key)
    # drive the cached lane executor with a drifted shape: jit retraces,
    # and the sentry must flag it because the lane is past warmup
    _, m = _graph(rng, 2 * key.bucket.rows)
    h = jnp.asarray(rng.normal(size=(m.shape[1], D)).astype(np.float32))
    exe(m, h)
    rep = obs.SENTRY.report()
    assert rep["unexpected_retraces"] == 1
    assert rep["events"][0]["lane"] == ex.lane_label(key)
    assert obs.REGISTRY.value("unexpected_retrace_total",
                              lane=ex.lane_label(key)) == 1


def test_steady_state_continuous_run_is_retrace_free(rng):
    from repro.serve.runtime import ContinuousBatchEngine, ContinuousConfig

    with ContinuousBatchEngine(cfg=ContinuousConfig(
            slots=2, adaptive=False, max_wait_ms=0.0)) as eng:
        for wave in range(4):       # same shapes, wave after wave
            mats, hs = _requests(rng, (48, 48, 80, 80))
            futs = [eng.submit(m, h) for m, h in zip(mats, hs)]
            eng.drain(timeout=120.0)
            [f.result(timeout=60) for f in futs]
    rep = obs.SENTRY.report()
    assert rep["calls"] > rep["compiles"] > 0
    assert rep["unexpected_retraces"] == 0


# ---------------------------------------------------------------------------
# Bench regression gate (satellite 5)
# ---------------------------------------------------------------------------


def test_regression_check_kernels():
    from benchmarks.regression_check import check_kernels

    base = {"rows": [{"name": "spmm_a", "us_per_call": 10.0},
                     {"name": "spmm_gone", "us_per_call": 1.0}]}
    cur = {"rows": [{"name": "spmm_a", "us_per_call": 25.0},
                    {"name": "spmm_new", "us_per_call": 5.0}]}
    failures, notes = check_kernels(cur, base, tolerance=2.0)
    assert len(failures) == 1 and "spmm_a" in failures[0]
    assert any("spmm_gone" in n for n in notes)
    assert any("spmm_new" in n for n in notes)
    failures, _ = check_kernels(cur, base, tolerance=3.0)
    assert failures == []


def test_regression_check_serve_flags_retrace_increase():
    from benchmarks.regression_check import check_serve

    base = {"micro_adaptive": {"req_per_s_wall": 100.0,
                               "steady_compiles": 0}}
    ok = {"micro_adaptive": {"req_per_s_wall": 60.0,
                             "steady_compiles": 0}}
    failures, _ = check_serve(ok, base)
    assert failures == []           # 1.7x slower: inside tolerance
    slow = {"micro_adaptive": {"req_per_s_wall": 40.0,
                               "steady_compiles": 0}}
    failures, _ = check_serve(slow, base)
    assert len(failures) == 1 and "req/s" in failures[0]
    retrace = {"micro_adaptive": {"req_per_s_wall": 100.0,
                                  "steady_compiles": 2}}
    failures, _ = check_serve(retrace, base)
    assert len(failures) == 1 and "steady_compiles" in failures[0]


def test_regression_check_tolerates_old_key_spellings():
    from benchmarks.regression_check import get_key

    assert get_key({"latency_ms_p50": 3.0}, "p50_ms") == 3.0
    assert get_key({"p50_ms": 4.0}, "p50_ms") == 4.0
    assert get_key({}, "p50_ms") is None
