"""Fleet serving: routing, journal at-most-once, supervision, chaos.

The deterministic acceptance storm lives here:
``test_fault_storm_kill_and_heartbeat_delay`` kills 1 of 3 workers
mid-batch while delaying heartbeats and requires zero stranded
requests, bit-identical outputs vs the fault-free run, exactly-once
completion of the dead worker's in-flight, and a clean RetraceSentry.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.resilience import chaos
from repro.resilience.chaos import FaultPlan, FaultSpec
from repro.resilience.errors import EngineClosedError, WorkerLostError
from repro.serve.fleet import (AutoscaleConfig, Autoscaler, FleetConfig,
                               ServingFleet)
from repro.serve.fleet.router import Router
from repro.serve.fleet.rpc import encode_request, lane_key


@pytest.fixture(autouse=True)
def _clean_obs_and_chaos():
    obs.reset()
    chaos.uninstall()
    yield
    chaos.uninstall()
    obs.reset()


def _graph(rng, n, d=4):
    dense = (rng.random((n, n)) < 0.15).astype(np.float32)
    h = rng.standard_normal((n, d)).astype(np.float32)
    return dense, h


def _counter_total(snap, name):
    return sum(snap["metrics"]["counters"].get(name, {}).values())


# ---------------------------------------------------------------------------
# Router unit tests (no engines, no workers)
# ---------------------------------------------------------------------------


class _FakeCarrier:
    def __init__(self, workers=("a", "b", "c")):
        self.live_workers = list(workers)
        self.sent = []
        self.fail_sends_to = set()

    def send(self, worker, msg):
        if worker in self.fail_sends_to:
            return False
        self.sent.append((worker, msg))
        return True

    def live(self):
        return list(self.live_workers)


def _router(carrier, **kw):
    return Router(send=carrier.send, live=carrier.live,
                  lock=threading.RLock(), **kw)


class TestRouter:
    def _payload(self, rng, n=16, d=4):
        dense, h = _graph(rng, n, d)
        return encode_request(dense, h)

    def test_lane_sticky_round_robin(self, rng):
        carrier = _FakeCarrier()
        router = _router(carrier)
        p16 = self._payload(rng, 16)
        p32 = self._payload(rng, 32)
        e1 = router.admit(p16)
        router.dispatch(e1)
        e2 = router.admit(p32)
        router.dispatch(e2)
        assert e1.worker == "a" and e2.worker == "b"  # round-robin
        e3 = router.admit(self._payload(rng, 16))
        router.dispatch(e3)
        assert e3.worker == "a"  # sticky: same lane, same owner

    def test_journal_completes_exactly_once(self, rng):
        carrier = _FakeCarrier()
        router = _router(carrier)
        entry = router.admit(self._payload(rng))
        router.dispatch(entry)
        out = np.ones((16, 4), np.float32)
        first = router.complete(entry.rid, True, out, src=entry.worker)
        assert first is not None
        dup = router.complete(entry.rid, True, out * 2, src="b")
        assert dup is None
        assert np.array_equal(entry.future.result(0), out)
        snap = obs.snapshot()
        assert _counter_total(snap, "fleet_duplicate_results_total") == 1

    def test_failover_reroutes_orphans(self, rng):
        carrier = _FakeCarrier()
        router = _router(carrier)
        entries = [router.admit(self._payload(rng, 16)) for _ in range(3)]
        for e in entries:
            router.dispatch(e)
        owner = entries[0].worker
        assert all(e.worker == owner for e in entries)
        carrier.live_workers.remove(owner)
        orphans = router.orphans_of(owner)
        assert {o.rid for o in orphans} == {e.rid for e in entries}
        for o in orphans:
            assert router.dispatch(o, exclude=(owner,))
        assert all(e.worker != owner for e in entries)

    def test_unrouted_parks_without_workers(self, rng):
        carrier = _FakeCarrier(workers=())
        router = _router(carrier)
        entry = router.admit(self._payload(rng))
        assert not router.dispatch(entry)
        assert len(router.unrouted) == 1
        carrier.live_workers = ["a"]
        parked = router.take_unrouted()
        assert [e.rid for e in parked] == [entry.rid]
        assert router.dispatch(parked[0])
        assert entry.worker == "a"

    def test_hedge_first_wins_cancels_loser(self, rng):
        carrier = _FakeCarrier(workers=("a", "b"))
        router = _router(carrier)
        entry = router.admit(self._payload(rng))
        router.dispatch(entry)
        assert router.hedge(entry)
        assert entry.hedge_worker == "b"
        assert not router.hedge(entry)  # at most one hedge
        out = np.zeros((16, 4), np.float32)
        got = router.complete(entry.rid, True, out, src="b")
        assert got is not None
        _, loser = got
        assert loser == "a"  # the fleet sends ("cancel", rid) there

    def test_dead_send_falls_through_to_next_worker(self, rng):
        carrier = _FakeCarrier(workers=("a", "b"))
        carrier.fail_sends_to.add("a")
        router = _router(carrier)
        entry = router.admit(self._payload(rng))
        assert router.dispatch(entry)
        assert entry.worker == "b"

    def test_journal_gc_bounds_done_entries(self, rng):
        carrier = _FakeCarrier(workers=("a",))
        router = _router(carrier, keep_done=4)
        p = self._payload(rng)
        for _ in range(10):
            e = router.admit(p)
            router.dispatch(e)
            router.complete(e.rid, True, np.zeros(1), src="a")
        done = [e for e in router.journal.values() if e.done]
        assert len(done) <= 4


# ---------------------------------------------------------------------------
# Autoscaler decision logic (injected clock)
# ---------------------------------------------------------------------------


class TestAutoscaler:
    def _scaler(self, **kw):
        base = dict(enabled=True, min_workers=1, max_workers=3,
                    up_pending_per_worker=4.0,
                    down_pending_per_worker=0.5,
                    idle_grace_s=1.0, cooldown_s=2.0)
        base.update(kw)
        return Autoscaler(AutoscaleConfig(**base))

    def test_scale_up_on_backlog(self):
        s = self._scaler()
        assert s.decide(0.0, pending=20, live_workers=2) == "up"

    def test_cooldown_blocks_consecutive_actions(self):
        s = self._scaler()
        assert s.decide(0.0, pending=20, live_workers=1) == "up"
        assert s.decide(1.0, pending=20, live_workers=2) is None
        assert s.decide(2.5, pending=20, live_workers=2) == "up"

    def test_max_workers_caps_up(self):
        s = self._scaler()
        assert s.decide(0.0, pending=100, live_workers=3) is None

    def test_scale_down_needs_idle_grace(self):
        s = self._scaler()
        assert s.decide(0.0, pending=0, live_workers=2) is None
        assert s.decide(0.5, pending=0, live_workers=2) is None
        assert s.decide(1.5, pending=0, live_workers=2) == "down"

    def test_burst_resets_idle_grace(self):
        s = self._scaler()
        assert s.decide(0.0, pending=0, live_workers=2) is None
        assert s.decide(0.6, pending=3, live_workers=2) is None  # busy again
        assert s.decide(1.4, pending=0, live_workers=2) is None  # regrace
        assert s.decide(2.6, pending=0, live_workers=2) == "down"

    def test_min_workers_floors_down(self):
        s = self._scaler()
        assert s.decide(0.0, pending=0, live_workers=1) is None
        assert s.decide(5.0, pending=0, live_workers=1) is None

    def test_p99_trigger(self):
        s = self._scaler(up_p99_ms=100.0)
        assert s.decide(0.0, pending=1, live_workers=2,
                        p99_ms=250.0) == "up"


# ---------------------------------------------------------------------------
# Config-default hygiene (satellite: mutable dataclass defaults)
# ---------------------------------------------------------------------------


class TestConfigDefaults:
    def test_health_detectors_get_private_configs(self):
        from repro.ft.health import Heartbeat, StragglerDetector
        d1, d2 = StragglerDetector(), StragglerDetector()
        assert d1.cfg is not d2.cfg
        d1.cfg.straggler_ratio = 99.0
        assert d2.cfg.straggler_ratio != 99.0
        h1, h2 = Heartbeat(), Heartbeat()
        assert h1.cfg is not h2.cfg

    def test_no_shared_mutable_dataclass_defaults(self):
        """Audit: a dataclass field whose default is a dataclass
        *instance* shares that instance across every config built with
        the default — only safe when the instance is frozen."""
        import repro.ft.health
        import repro.resilience.chaos
        import repro.resilience.retry
        import repro.serve.engine
        import repro.serve.fleet.autoscale
        import repro.serve.fleet.fleet
        import repro.serve.fleet.worker
        import repro.serve.runtime.continuous
        import repro.serve.runtime.ladder
        mods = [repro.serve.engine, repro.serve.runtime.continuous,
                repro.serve.runtime.ladder, repro.resilience.retry,
                repro.resilience.chaos, repro.ft.health,
                repro.serve.fleet.fleet, repro.serve.fleet.worker,
                repro.serve.fleet.autoscale]
        offenders = []
        for mod in mods:
            for obj in vars(mod).values():
                if not (isinstance(obj, type)
                        and dataclasses.is_dataclass(obj)
                        and obj.__module__ == mod.__name__):
                    continue
                for f in dataclasses.fields(obj):
                    default = f.default
                    if default is dataclasses.MISSING or default is None:
                        continue
                    if dataclasses.is_dataclass(default) \
                            and not isinstance(default, type) \
                            and not type(default).__dataclass_params__.frozen:
                        offenders.append(
                            f"{obj.__qualname__}.{f.name} shares a "
                            f"mutable {type(default).__name__} instance")
        assert not offenders, offenders


# ---------------------------------------------------------------------------
# Fleet integration (thread backend — deterministic, tier-1)
# ---------------------------------------------------------------------------


def _fleet(**kw):
    base = dict(backend="thread", workers=2, hedge_after_ms=10_000.0)
    base.update(kw)
    return ServingFleet(FleetConfig(**base))


class TestFleetServing:
    def test_serves_correct_results_and_reports(self, rng):
        fleet = _fleet(workers=2)
        try:
            assert fleet.wait_live(2, timeout=60)
            reqs = [_graph(rng, 16 + 8 * (i % 2)) for i in range(8)]
            futs = [fleet.submit(d, h) for d, h in reqs]
            outs = [f.result(timeout=60) for f in futs]
            for (dense, h), out in zip(reqs, outs):
                np.testing.assert_allclose(out, dense @ h,
                                           rtol=1e-4, atol=1e-4)
            rep = fleet.report()
            assert rep["completed"] == 8 and rep["failed"] == 0
            for key in ("p50_ms", "p99_ms", "waste", "workers", "fleet"):
                assert key in rep
            assert rep["fleet"]["requests_lost"] == 0
            served = sum(w["served"] for w in rep["workers"].values())
            assert served == 8
        finally:
            fleet.close()

    def test_fault_storm_kill_and_heartbeat_delay(self, rng):
        """Acceptance: kill 1 of 3 workers mid-batch + delay heartbeats
        → zero strands, outputs bit-identical to the fault-free run,
        the dead worker's in-flight completes on survivors exactly
        once, and no unexpected retraces."""
        reqs = [_graph(np.random.default_rng(100 + i), 16 + 8 * (i % 2))
                for i in range(24)]

        def run(plan):
            obs.reset()
            fleet = _fleet(workers=3, max_restarts_per_worker=2)
            try:
                assert fleet.wait_live(3, timeout=60)
                if plan is not None:
                    chaos.install(plan)
                futs = [fleet.submit(d, h) for d, h in reqs]
                outs = [f.result(timeout=120) for f in futs]
                rep = fleet.report()
            finally:
                chaos.uninstall()
                fleet.close()
            return outs, rep, obs.snapshot()

        base_outs, base_rep, _ = run(None)
        assert base_rep["completed"] == len(reqs)

        plan = FaultPlan([
            FaultSpec(site="fleet.worker", kind="kill_proc", at=3,
                      match={"worker": "w2", "phase": "dispatch"}),
            FaultSpec(site="fleet.heartbeat", kind="delay",
                      payload=0.04, at=4, times=3),
        ], seed=7)
        outs, rep, snap = run(plan)

        assert any(k == "kill_proc" for _, k, _ in plan.events)
        # zero strands: every future resolved with a result
        assert rep["completed"] == len(reqs)
        assert rep["failed"] == 0
        assert rep["fleet"]["requests_lost"] == 0
        # innocents AND the victim's re-routed in-flight: bit-identical
        for a, b in zip(base_outs, outs):
            assert np.array_equal(a, b)
        # the dead worker's in-flight moved to survivors (exactly once
        # is the journal's invariant — completed == submitted above)
        assert _counter_total(snap, "fleet_failovers_total") >= 1
        assert _counter_total(snap, "fleet_worker_deaths_total") >= 1
        # post-failover the executor cache is coherent: no unexpected
        # retraces anywhere in the fleet
        assert snap["sentry"]["unexpected_retraces"] == 0

    def test_hang_triggers_missed_heartbeat_restart(self, rng):
        from repro.ft.health import HealthConfig
        fleet = _fleet(workers=2,
                       health=HealthConfig(heartbeat_timeout_s=0.2),
                       max_restarts_per_worker=2)
        try:
            assert fleet.wait_live(2, timeout=60)
            # one request to warm a lane (owned by w1)
            dense, h = _graph(rng, 16)
            fleet.infer(dense, h, timeout=60)
            chaos.install(FaultPlan([
                FaultSpec(site="fleet.worker", kind="hang", payload=30.0,
                          at=1, match={"worker": "w1",
                                       "phase": "monitor"}),
            ], seed=3))
            futs = [fleet.submit(*_graph(rng, 16)) for _ in range(6)]
            outs = [f.result(timeout=120) for f in futs]
            assert len(outs) == 6
            # the hang command is queued behind the requests, so w1 may
            # serve all six before it stops beating — the death is
            # guaranteed (the hang outlives the heartbeat timeout) but
            # asynchronous; poll for it
            deaths = {}
            deadline = time.monotonic() + 10.0
            while time.monotonic() < deadline:
                deaths = obs.snapshot()["metrics"]["counters"].get(
                    "fleet_worker_deaths_total", {})
                if deaths:
                    break
                time.sleep(0.02)
            assert any("heartbeat" in k or "killed" in k for k in deaths)
            assert fleet.report()["fleet"]["requests_lost"] == 0
        finally:
            chaos.uninstall()
            fleet.close()

    def test_blackholed_request_is_hedged(self, rng):
        fleet = _fleet(workers=2, hedge_after_ms=50.0)
        try:
            assert fleet.wait_live(2, timeout=60)
            dense, h = _graph(rng, 16)
            fleet.infer(dense, h, timeout=60)  # lane now owned by w1
            # blackhole the next request send to w1: claimed delivered,
            # never arrives — only hedging can complete it
            chaos.install(FaultPlan([
                FaultSpec(site="fleet.rpc", kind="hang", at=1,
                          match={"worker": "w1", "phase": "send"}),
            ], seed=5))
            out = fleet.infer(dense, h, timeout=60)
            np.testing.assert_allclose(out, dense @ h,
                                       rtol=1e-4, atol=1e-4)
            snap = obs.snapshot()
            assert _counter_total(snap, "fleet_hedges_total") >= 1
        finally:
            chaos.uninstall()
            fleet.close()

    def test_autoscale_up_then_down(self, rng):
        fleet = _fleet(
            workers=1,
            autoscale=AutoscaleConfig(
                enabled=True, min_workers=1, max_workers=2,
                up_pending_per_worker=2.0, down_pending_per_worker=0.5,
                idle_grace_s=0.1, cooldown_s=0.2))
        try:
            assert fleet.wait_live(1, timeout=60)
            futs = [fleet.submit(*_graph(rng, 16)) for _ in range(12)]
            deadline = time.monotonic() + 60
            while len(fleet.sup.live()) < 2:
                assert time.monotonic() < deadline, "no scale-up"
                time.sleep(0.01)
            for f in futs:
                f.result(timeout=120)
            deadline = time.monotonic() + 60
            while len(fleet.sup.live()) > 1:
                assert time.monotonic() < deadline, "no scale-down"
                time.sleep(0.01)
            snap = obs.snapshot()
            assert _counter_total(snap, "fleet_scale_ups_total") >= 1
            assert _counter_total(snap, "fleet_scale_downs_total") >= 1
            assert fleet.report()["fleet"]["requests_lost"] == 0
        finally:
            fleet.close()

    def test_rolling_restart_keeps_serving(self, rng):
        fleet = _fleet(workers=2)
        try:
            assert fleet.wait_live(2, timeout=60)
            reqs = [_graph(rng, 16) for _ in range(4)]
            for d, h in reqs:
                fleet.infer(d, h, timeout=60)
            old = {ws.name for ws in fleet.sup.states()}
            fleet.rolling_restart()
            assert fleet.wait_live(2, timeout=60)
            live = set(fleet.sup.live())
            assert live and live.isdisjoint(old)
            out = fleet.infer(*reqs[0], timeout=60)
            np.testing.assert_allclose(out, reqs[0][0] @ reqs[0][1],
                                       rtol=1e-4, atol=1e-4)
            assert fleet.report()["fleet"]["requests_lost"] == 0
        finally:
            fleet.close()

    def test_restart_budget_exhausted_fails_with_worker_lost(self, rng):
        fleet = _fleet(workers=1, max_restarts_per_worker=0)
        try:
            assert fleet.wait_live(1, timeout=60)
            chaos.install(FaultPlan([
                FaultSpec(site="fleet.worker", kind="kill_proc", at=1,
                          match={"worker": "w1", "phase": "dispatch"}),
            ], seed=1))
            fut = fleet.submit(*_graph(rng, 16))
            with pytest.raises(WorkerLostError):
                fut.result(timeout=30)
            snap = obs.snapshot()
            assert _counter_total(snap, "fleet_requests_lost_total") == 1
        finally:
            chaos.uninstall()
            fleet.close()


class TestFleetCloseDrain:
    def test_double_close_and_submit_after_close(self, rng):
        fleet = _fleet(workers=1)
        assert fleet.wait_live(1, timeout=60)
        dense, h = _graph(rng, 16)
        fut = fleet.submit(dense, h)
        fleet.close()
        fleet.close()  # idempotent
        assert fut.done() and fut.exception() is None
        with pytest.raises(EngineClosedError):
            fleet.submit(dense, h)

    def test_close_while_worker_mid_kill(self, rng):
        """close() racing a chaos kill: every future still resolves —
        with a result (failover) or a taxonomy error, never a hang."""
        fleet = _fleet(workers=2, max_restarts_per_worker=1)
        try:
            assert fleet.wait_live(2, timeout=60)
            chaos.install(FaultPlan([
                FaultSpec(site="fleet.worker", kind="kill_proc", at=2,
                          match={"phase": "dispatch"}),
            ], seed=11))
            futs = [fleet.submit(*_graph(rng, 16)) for _ in range(6)]
        finally:
            fleet.close(timeout=60)
            chaos.uninstall()
        for f in futs:
            assert f.done()
            exc = f.exception()
            assert exc is None or isinstance(
                exc, (EngineClosedError, WorkerLostError))

    def test_concurrent_close_races(self, rng):
        fleet = _fleet(workers=1)
        assert fleet.wait_live(1, timeout=60)
        fut = fleet.submit(*_graph(rng, 16))
        threads = [threading.Thread(target=fleet.close) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert fut.done()


# ---------------------------------------------------------------------------
# Process backend: real SIGKILL surface
# ---------------------------------------------------------------------------


class TestProcessBackend:
    def test_process_worker_serves(self, rng):
        fleet = _fleet(backend="process", workers=1)
        try:
            assert fleet.wait_live(1, timeout=120)
            dense, h = _graph(rng, 16)
            out = fleet.infer(dense, h, timeout=120)
            np.testing.assert_allclose(out, dense @ h,
                                       rtol=1e-4, atol=1e-4)
            assert fleet.report()["fleet"]["requests_lost"] == 0
        finally:
            fleet.close()

    @pytest.mark.slow
    def test_process_worker_sigkill_failover(self, rng):
        fleet = _fleet(backend="process", workers=2,
                       max_restarts_per_worker=1)
        try:
            assert fleet.wait_live(2, timeout=180)
            reqs = [_graph(rng, 16) for _ in range(6)]
            # warm both lanes, then SIGKILL whichever worker owns the
            # next dispatch and require completion on the survivor
            fleet.infer(*reqs[0], timeout=120)
            chaos.install(FaultPlan([
                FaultSpec(site="fleet.worker", kind="kill_proc", at=2,
                          match={"phase": "dispatch"}),
            ], seed=2))
            futs = [fleet.submit(d, h) for d, h in reqs]
            outs = [f.result(timeout=180) for f in futs]
            assert len(outs) == len(reqs)
            snap = obs.snapshot()
            assert _counter_total(snap, "fleet_kills_total") >= 1
            assert fleet.report()["fleet"]["requests_lost"] == 0
        finally:
            chaos.uninstall()
            fleet.close()
