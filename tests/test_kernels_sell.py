"""Tile-pruned SELL-C-σ Pallas kernels vs jnp oracles (interpret mode).

The SpMM kernel's flush-on-row-change logic (width-adaptive: each
block-row owns a different number of grid steps) is the part the global
fixed-width Block-ELL kernel never exercises, so the parity sweep leans
on skewed and pruned structures.  Larger parity cases are slow-marked
for the scheduled kernel-parity CI job (``--runslow``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import SellCS
from repro.kernels.sddmm.sell import sample_sell_blocked
from repro.kernels.spmm.sell import (sell_tile_blocks, spmm_sell_blocked,
                                     spmm_sell_kernel, spmm_sell_tiles_ref)
from repro.sparse.paths import spmm_sell_ref


def _rand_sparse(rng, m, n, density):
    mask = rng.random((m, n)) < density
    return np.where(mask, rng.normal(size=(m, n)), 0.0).astype(np.float32)


def _pad_h(sell, h):
    n_pad = -(-sell.shape[1] // sell.bn) * sell.bn
    out = np.zeros((n_pad, h.shape[1]), h.dtype)
    out[: h.shape[0]] = h
    return jnp.asarray(out)


@pytest.mark.parametrize("m,n,block,c", [
    (128, 128, (16, 16), 8),
    (100, 70, (4, 4), 8),      # ragged vs the tile grid
    (256, 128, (8, 16), 4),    # rectangular tiles
])
@pytest.mark.parametrize("density", [0.005, 0.05, 0.3])
def test_spmm_sell_kernel_matches_oracles(rng, m, n, block, c, density):
    dense = _rand_sparse(rng, m, n, density)
    sell = SellCS.from_dense(dense, c=c, block=block)
    d = 32
    h = rng.normal(size=(n, d)).astype(np.float32)
    out = np.asarray(spmm_sell_blocked(sell, jnp.asarray(h),
                                       interpret=True))
    np.testing.assert_allclose(out, dense @ h, rtol=5e-4, atol=5e-4)
    # kernel == tile-granular jnp oracle on the compact output
    if sell.n_live_block_rows:
        hh = _pad_h(sell, h)
        compact = spmm_sell_kernel(
            sell.tile_rows, sell.tile_cols, sell_tile_blocks(sell), hh,
            n_live_block_rows=sell.n_live_block_rows, bd=d,
            interpret=True)
        ref = spmm_sell_tiles_ref(
            sell.tile_rows, sell.tile_cols, sell_tile_blocks(sell), hh,
            n_live_block_rows=sell.n_live_block_rows)
        np.testing.assert_allclose(np.asarray(compact), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
    # kernel route == bucketed reference route
    ref2 = np.asarray(spmm_sell_ref(sell, jnp.asarray(h)))
    np.testing.assert_allclose(out, ref2, rtol=5e-4, atol=5e-4)


def test_spmm_sell_skewed_widths(rng):
    """A few hot rows + many near-empty rows: block-rows own wildly
    different live-tile counts, stressing the flush logic."""
    dense = np.zeros((256, 256), np.float32)
    dense[:4] = _rand_sparse(rng, 4, 256, 0.6)       # hot rows
    dense[100:140] = _rand_sparse(rng, 40, 256, 0.01)
    dense[255, 255] = 2.0                            # lone corner element
    sell = SellCS.from_dense(dense, c=8, block=(8, 8))
    h = rng.normal(size=(256, 64)).astype(np.float32)
    out = np.asarray(spmm_sell_blocked(sell, jnp.asarray(h),
                                       interpret=True))
    np.testing.assert_allclose(out, dense @ h, rtol=5e-4, atol=5e-4)


def test_spmm_sell_empty_rows_never_launch(rng):
    """Pruned (all-zero) rows produce exact zeros via the epilogue
    gather — they are not kernel output."""
    dense = np.zeros((128, 128), np.float32)
    dense[:8] = _rand_sparse(rng, 8, 128, 0.2)
    sell = SellCS.from_dense(dense, c=8, block=(16, 16))
    assert sell.n_live_block_rows == 1  # 8 live rows -> one block-row
    h = rng.normal(size=(128, 32)).astype(np.float32)
    out = np.asarray(spmm_sell_blocked(sell, jnp.asarray(h),
                                       interpret=True))
    assert np.all(out[8:] == 0.0)
    np.testing.assert_allclose(out, dense @ h, rtol=5e-4, atol=5e-4)


def test_spmm_sell_empty_matrix():
    sell = SellCS.from_dense(np.zeros((64, 64), np.float32))
    out = spmm_sell_blocked(sell, jnp.ones((64, 8), jnp.float32),
                            interpret=True)
    assert out.shape == (64, 8)
    assert np.all(np.asarray(out) == 0.0)


@pytest.mark.parametrize("density", [0.01, 0.2])
def test_sddmm_sell_kernel_matches_dense_sample(rng, density):
    m, n, k = 128, 96, 64
    dense = _rand_sparse(rng, m, n, density)
    sell = SellCS.from_dense(dense, c=8, block=(16, 16))
    b = rng.normal(size=(m, k)).astype(np.float32)
    c = rng.normal(size=(k, n)).astype(np.float32)
    dots = np.asarray(sample_sell_blocked(
        sell, jnp.asarray(b), jnp.asarray(c), interpret=True))
    full = b @ c
    sr = np.asarray(sell.slot_rows)
    sc = np.asarray(sell.slot_cols)
    real = np.asarray(sell.slot_vals) != 0
    np.testing.assert_allclose(dots[real], full[sr[real], sc[real]],
                               rtol=1e-3, atol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("m,n,d,block", [
    (512, 512, 256, (64, 128)),
    (384, 768, 128, (128, 128)),
])
@pytest.mark.parametrize("density", [0.002, 0.02, 0.2])
def test_spmm_sell_kernel_parity_large(rng, m, n, d, block, density):
    """Slow kernel-parity sweep (scheduled CI job): MXU-shaped tiles."""
    dense = _rand_sparse(rng, m, n, density)
    sell = SellCS.from_dense(dense, c=16, block=block)
    h = rng.normal(size=(n, d)).astype(np.float32)
    out = np.asarray(spmm_sell_blocked(sell, jnp.asarray(h),
                                       interpret=True))
    np.testing.assert_allclose(out, dense @ h, rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_sddmm_sell_kernel_parity_large(rng):
    m, n, k = 512, 512, 256
    dense = _rand_sparse(rng, m, n, 0.01)
    sell = SellCS.from_dense(dense, c=16, block=(64, 64))
    b = rng.normal(size=(m, k)).astype(np.float32)
    c = rng.normal(size=(k, n)).astype(np.float32)
    dots = np.asarray(sample_sell_blocked(
        sell, jnp.asarray(b), jnp.asarray(c), interpret=True))
    full = b @ c
    sr = np.asarray(sell.slot_rows)
    sc = np.asarray(sell.slot_cols)
    real = np.asarray(sell.slot_vals) != 0
    np.testing.assert_allclose(dots[real], full[sr[real], sc[real]],
                               rtol=1e-2, atol=1e-2)
