"""Fused block-sparse flash attention kernel vs dense masked oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsattn.ops import banded_ell, block_sparse_flash_attention
from repro.kernels.bsattn.ref import (block_sparse_attention_ref,
                                      dense_mask_from_ell)


def _qkv(rng, bh=4, bkv=2, s=256, d=64, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(bh, s, d)).astype(dtype))
    k = jnp.asarray(rng.normal(size=(bkv, s, d)).astype(dtype))
    v = jnp.asarray(rng.normal(size=(bkv, s, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("window,bq,bk", [
    (64, 64, 64), (128, 64, 64), (64, 64, 32), (128, 128, 64),
])
def test_banded_kernel_matches_oracle(rng, window, bq, bk):
    q, k, v = _qkv(rng)
    s = q.shape[1]
    ell, val = banded_ell(s, bq, bk, window)
    mask = dense_mask_from_ell(ell, val, s, bq, bk, causal=True,
                               window=window)
    ref = block_sparse_attention_ref(q, k, v, mask)
    out = block_sparse_flash_attention(q, k, v, window=window, block_q=bq,
                                       block_kv=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_full_causal_window0(rng):
    q, k, v = _qkv(rng, s=128)
    out = block_sparse_flash_attention(q, k, v, window=0, block_q=64,
                                       block_kv=64, interpret=True)
    ell, val = banded_ell(128, 64, 64, 0)
    mask = dense_mask_from_ell(ell, val, 128, 64, 64, causal=True)
    ref = block_sparse_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_custom_block_pattern(rng):
    """BigBird-ish pattern: every q block sees block 0 (global) + itself."""
    q, k, v = _qkv(rng, s=256)
    nq = 4
    ell = np.stack([np.zeros(nq), np.arange(nq)], axis=1).astype(np.int32)
    val = np.ones_like(ell)
    out = block_sparse_flash_attention(
        q, k, v, causal=True, block_q=64, block_kv=64,
        ell_idx=jnp.asarray(ell), valid=jnp.asarray(val), interpret=True)
    mask = dense_mask_from_ell(ell, val, 256, 64, 64, causal=True)
    ref = block_sparse_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs(rng):
    q, k, v = _qkv(rng, s=128, dtype=np.float32)
    q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
    out = block_sparse_flash_attention(q, k, v, window=64, block_q=64,
                                       block_kv=64, interpret=True)
    ell, val = banded_ell(128, 64, 64, 64)
    mask = dense_mask_from_ell(ell, val, 128, 64, 64, causal=True,
                               window=64)
    ref = block_sparse_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=3e-2, atol=3e-2)


def test_gqa_head_mapping(rng):
    """8 q heads on 2 kv heads: kernel's index-map gather == repeated KV."""
    q, k, v = _qkv(rng, bh=8, bkv=2, s=128)
    out = block_sparse_flash_attention(q, k, v, window=64, block_q=64,
                                       block_kv=64, interpret=True)
    krep = jnp.repeat(k, 4, axis=0)
    vrep = jnp.repeat(v, 4, axis=0)
    out2 = block_sparse_flash_attention(q, krep, vrep, window=64,
                                        block_q=64, block_kv=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
