"""Checkpoint/restart, elastic resharding, straggler detection."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, lm_data_iter
from repro.ft.checkpoint import Checkpointer
from repro.ft.health import (HealthConfig, Heartbeat, SimulatedCluster,
                             StragglerDetector)
from repro.ft.resharding import replicated_tree, reshard
from repro.models.transformer import init_lm
from repro.sharding.specs import make_mesh
from repro.train.loop import TrainConfig, init_train_state, make_train_step
from repro.train.optimizer import OptConfig


def _setup(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("nemotron-4-15b"),
                              dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=5e-3, warmup_steps=0,
                                     total_steps=100))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    it = lambda start: lm_data_iter(  # noqa: E731
        cfg, ShapeConfig("t", 32, 4, "train"), DataConfig(seed=9),
        start_step=start)
    return cfg, tcfg, params, state, step, it


def test_checkpoint_roundtrip_and_gc(tmp_path, rng):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32)),
            "nested": {"b": jnp.arange(5)}}
    for s in (1, 2, 3):
        ck.save(s, tree, meta={"tag": "x"})
    assert ck.all_steps() == [2, 3]  # keep=2 garbage-collects step 1
    out = ck.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
    assert ck.metadata()["tag"] == "x"


def test_checkpoint_atomicity_on_partial_write(tmp_path, rng):
    ck = Checkpointer(str(tmp_path), async_save=False)
    tree = {"a": jnp.zeros((2,))}
    ck.save(1, tree)
    # simulate a crashed write: stray tmp dir must not be visible as a step
    os.makedirs(os.path.join(str(tmp_path), ".tmp_crashed"), exist_ok=True)
    open(os.path.join(str(tmp_path), ".tmp_crashed", "a.npy"), "wb").close()
    assert ck.all_steps() == [1]
    ck.restore(tree)  # still restores cleanly


def test_failure_restart_resumes_identically(tmp_path):
    """Train 6 steps; 'crash' after ckpt at 3; restore+replay == original.

    Deterministic data + deterministic step => bit-identical recovery, the
    property a 1000-node deployment relies on for elastic restarts.
    """
    cfg, tcfg, params, state, step, make_it = _setup(tmp_path)
    ck = Checkpointer(str(tmp_path), async_save=False)

    it = make_it(0)
    p, s = params, state
    for i in range(6):
        p, s, _ = step(p, s, next(it))
        if i == 2:
            ck.save(3, {"params": p, "state": s})
    final_direct = p

    # crash + restore at step 3, replay steps 3..5 with the same data
    restored = ck.restore({"params": params, "state": state})
    p2, s2 = restored["params"], restored["state"]
    it2 = make_it(3)
    for i in range(3):
        p2, s2, _ = step(p2, s2, next(it2))

    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), final_direct, p2)
    assert max(jax.tree_util.tree_leaves(diff)) < 1e-6


def test_reshard_roundtrip(rng):
    mesh = make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))}
    out = reshard(tree, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    sh = replicated_tree(tree, mesh)
    assert sh["w"].mesh.shape == mesh.shape


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(HealthConfig(window=20))
    for i in range(15):
        det.record(i, 0.100 + 0.001 * (i % 3))
    assert det.record(15, 0.5) is True  # 5x median
    assert det.record(16, 0.101) is False
    assert det.flags == [15]


def test_heartbeat_timeout():
    hb = Heartbeat(HealthConfig(heartbeat_timeout_s=10))
    hb.beat(0, now=100.0)
    hb.beat(1, now=105.0)
    assert hb.dead_hosts(now=112.0) == [0]
    assert set(hb.dead_hosts(now=120.0)) == {0, 1}


def test_simulated_cluster_hot_spare_then_shrink():
    c = SimulatedCluster(n_hosts=4, n_spares=1)
    assert c.fail(2) == "swap"
    assert c.world_size == 4
    assert c.fail(0) == "shrink"  # spares exhausted -> elastic shrink
    assert c.world_size == 3
