"""Distributed decompositions (paper §2.4) — runs in a subprocess with 8
fake devices so the main test process keeps the default single device."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.formats import BlockELL
    from repro.core.distributed import (spmm_1p5d, spmm_2d, spmm_2p5d,
                                        allgather_matmul_overlap)

    rng = np.random.default_rng(2)
    M, N, D = 256, 256, 64
    dense = (rng.normal(size=(M, N)) * (rng.random((M, N)) < 0.2)) \\
        .astype(np.float32)
    h = rng.normal(size=(N, D)).astype(np.float32)
    expected = dense @ h
    ell = BlockELL.from_dense(dense, bm=32, bn=32)

    from repro.sharding.specs import make_mesh
    mesh = make_mesh((2, 2), ("data", "model"))
    for name, fn in [("1.5D", spmm_1p5d), ("2D", spmm_2d)]:
        y = fn(ell, jnp.asarray(h), mesh)
        np.testing.assert_allclose(np.asarray(y), expected,
                                   rtol=2e-4, atol=2e-4)
        print(name, "OK")

    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    y = spmm_2p5d(ell, jnp.asarray(h), mesh3)
    np.testing.assert_allclose(np.asarray(y), expected, rtol=2e-4, atol=2e-4)
    print("2.5D OK")

    x = rng.normal(size=(16, 64)).astype(np.float32)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    ym = allgather_matmul_overlap(jnp.asarray(x), jnp.asarray(w), mesh,
                                  axis="model")
    np.testing.assert_allclose(np.asarray(ym), x @ w, rtol=2e-4, atol=2e-4)
    print("collective-matmul OK")

    # sharded train step parity vs single-device (tiny model)
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models.transformer import init_lm
    from repro.train.loop import TrainConfig, init_train_state, \\
        make_train_step
    from repro.train.optimizer import OptConfig
    from repro.data.pipeline import make_lm_batch, DataConfig
    from repro.sharding.specs import param_sharding_tree, data_sharding_tree
    from repro.sharding import ctx as shard_ctx

    cfg = dataclasses.replace(get_smoke_config("granite-20b"),
                              dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0,
                                     total_steps=10))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    batch = make_lm_batch(cfg, 32, 8, 0, DataConfig(seed=0))
    step = make_train_step(cfg, tcfg)
    p1, _, m1 = jax.jit(step)(params, state, batch)

    # fresh step fn for the sharded run: jit reuses the traced jaxpr per
    # function object, and step's first trace (no mesh installed) has no
    # shard_hint constraints baked in
    step2 = make_train_step(cfg, tcfg)
    p_sh = param_sharding_tree(params, mesh)
    s_sh = param_sharding_tree(state, mesh)
    b_sh = data_sharding_tree(batch, mesh, 8)
    shard_ctx.set_mesh(mesh)
    p2, _, m2 = jax.jit(step2, in_shardings=(p_sh, s_sh, b_sh),
                        out_shardings=(p_sh, s_sh, None))(
        params, state, batch)
    shard_ctx.clear_mesh()
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    import jax.tree_util as jtu
    diff = jtu.tree_map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    # first adam step quantizes updates to ~+-lr; reduction-order noise on
    # near-zero grads can flip signs, so allow a few lr quanta of drift
    assert max(jtu.tree_leaves(diff)) < 3e-3, max(jtu.tree_leaves(diff))
    print("sharded-train-parity OK")
""")


@pytest.mark.slow
def test_distributed_spmm_and_sharded_train():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    for tag in ("1.5D OK", "2D OK", "2.5D OK", "collective-matmul OK",
                "sharded-train-parity OK"):
        assert tag in out.stdout
