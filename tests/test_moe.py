"""MoE dispatch correctness (the paper's hyper-sparse SpMM, DESIGN.md §4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.moe import _capacity, init_moe, moe_ffn
from repro.models.layers import activation


def _dense_reference(p, x, cfg):
    """Per-token dense expert compute (no capacity drops)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gate = np.asarray(jnp.max(probs, axis=-1))
    eid = np.asarray(jnp.argmax(probs, axis=-1))
    out = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        e = int(eid[t])
        h = np.asarray(xf[t]) @ np.asarray(p["w_in"][e])
        h = np.asarray(activation(jnp.asarray(h), cfg.act))
        if cfg.gated_mlp:
            h = h * (np.asarray(xf[t]) @ np.asarray(p["w_gate"][e]))
        out[t] = (h @ np.asarray(p["w_out"][e])) * gate[t]
    if "shared" in p:
        from repro.models.layers import mlp
        out = out + np.asarray(mlp(p["shared"], jnp.asarray(xf), cfg))
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_no_drops(rng):
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    y, aux = moe_ffn(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_bounded(rng):
    """With cf=1.0 every expert processes at most `capacity` tokens."""
    cfg = get_smoke_config("llama4-maverick-400b-a17b")
    cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 32, cfg.d_model)).astype(np.float32))
    y, _ = moe_ffn(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    cap = _capacity(4 * 32, cfg)
    assert cap >= 8 and cap % 8 == 0


def test_moe_aux_loss_prefers_balance(rng):
    """Uniform routing gives lower aux loss than collapsed routing."""
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    t, d, e = 64, cfg.d_model, cfg.n_experts
    x = jnp.asarray(rng.normal(size=(1, t, d)).astype(np.float32))
    # collapse: router weights push everything to expert 0
    p_collapsed = dict(p)
    router = np.zeros((d, e), np.float32)
    router[:, 0] = 1.0
    p_collapsed["router"] = jnp.asarray(router)
    _, aux_bal = moe_ffn(p, x, cfg)
    _, aux_col = moe_ffn(p_collapsed, x, cfg)
    assert float(aux_col) > float(aux_bal)


def test_moe_grad_flows(rng):
    cfg = get_smoke_config("llama4-scout-17b-a16e")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)).astype(np.float32))

    def loss(p):
        y, aux = moe_ffn(p, x, cfg)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = [float(jnp.abs(v).max()) for v in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(gn)) and max(gn) > 0
