"""Format round-trips + the paper's SELLPACK stream accounting."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.formats import (BlockELL, BlockCOO, CSR, SellCS,
                                blockell_stream_elements,
                                sell_slot_volume,
                                sellpack_stream_elements)
from repro.core.topology import (balance_permutation, block_row_counts,
                                 choose_ell_width, padding_stats)


def _rand_sparse(rng, m, n, density):
    mask = rng.random((m, n)) < density
    return np.where(mask, rng.normal(size=(m, n)), 0.0).astype(np.float32)


@pytest.mark.parametrize("m,n,bm,bn", [
    (64, 64, 16, 16), (128, 64, 32, 16), (100, 70, 16, 32), (16, 16, 16, 16),
])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5, 1.0])
def test_blockell_roundtrip(rng, m, n, bm, bn, density):
    dense = _rand_sparse(rng, m, n, density)
    ell = BlockELL.from_dense(dense, bm, bn)
    back = ell.to_dense()
    assert back.shape[0] % bm == 0 and back.shape[1] % bn == 0
    np.testing.assert_array_equal(back[:m, :n], dense)
    # padding region is zero
    assert np.all(back[m:] == 0) and np.all(back[:, n:] == 0)


@pytest.mark.parametrize("pad_to", [None, 64])
def test_blockcoo_roundtrip(rng, pad_to):
    dense = _rand_sparse(rng, 96, 80, 0.1)
    coo = BlockCOO.from_dense(dense, 16, 16, pad_to=pad_to)
    np.testing.assert_array_equal(coo.to_dense()[:96, :80], dense)


def test_csr_roundtrip(rng):
    dense = _rand_sparse(rng, 50, 70, 0.15)
    csr = CSR.from_dense(dense)
    np.testing.assert_array_equal(csr.to_dense(), dense)
    assert csr.nnz == (dense != 0).sum()


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(17, 80), n=st.integers(17, 80),
    density=st.floats(0.0, 0.6),
    seed=st.integers(0, 2**31 - 1),
)
def test_blockell_roundtrip_property(m, n, density, seed):
    rng = np.random.default_rng(seed)
    dense = _rand_sparse(rng, m, n, density)
    ell = BlockELL.from_dense(dense, 16, 16)
    np.testing.assert_array_equal(ell.to_dense()[:m, :n], dense)


def test_sellpack_stream_counts_small():
    # worked example: 4x4 matrix, myc=2, mvpp=2 -> 2 buckets
    dense = np.array([
        [1, 0, 0, 2],
        [0, 0, 0, 0],
        [3, 4, 0, 0],
        [0, 0, 5, 0],
    ], dtype=np.float32)
    csr = CSR.from_dense(dense)
    total = sellpack_stream_elements(csr, max_y_chunk=2, max_v_per_pe=2)
    # chunk 1: b0=[v1,E(run absorbs empty row1)] b1=[v2,E] -> max 2 each
    # chunk 2: b0=[v3,v4,E] b1=[E,v5,E] -> max 3 each
    assert total == 2 * 2 + 3 * 2


def test_sellpack_ratio_grows_with_sparsity(rng):
    """Paper Fig. 8: lower density => worse SELL/CSR element ratio."""
    n = 256
    ratios = []
    for density in (0.1, 0.01, 0.001):
        dense = _rand_sparse(rng, n, n, density)
        csr = CSR.from_dense(dense)
        if csr.nnz == 0:
            continue
        tot = sellpack_stream_elements(csr, 64, 64)
        ratios.append(tot / max(csr.nnz, 1))
    assert ratios == sorted(ratios), ratios


def test_blockell_stream_elements(rng):
    dense = _rand_sparse(rng, 128, 128, 0.05)
    ell = BlockELL.from_dense(dense, 32, 32)
    assert blockell_stream_elements(ell) == \
        ell.blocks.size + ell.indices.size


def test_balance_permutation_reduces_padding(rng):
    # skewed block-row counts: one very dense stripe
    dense = _rand_sparse(rng, 256, 256, 0.02)
    dense[:16] = rng.normal(size=(16, 256))  # hot rows
    counts = block_row_counts(dense, 16, 16)
    stats_before = padding_stats(counts)
    perm = balance_permutation(counts)
    counts_after = block_row_counts(dense[np.concatenate(
        [np.arange(i * 16, i * 16 + 16) for i in perm])], 16, 16)
    # sorted rows: same max but slice-local widths shrink; verify the
    # sorted property which sliced-ELL exploits
    assert (np.diff(counts_after) <= 0).all()
    assert stats_before["max_count"] == counts_after.max()


def test_choose_ell_width_occupancy(rng):
    counts = np.array([1, 1, 1, 50])
    assert choose_ell_width(counts) == 50
    w = choose_ell_width(counts, occupancy_target=0.5)
    assert w < 50


# ---------------------------------------------------------------------------
# Adversarial roundtrips (dispatcher edge inputs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["csr", "blockell", "blockcoo"])
def test_all_zero_matrix_roundtrip(fmt):
    dense = np.zeros((64, 48), np.float32)
    if fmt == "csr":
        csr = CSR.from_dense(dense)
        assert csr.nnz == 0
        np.testing.assert_array_equal(csr.to_dense(), dense)
    elif fmt == "blockell":
        ell = BlockELL.from_dense(dense, 16, 16)
        assert ell.ell_width == 1  # padded floor: one (zero) slot per row
        assert ell.occupancy() == 0.0
        np.testing.assert_array_equal(ell.to_dense(), dense)
    else:
        coo = BlockCOO.from_dense(dense, 16, 16)
        assert coo.nnzb == 1  # sentinel zero block
        np.testing.assert_array_equal(coo.to_dense(), dense)


@pytest.mark.parametrize("pos", [(0, 0), (63, 47), (17, 31)])
def test_single_nonzero_roundtrip(pos):
    dense = np.zeros((64, 48), np.float32)
    dense[pos] = 3.5
    for back in (CSR.from_dense(dense).to_dense(),
                 BlockELL.from_dense(dense, 16, 16).to_dense(),
                 BlockCOO.from_dense(dense, 16, 16).to_dense()):
        np.testing.assert_array_equal(back[:64, :48], dense)


@pytest.mark.parametrize("m,n,bm,bn", [
    (65, 47, 16, 16),   # both dims ragged
    (16, 17, 16, 16),   # one column over
    (1, 1, 16, 32),     # tiny
    (31, 128, 32, 64),  # row-ragged only
])
def test_non_divisible_shapes_roundtrip(rng, m, n, bm, bn):
    dense = _rand_sparse(rng, m, n, 0.3)
    ell = BlockELL.from_dense(dense, bm, bn)
    assert ell.shape[0] % bm == 0 and ell.shape[1] % bn == 0
    np.testing.assert_array_equal(ell.to_dense()[:m, :n], dense)
    coo = BlockCOO.from_dense(dense, bm, bn)
    np.testing.assert_array_equal(coo.to_dense()[:m, :n], dense)


def test_full_density_roundtrip(rng):
    dense = rng.normal(size=(64, 64)).astype(np.float32)
    dense[dense == 0] = 1.0  # ensure truly full
    ell = BlockELL.from_dense(dense, 16, 16)
    assert ell.ell_width == 4  # every block-column occupied
    assert ell.occupancy() == 1.0
    np.testing.assert_array_equal(ell.to_dense(), dense)
    csr = CSR.from_dense(dense)
    assert csr.nnz == 64 * 64


def test_ell_width_overflow_raises(rng):
    dense = _rand_sparse(rng, 64, 64, 0.9)
    with pytest.raises(ValueError, match="ell_width"):
        BlockELL.from_dense(dense, 16, 16, ell_width=1)


def test_sellpack_stream_elements_monotone_in_nnz(rng):
    """Regression: more nonzeros can never shrink the streamed volume."""
    n = 128
    base = rng.random((n, n))
    prev = None
    for density in (0.001, 0.01, 0.05, 0.1, 0.3):
        dense = np.where(base < density, 1.0, 0.0).astype(np.float32)
        csr = CSR.from_dense(dense)
        tot = sellpack_stream_elements(csr, max_y_chunk=32, max_v_per_pe=32)
        if prev is not None:
            assert tot >= prev, (density, tot, prev)
        prev = tot


# ---------------------------------------------------------------------------
# SELL-C-σ
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,c,sigma,block", [
    (64, 64, 8, 0, (16, 16)),
    (100, 70, 8, 0, (4, 4)),       # ragged vs the tile grid
    (128, 128, 4, 32, (8, 8)),     # σ-windowed sort
    (33, 47, 8, 8, (4, 4)),
])
@pytest.mark.parametrize("density", [0.005, 0.05, 0.3])
def test_sellcs_roundtrip(rng, m, n, c, sigma, block, density):
    dense = _rand_sparse(rng, m, n, density)
    sell = SellCS.from_dense(dense, c=c, sigma=sigma, block=block)
    np.testing.assert_array_equal(sell.to_dense(), dense)
    # the stats helper prices exactly what the packer built
    assert sell.n_slots == sell_slot_volume(
        (dense != 0).sum(axis=1), c, sigma)


def test_sellcs_prunes_empty_slices(rng):
    """All-zero rows cost nothing: no slots, no tiles, no output rows."""
    dense = np.zeros((128, 128), np.float32)
    dense[:16] = _rand_sparse(rng, 16, 128, 0.2)
    sell = SellCS.from_dense(dense, c=8, block=(16, 16))
    # only the 16 live rows are packed (2 slices of 8)
    assert sell.n_packed_rows == 16
    assert (np.asarray(sell.out_gather)[16:] == sell.n_packed_rows).all()
    np.testing.assert_array_equal(sell.to_dense(), dense)


def test_sellcs_no_dead_tiles(rng):
    """Every stored tile holds at least one live slot (tile pruning)."""
    dense = _rand_sparse(rng, 256, 256, 0.005)
    sell = SellCS.from_dense(dense, c=8, block=(4, 4))
    tsm = np.asarray(sell.tile_slot_map).reshape(sell.n_tiles, -1)
    assert ((tsm < sell.n_slots).any(axis=1)).all()
    # tiles are block-row-major so the kernel can accumulate sequentially
    assert (np.diff(np.asarray(sell.tile_rows)) >= 0).all()


def test_sellcs_width_adaptive_beats_global_ell_width(rng):
    """The cliff mechanism: at hyper-sparsity the sell slot volume stays
    ~nnz while Block-ELL's global-width stream volume blows up."""
    dense = _rand_sparse(rng, 512, 512, 0.005)
    # one heavy row forces the Block-ELL global width wide
    dense[0] = np.where(rng.random(512) < 0.5, 1.0, 0.0)
    nnz = int((dense != 0).sum())
    ell = BlockELL.from_dense(dense, 4, 4)
    ell_stored = int(np.prod(ell.blocks.shape))
    sell = SellCS.from_dense(dense, c=8, block=(4, 4))
    # the heavy row pads only its own C-row slice, never the matrix
    assert sell.n_slots < nnz * 3
    assert ell_stored > 10 * sell.n_slots


def test_sellcs_sigma_window_tradeoff(rng):
    """Full sort packs at least as tight as windowed sort (σ trades
    packing efficiency for permutation locality)."""
    dense = _rand_sparse(rng, 256, 256, 0.02)
    row_nnz = (dense != 0).sum(axis=1)
    full = sell_slot_volume(row_nnz, 8, 0)
    for sigma in (16, 64, 128):
        assert sell_slot_volume(row_nnz, 8, sigma) >= full
    # no sort at all (window == slice) can only be worse or equal
    assert sell_slot_volume(row_nnz, 8, 8) >= full


def test_sellcs_empty_matrix():
    sell = SellCS.from_dense(np.zeros((64, 64), np.float32))
    assert sell.n_slots == 0 and sell.n_tiles == 0
    assert sell.n_live_block_rows == 0 and sell.buckets == ()
    np.testing.assert_array_equal(sell.to_dense(),
                                  np.zeros((64, 64), np.float32))


# ---------------------------------------------------------------------------
# Degenerate inputs through the full SparseMatrix pipeline
# ---------------------------------------------------------------------------
#
# Every format must survive the degenerate structures real corpora
# contain — an all-zero operand, a single hub row that forces the
# global ELL width to the full row, and shapes that leave ragged
# block/slice remainders (M % C != 0, M % bm != 0) — at all three
# layers: construction, measured stats, and execution against the
# dense oracle (SpMM, SpMV, and the forced native path).


def _degenerate(case, m, n, rng):
    a = np.zeros((m, n), np.float32)
    if case == "all_zero":
        return a
    if case == "hub_row":
        a[min(3, m - 1), :] = 1.0 + np.abs(rng.normal(size=n)) \
            .astype(np.float32)
        return a
    if case == "ragged":
        mask = rng.random((m, n)) < 0.1
        return np.where(mask, rng.normal(size=(m, n)), 0.0) \
            .astype(np.float32)
    raise ValueError(case)


@pytest.mark.parametrize("fmt", ["ell", "sell", "csr", "coo"])
@pytest.mark.parametrize("case,m,n", [
    ("all_zero", 64, 64),
    ("hub_row", 64, 64),
    ("ragged", 100, 70),   # M % C != 0 and M % bm != 0
])
def test_degenerate_inputs_full_pipeline(rng, fmt, case, m, n):
    from repro.sparse import SparseMatrix, matmul

    a = _degenerate(case, m, n, rng)
    A = SparseMatrix.from_dense(a, format=fmt, block=(16, 16))
    s = A.stats
    nnz = int(np.count_nonzero(a))
    assert s.nnz == nnz
    assert s.max_row_nnz == int((a != 0).sum(axis=1).max())
    if case == "hub_row":
        # the hub prices the whole streaming layout
        assert s.ell_stream_estimate >= s.shape[0] * n
    h = rng.normal(size=(n, 8)).astype(np.float32)
    v = rng.normal(size=(n,)).astype(np.float32)
    for pol in ("auto", "dense", fmt if fmt != "coo" else "ell"):
        np.testing.assert_allclose(np.asarray(matmul(A, h, policy=pol)),
                                   a @ h, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{fmt}/{case}/spmm/{pol}")
        np.testing.assert_allclose(np.asarray(matmul(A, v, policy=pol)),
                                   a @ v, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{fmt}/{case}/spmv/{pol}")
