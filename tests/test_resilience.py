"""Chaos suite: fault injection, retry/bisection, shedding, recovery.

Covers the resilience layer's acceptance contract:
  * a poison request co-batched with innocents is quarantined alone —
    bisection completes the innocents from its probe executions (the
    ``_fail_lane`` collateral-damage regression);
  * transient executor faults retry with backoff and succeed; persistent
    ones fail with a structured retries-exhausted error;
  * NaN/Inf output blocks quarantine instead of returning garbage;
  * dead worker threads restart under a bounded supervisor, and
    ``infer(timeout=)`` bounds the wait on a stuck future;
  * queue overflow sheds the lowest-priority request; expired deadlines
    fail queued requests with ``DeadlineExceededError``;
  * a form that keeps failing degrades and the lane rebuilds on the
    surviving form;
  * a chaos-killed training step restores from the newest checkpoint
    and reconverges to the same final loss;
  * a crashed background repack leaves the old overlay serving;
  * a deterministic fault storm strands nothing: every future resolves,
    non-poison requests complete exactly once with correct results, and
    every recovery action is visible in ``obs.snapshot()``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.resilience import (DeadlineExceededError, FaultPlan, FaultSpec,
                              PoisonRequestError, RequestShedError,
                              RetryPolicy, TransientExecutorError, chaos)
from repro.serve.runtime import ContinuousBatchEngine, ContinuousConfig
from repro.sparse import SparseMatrix

BLOCK = (16, 16)
D = 8
FAST_RETRY = RetryPolicy(max_attempts=3, base_ms=0.1, max_ms=1.0)


@pytest.fixture(autouse=True)
def _clean_obs_and_chaos():
    obs.reset()
    chaos.uninstall()
    yield
    chaos.uninstall()


def _graph(rng, n: int, sparsity: float = 0.9):
    dense = np.where(rng.random((n, n)) < (1.0 - sparsity),
                     rng.normal(size=(n, n)), 0.0).astype(np.float32)
    if not dense.any():
        dense[0, 0] = 1.0
    return dense, SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                          block=BLOCK)


def _cfg(**kw) -> ContinuousConfig:
    kw.setdefault("slots", 4)
    kw.setdefault("adaptive", False)
    kw.setdefault("max_wait_ms", 0.0)
    kw.setdefault("retry", FAST_RETRY)
    return ContinuousConfig(**kw)


def _counter_total(snap, name: str) -> float:
    return sum(snap["metrics"]["counters"].get(name, {}).values())


# ---------------------------------------------------------------------------
# poison bisection (the _fail_lane collateral-damage regression)
# ---------------------------------------------------------------------------


def test_poison_bisection_quarantines_only_culprit(rng):
    """One poison request + three innocents in a full lane: only the
    tagged request fails; the innocents complete with correct results
    from the bisection probes."""
    plan = FaultPlan([FaultSpec(site="continuous.execute", kind="poison",
                                times=None, match={"tags": "bad"})])
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        futs, refs = [], []
        for i in range(4):
            dense, mat = _graph(rng, 48)
            h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
            futs.append(eng.submit(mat, h, tag="bad" if i == 2 else None))
            refs.append(dense @ np.asarray(h))
        eng.drain()
        for i, (f, ref) in enumerate(zip(futs, refs)):
            if i == 2:
                with pytest.raises(PoisonRequestError):
                    f.result()
            else:
                np.testing.assert_allclose(f.result(), ref,
                                           rtol=2e-4, atol=2e-4)
        rep = eng.report()
        assert rep["resilience"]["quarantined"] == 1
        assert rep["failed"] == 1 and rep["completed"] == 4
    snap = obs.snapshot()
    assert _counter_total(snap, "resilience_quarantined_total") == 1
    assert _counter_total(snap, "chaos_faults_total") >= 1


def test_transient_fault_retries_and_succeeds(rng):
    plan = FaultPlan([FaultSpec(site="continuous.execute", kind="raise",
                                at=1, times=1)])
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        y = eng.infer(mat, h)
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
        assert eng.report()["failed"] == 0
    assert _counter_total(obs.snapshot(), "resilience_retries_total") >= 1


def test_retries_exhausted_fails_structured(rng):
    """A request whose every execution fails transiently gets a
    structured retries-exhausted error, not a hang or a raw traceback
    from deep inside the executor."""
    plan = FaultPlan([FaultSpec(site="continuous.execute", kind="raise",
                                times=None, match={"tags": "cursed"})])
    # form is pinned so the persistent failure cannot trigger a lane
    # rebuild onto the other form (that path has its own test below)
    with chaos.active(plan), \
            ContinuousBatchEngine(cfg=_cfg(form="csr")) as eng:
        _, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        fut = eng.submit(mat, h, tag="cursed")
        while not fut.done():
            eng.step(force=True)
        with pytest.raises(TransientExecutorError, match="retries exhausted"):
            fut.result()


def test_nan_output_quarantined(rng):
    from repro.resilience import NaNOutputError

    plan = FaultPlan([FaultSpec(site="continuous.output", kind="nan",
                                payload=(0, 0))])
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        fut = eng.submit(mat, h)
        while not fut.done():
            eng.step(force=True)
        with pytest.raises(NaNOutputError):
            fut.result()
        # the engine keeps serving clean traffic afterwards
        y = eng.infer(mat, h)
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
    snap = obs.snapshot()
    assert snap["metrics"]["counters"][
        "resilience_quarantined_total"].get("kind=nan") == 1


def test_latency_spike_is_survived(rng):
    plan = FaultPlan([FaultSpec(site="continuous.execute", kind="delay",
                                payload=0.02, times=2)])
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        y = eng.infer(mat, h)
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
    assert ("continuous.execute", "delay", 1) in plan.events


# ---------------------------------------------------------------------------
# worker supervision, deadlines, shedding
# ---------------------------------------------------------------------------


def test_continuous_worker_death_restarts(rng):
    plan = FaultPlan([FaultSpec(site="continuous.worker", kind="die",
                                at=1, times=1)])
    with chaos.active(plan), \
            ContinuousBatchEngine(cfg=_cfg(background=True,
                                           max_wait_ms=0.5)) as eng:
        import time
        time.sleep(0.05)  # let the first loop iteration die
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        y = eng.infer(mat, h, timeout=30.0)
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
        assert eng.report()["resilience"]["worker_restarts"] == 1
    assert _counter_total(obs.snapshot(),
                          "resilience_worker_restarts_total") == 1


def test_queued_deadline_expires(rng):
    with ContinuousBatchEngine(cfg=_cfg(slots=1)) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        seated = eng.submit(mat, h)                      # takes the slot
        doomed = eng.submit(mat, h, deadline_ms=0.0)     # queued, expired
        while not seated.done():
            eng.step(force=True)
        with pytest.raises(DeadlineExceededError):
            doomed.result(timeout=10)
        assert eng.report()["resilience"]["shed"] == 1
    assert obs.snapshot()["metrics"]["counters"][
        "resilience_shed_total"].get("reason=deadline") == 1


def test_queue_overflow_sheds_lowest_priority(rng):
    with ContinuousBatchEngine(cfg=_cfg(slots=1, queue_depth=1)) as eng:
        dense, mat = _graph(rng, 48)
        h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
        seated = eng.submit(mat, h, priority=1)
        queued = eng.submit(mat, h, priority=1)
        low = eng.submit(mat, h, priority=0)  # over capacity: shed (lowest)
        with pytest.raises(RequestShedError):
            low.result(timeout=10)
        eng.drain()
        for f in (seated, queued):
            np.testing.assert_allclose(f.result(), dense @ np.asarray(h),
                                       rtol=2e-4, atol=2e-4)
        assert eng.report()["resilience"]["shed"] == 1
    assert obs.snapshot()["metrics"]["counters"][
        "resilience_shed_total"].get("reason=queue_full") == 1


def test_degraded_form_rebuilds_lane_on_survivor(rng):
    """A form that keeps failing transiently is degraded; the lane
    rebuilds on the surviving form and the request still completes."""
    dense, mat = _graph(rng, 48)
    h = jnp.asarray(rng.normal(size=(48, D)).astype(np.float32))
    # learn which form the planner picks for this lane
    with ContinuousBatchEngine(cfg=_cfg()) as probe:
        probe.infer(mat, h)
        (lane_info,) = probe.report()["lanes"].values()
    doomed_form = lane_info["form"]
    other = {"ell": "csr", "csr": "ell"}[doomed_form]
    plan = FaultPlan([FaultSpec(site="continuous.execute", kind="raise",
                                times=None, match={"form": doomed_form})])
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        y = eng.infer(mat, h)
        np.testing.assert_allclose(y, dense @ np.asarray(h),
                                   rtol=2e-4, atol=2e-4)
        rep = eng.report()
        (lane_info,) = rep["lanes"].values()
        assert lane_info["form"] == other
        assert any(d.endswith(doomed_form)
                   for d in rep["executor"]["degraded"])
    snap = obs.snapshot()
    assert _counter_total(snap, "resilience_degraded_total") == 1
    assert snap["metrics"]["counters"]["resilience_recoveries_total"].get(
        "site=lane_rebuild") == 1


# ---------------------------------------------------------------------------
# micro-batching engine (BatchServingEngine)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gcn_setup():
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gcn

    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    graphs = [build_graph(random_graph(n, avg_degree=4, seed=n), GCFG)
              for n in (48, 80)]
    return GCFG, params, graphs


def test_batch_engine_worker_death_restarts(gcn_setup):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    plan = FaultPlan([FaultSpec(site="serve.worker", kind="die",
                                at=1, times=1)])
    with chaos.active(plan), BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=4,
                                          max_delay_ms=1.0)) as eng:
        import time
        time.sleep(0.1)  # let the first loop iteration die
        x = jnp.zeros((graphs[0].n_nodes, cfg.in_features), jnp.float32)
        y = eng.infer(graphs[0], x)
        assert y.shape == (graphs[0].n_nodes, cfg.n_classes)
        assert eng.report()["resilience"]["worker_restarts"] == 1


def test_batch_engine_poison_bisection(gcn_setup):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    plan = FaultPlan([FaultSpec(site="serve.flush", kind="poison",
                                times=None, match={"tags": "bad"})])
    scfg = BatchServeConfig(max_batch=4, max_delay_ms=200.0,
                            retry=FAST_RETRY)
    with chaos.active(plan), BatchServingEngine.for_gcn(
            params, scfg=scfg) as eng:
        g = graphs[0]
        x = jnp.zeros((g.n_nodes, cfg.in_features), jnp.float32)
        futs = [eng.submit(g, x, tag="bad" if i == 1 else None)
                for i in range(4)]
        eng.drain(timeout=60)
        for i, f in enumerate(futs):
            if i == 1:
                with pytest.raises(PoisonRequestError):
                    f.result()
            else:
                assert f.result().shape == (g.n_nodes, cfg.n_classes)
        assert eng.report()["resilience"]["quarantined"] == 1


def test_batch_engine_infer_timeout(gcn_setup):
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    cfg, params, graphs = gcn_setup
    # the worker dies immediately and the restart budget is zero: the
    # future can never resolve, so infer() must time out, not hang
    plan = FaultPlan([FaultSpec(site="serve.worker", kind="die",
                                times=None)])
    with chaos.active(plan), BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=2, max_delay_ms=1.0,
                                          max_worker_restarts=0)) as eng:
        import time
        time.sleep(0.05)
        x = jnp.zeros((graphs[0].n_nodes, cfg.in_features), jnp.float32)
        with pytest.raises(DeadlineExceededError):
            eng.infer(graphs[0], x, timeout=0.3)


# ---------------------------------------------------------------------------
# train-loop crash recovery
# ---------------------------------------------------------------------------


def _train_setup():
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import DataConfig, lm_data_iter
    from repro.models.transformer import init_lm
    from repro.train.loop import (TrainConfig, init_train_state,
                                  make_train_step)
    from repro.train.optimizer import OptConfig

    cfg = dataclasses.replace(get_smoke_config("nemotron-4-15b"),
                              dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=5e-3, warmup_steps=0,
                                     total_steps=100))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = make_train_step(cfg, tcfg)
    it = lambda start: lm_data_iter(  # noqa: E731
        cfg, ShapeConfig("t", 32, 4, "train"), DataConfig(seed=9),
        start_step=start)
    return params, state, step, it


def test_train_crash_recovery_reconverges(tmp_path):
    """A chaos-killed step mid-epoch restores from the newest atomic
    checkpoint, replays the data stream, and lands on the same final
    params as the undisturbed run."""
    from repro.ft.checkpoint import Checkpointer
    from repro.train.loop import train_loop

    n_steps = 6
    params, state, step, it = _train_setup()
    base = train_loop(params, state, step, it(0), n_steps, log_every=1)
    assert base["recoveries"] == 0

    params, state, step2, it = _train_setup()
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    plan = FaultPlan([FaultSpec(site="train.step", kind="die", at=5)])
    with chaos.active(plan):
        out = train_loop(params, state, step2, it(0), n_steps, log_every=1,
                         checkpointer=ck, ckpt_every=2, data_factory=it,
                         max_recoveries=2)
    assert out["recoveries"] == 1
    assert ("train.step", "die", 5) in plan.events
    for a, b in zip(jax.tree_util.tree_leaves(base["params"]),
                    jax.tree_util.tree_leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    assert base["history"][-1]["loss"] == pytest.approx(
        out["history"][-1]["loss"], rel=1e-6)
    assert _counter_total(obs.snapshot(),
                          "resilience_recoveries_total") >= 1


def test_train_crash_before_first_checkpoint_restarts_from_init():
    from repro.train.loop import train_loop
    from repro.ft.checkpoint import Checkpointer
    import tempfile

    n_steps = 3
    params, state, step, it = _train_setup()
    base = train_loop(params, state, step, it(0), n_steps, log_every=1)

    params, state, step2, it = _train_setup()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        plan = FaultPlan([FaultSpec(site="train.step", kind="raise", at=2)])
        with chaos.active(plan):
            out = train_loop(params, state, step2, it(0), n_steps,
                             log_every=1, checkpointer=ck, ckpt_every=0,
                             data_factory=it, max_recoveries=1)
    assert out["recoveries"] == 1
    assert base["history"][-1]["loss"] == pytest.approx(
        out["history"][-1]["loss"], rel=1e-6)


# ---------------------------------------------------------------------------
# DeltaGraph background-repack crash safety
# ---------------------------------------------------------------------------


def test_repack_crash_leaves_old_overlay_serving(rng):
    from repro.serve.runtime import DeltaGraph

    dense = np.zeros((32, 32), np.float32)
    dense[rng.random((32, 32)) < 0.2] = 1.0
    g = DeltaGraph(dense, form="csr", slack=0.05)
    plan = FaultPlan([FaultSpec(site="delta.repack", kind="raise",
                                at=1, times=1)])
    with chaos.active(plan):
        # force the build to start (low free slots not required with a
        # high low_water) and crash inside it
        started = g.maybe_repack_async(low_water=1.0)
        assert started
        assert not g.poll_repack(timeout=10.0)  # crashed: nothing swapped
    assert g.report()["repack_failures"] == 1
    # the overlay never stopped serving, and a retry succeeds
    before = g.matrix.to_dense()
    assert g.maybe_repack_async(low_water=1.0)
    assert g.poll_repack(timeout=10.0)
    np.testing.assert_array_equal(np.asarray(before),
                                  np.asarray(g.matrix.to_dense()))
    assert obs.snapshot()["metrics"]["counters"][
        "resilience_recoveries_total"].get("site=delta.repack") == 1


# ---------------------------------------------------------------------------
# fault-storm soak
# ---------------------------------------------------------------------------


def test_fault_storm_strands_nothing(rng):
    """Deterministic storm: poison matched on two tags, a transient
    burst, and latency spikes.  Every future resolves; non-poison
    requests complete exactly once with correct results; the whole
    story is visible in obs.snapshot()."""
    plan = FaultPlan([
        FaultSpec(site="continuous.execute", kind="poison", times=None,
                  match={"tags": "p0"}),
        FaultSpec(site="continuous.execute", kind="poison", times=None,
                  match={"tags": "p1"}),
        FaultSpec(site="continuous.execute", kind="raise", at=4, times=2),
        FaultSpec(site="continuous.execute", kind="delay", payload=0.005,
                  at=8, times=3),
    ], seed=7)
    n_req, poison_at = 20, (3, 11)
    with chaos.active(plan), ContinuousBatchEngine(cfg=_cfg()) as eng:
        futs, refs, tags = [], [], []
        for i in range(n_req):
            n = 48 if i % 3 else 80
            dense, mat = _graph(rng, n)
            h = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
            tag = f"p{poison_at.index(i)}" if i in poison_at else None
            futs.append(eng.submit(mat, h, tag=tag))
            refs.append(dense @ np.asarray(h))
            tags.append(tag)
        eng.drain(timeout=120)
        # zero stranded futures
        assert all(f.done() for f in futs)
        for f, ref, tag in zip(futs, refs, tags):
            if tag is None:
                np.testing.assert_allclose(f.result(), ref,
                                           rtol=2e-4, atol=2e-4)
            else:
                with pytest.raises(PoisonRequestError):
                    f.result()
        rep = eng.report()
        assert rep["completed"] == rep["submitted"] == n_req
        assert rep["pending"] == 0
        assert rep["failed"] == len(poison_at)
        assert rep["resilience"]["quarantined"] == len(poison_at)
    snap = obs.snapshot()
    assert set(snap) == {"metrics", "spans", "sentry", "audit"}
    counters = snap["metrics"]["counters"]
    assert _counter_total(snap, "chaos_faults_total") >= 4
    assert "resilience_quarantined_total" in counters
    # the storm's injected-fault ledger is replayable evidence
    assert len(plan.events) >= 4
    assert all(site.startswith("continuous.") for site, _, _ in plan.events)


# ---------------------------------------------------------------------------
# Supervisor restart race (concurrent ensure must charge one restart)
# ---------------------------------------------------------------------------


class TestSupervisorEnsureRace:
    def _dies_once_then_blocks(self):
        """A worker target that exits instantly on its first life and
        blocks forever afterwards (so the post-restart thread cannot
        die again and muddy the restart count)."""
        import threading as _t
        lives = {"n": 0}
        release = _t.Event()

        def target():
            lives["n"] += 1
            if lives["n"] > 1:
                release.wait()

        return target, release

    def test_concurrent_ensure_restarts_exactly_once(self):
        import threading as _t

        from repro.resilience.supervisor import WorkerSupervisor
        target, release = self._dies_once_then_blocks()
        sup = WorkerSupervisor("race", target, max_restarts=8)
        sup.start()
        sup.join(timeout=5.0)  # first life exits immediately
        assert not sup.alive()
        barrier = _t.Barrier(8)
        results = []

        def racer():
            barrier.wait()
            results.append(sup.ensure())

        threads = [_t.Thread(target=racer) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
        try:
            assert all(results)
            # one death, eight observers, exactly one restart charged
            assert sup.restarts == 1
            assert sup.generation == 2
        finally:
            release.set()

    def test_ensure_with_stale_generation_is_noop(self):
        import threading as _t

        from repro.resilience.supervisor import WorkerSupervisor
        release = _t.Event()
        first = {"done": False}

        def target():
            if not first["done"]:
                first["done"] = True
                return
            release.wait()

        sup = WorkerSupervisor("stale", target, max_restarts=8)
        sup.start()
        sup.join(timeout=5.0)
        assert sup.ensure()  # handles the death: generation 1 -> 2
        assert sup.restarts == 1
        try:
            # an observer that saw generation 1 die reports late: the
            # death was already handled, so nothing is charged
            assert sup.ensure(observed_generation=1)
            assert sup.restarts == 1
            assert sup.generation == 2
        finally:
            release.set()


# ---------------------------------------------------------------------------
# Close/drain under failure (both engines; the fleet's variant lives in
# tests/test_fleet.py)
# ---------------------------------------------------------------------------


class TestCloseDrainUnderFailure:
    def test_continuous_double_close_and_submit_after_close(self, rng):
        from repro.resilience import EngineClosedError
        dense, mat = _graph(rng, 24)
        h = rng.standard_normal((24, D)).astype(np.float32)
        eng = ContinuousBatchEngine(cfg=_cfg())
        fut = eng.submit(mat, h)
        eng.close()
        eng.close()  # idempotent
        assert fut.done() and fut.exception() is None
        with pytest.raises(EngineClosedError):
            eng.submit(mat, h)

    def test_continuous_concurrent_close_resolves_everything(self, rng):
        import threading as _t

        from repro.resilience import EngineClosedError
        _, mat = _graph(rng, 24)
        h = rng.standard_normal((24, D)).astype(np.float32)
        eng = ContinuousBatchEngine(cfg=_cfg(background=True))
        futs = [eng.submit(mat, h) for _ in range(4)]
        closers = [_t.Thread(target=eng.close) for _ in range(3)]
        for t in closers:
            t.start()
        # keep submitting while close races; rejected submissions raise
        for _ in range(8):
            try:
                futs.append(eng.submit(mat, h))
            except EngineClosedError:
                break
        for t in closers:
            t.join(timeout=30.0)
        for f in futs:
            assert f.done()  # a result or EngineClosedError, never a hang

    def test_continuous_close_while_worker_dying(self, rng):
        _, mat = _graph(rng, 24)
        h = rng.standard_normal((24, D)).astype(np.float32)
        eng = ContinuousBatchEngine(cfg=_cfg(background=True))
        with chaos.active(FaultPlan([
                FaultSpec(site="continuous.worker", kind="die",
                          at=1, times=None)], seed=0)):
            futs = [eng.submit(mat, h) for _ in range(4)]
            eng.close()
        for f in futs:
            assert f.done()

    def test_batch_double_close_and_submit_after_close(self, gcn_setup):
        from repro.resilience import EngineClosedError
        from repro.serve.engine import BatchServeConfig, BatchServingEngine
        cfg, params, graphs = gcn_setup
        eng = BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=4, max_delay_ms=1.0))
        x = jnp.zeros((graphs[0].n_nodes, cfg.in_features), jnp.float32)
        fut = eng.submit(graphs[0], x)
        eng.close()
        eng.close()  # idempotent
        assert fut.done() and fut.exception() is None
        with pytest.raises(EngineClosedError):
            eng.submit(graphs[0], x)

    def test_batch_concurrent_close_resolves_everything(self, gcn_setup):
        import threading as _t

        from repro.serve.engine import BatchServeConfig, BatchServingEngine
        cfg, params, graphs = gcn_setup
        eng = BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=4, max_delay_ms=1.0))
        futs = [eng.submit(g, jnp.zeros((g.n_nodes, cfg.in_features),
                                        jnp.float32))
                for g in graphs for _ in range(2)]
        closers = [_t.Thread(target=eng.close) for _ in range(3)]
        for t in closers:
            t.start()
        for t in closers:
            t.join(timeout=30.0)
        for f in futs:
            assert f.done()
