"""Matrix-corpus generators + structure-aware dispatch.

Covers the structured-matrix corpus (determinism, realized sparsity,
feature discrimination), the cross-form stats-granularity regression
(the same matrix must produce the same stats — and therefore the same
plan — whichever storage form the stats were measured from), and the
acceptance property that the auto policy picks *different* execution
paths for matrices of equal global sparsity but different structure.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.formats import CSR, BlockELL
from repro.corpus import (CorpusSpec, FAMILIES, default_corpus, make_dense,
                          make_matrix)
from repro.dispatch.dispatcher import plan_spmm, plan_spmv
from repro.dispatch.stats import MatrixStats

FULL = ("ell", "sell", "csr", "dense")
LEGACY = ("ell", "csr", "dense")  # the GNN Graph candidate set


def _stats(family, sparsity, shape=(512, 512), block=(4, 4), **kw):
    spec = CorpusSpec(family=family, shape=shape, sparsity=sparsity, **kw)
    return MatrixStats.from_csr(CSR.from_dense(make_dense(spec)),
                                block[0], block[1])


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_generators_deterministic_under_seed(family):
    spec = CorpusSpec(family=family, shape=(128, 128), sparsity=0.9, seed=3)
    np.testing.assert_array_equal(make_dense(spec), make_dense(spec))
    other = dataclasses.replace(spec, seed=4)
    assert (make_dense(spec) != make_dense(other)).any()


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("sparsity", [0.9, 0.99])
def test_realized_sparsity_matches_request(family, sparsity):
    spec = CorpusSpec(family=family, shape=(256, 256), sparsity=sparsity)
    nnz = np.count_nonzero(make_dense(spec))
    # block_pruned rounds to whole tiles; everything else is exact-count
    tol = 8 * 8 // 2 if family == "block_pruned" else 0
    assert abs(nnz - spec.target_nnz) <= tol, (family, nnz, spec.target_nnz)


def test_banded_capacity_clamp():
    # a 4-wide band cannot hold 50% density: the generator fills the
    # whole band and stops instead of scattering out-of-band nonzeros
    spec = CorpusSpec(family="banded", shape=(64, 64), sparsity=0.5,
                      band_width=4)
    a = make_dense(spec)
    i, j = np.nonzero(a)
    assert np.abs(i - j).max() <= 4
    assert np.count_nonzero(a) < spec.target_nnz  # clamped, not scattered


def test_banded_diagonal_dominance():
    a = make_dense(CorpusSpec(family="banded", shape=(128, 128),
                              sparsity=0.9, band_width=8))
    d = np.abs(np.diag(a))
    off = np.abs(a).sum(axis=1) - d
    assert (d[d > 0] > off[d > 0]).all()


def test_block_pruned_structure_is_whole_tiles():
    spec = CorpusSpec(family="block_pruned", shape=(64, 64), sparsity=0.9,
                      block=(8, 8))
    a = make_dense(spec)
    tiles = a.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3).reshape(64, -1)
    tile_nnz = (tiles != 0).sum(axis=1)
    # every tile is either fully dense or fully zero
    assert set(np.unique(tile_nnz)) <= {0, 64}


def test_structure_features_discriminate_families():
    s = {f: _stats(f, 0.99, shape=(256, 256), block=(1, 1))
         for f in ("uniform", "powerlaw", "banded")}
    # hub skew: powerlaw rows are far more uneven than uniform rows
    assert s["powerlaw"].row_nnz_cv > 1.0 > s["uniform"].row_nnz_cv
    assert s["powerlaw"].max_row_nnz > 4 * s["uniform"].max_row_nnz
    # band locality: banded |i-j| stays near the diagonal, uniform p95
    # of the normalized diagonal distance sits near 0.78
    assert s["banded"].bandwidth_frac < 0.15 < s["uniform"].bandwidth_frac


def test_default_corpus_covers_every_family():
    specs = default_corpus(quick=True)
    assert {sp.family for sp in specs} == set(FAMILIES)
    assert {sp.sparsity for sp in specs} == {0.9, 0.99}


def test_make_matrix_executes_against_dense_oracle(rng):
    for family in ("powerlaw", "banded"):
        spec = CorpusSpec(family=family, shape=(128, 128), sparsity=0.95)
        a = make_dense(spec)
        mat = make_matrix(spec, block=(8, 8))
        h = rng.normal(size=(128, 16)).astype(np.float32)
        np.testing.assert_allclose(np.asarray(mat @ h), a @ h,
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Cross-form stats granularity (regression: the from_csr/from_blockell
# disagreement made the same matrix plan differently per storage form)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["uniform", "powerlaw", "banded"])
def test_stats_agree_across_storage_forms(family):
    a = make_dense(CorpusSpec(family=family, shape=(128, 128),
                              sparsity=0.97))
    s_ell = MatrixStats.from_blockell(BlockELL.from_dense(a, 32, 32))
    s_csr = MatrixStats.from_csr(CSR.from_dense(a), 32, 32)
    for field in ("shape", "nnz", "stored_elements", "block_m", "block_n",
                  "n_block_rows", "ell_width", "max_row_nnz",
                  "sell_stored_elements"):
        assert getattr(s_ell, field) == getattr(s_csr, field), field
    for field in ("occupancy", "row_nnz_mean", "row_nnz_cv",
                  "bandwidth_frac"):
        np.testing.assert_allclose(getattr(s_ell, field),
                                   getattr(s_csr, field), rtol=1e-12,
                                   err_msg=field)
    # same stats => same plan, whichever form the stats came from
    assert plan_spmm(s_ell, 64, candidates=FULL).path \
        == plan_spmm(s_csr, 64, candidates=FULL).path


def test_from_csr_hub_row_prices_ell_stream_honestly():
    """Pre-fix, csr-built stats priced the ELL stream at raw nnz, so a
    single hub row — which forces the global ELL width to the full row
    — still auto-planned ell from csr stats."""
    a = np.zeros((256, 256), np.float32)
    a[3, :] = 1.0  # one full hub row
    s = MatrixStats.from_csr(CSR.from_dense(a))
    assert s.max_row_nnz == 256
    # element-granular ELL width is the heaviest row: M * max_row_nnz
    assert s.ell_stream_estimate >= 256 * 256
    assert plan_spmm(s, 64, candidates=LEGACY).path != "ell"


def test_all_zero_from_csr_stats_are_empty_and_plannable():
    s = MatrixStats.from_csr(CSR.from_dense(np.zeros((64, 64), np.float32)))
    assert s.nnz == 0 and s.max_row_nnz == 0
    assert s.row_nnz_cv == 0.0 and s.bandwidth_frac == 0.0
    assert plan_spmm(s, 16, candidates=FULL).path in FULL


# ---------------------------------------------------------------------------
# Structure-aware dispatch (acceptance: equal sparsity, different path)
# ---------------------------------------------------------------------------


def test_auto_path_diverges_on_structure_at_equal_sparsity():
    """Equal global sparsity, different row structure => the cost model
    picks different execution paths (the PR's acceptance property)."""
    uni99 = plan_spmm(_stats("uniform", 0.99), 64, candidates=FULL).path
    hub99 = plan_spmm(_stats("powerlaw", 0.99), 64, candidates=FULL).path
    assert uni99 != hub99
    assert (uni99, hub99) == ("sell", "csr")
    uni90 = plan_spmm(_stats("uniform", 0.9), 64, candidates=FULL).path
    hub90 = plan_spmm(_stats("powerlaw", 0.9), 64, candidates=FULL).path
    assert uni90 != hub90


def test_hub_heavy_powerlaw_prefers_sell():
    """Moderately hub-heavy rows (high CV, hubs short of a full row):
    the load-balanced sell packing wins where global-width ell pays the
    hub tax on every row and csr gives up the streaming discount."""
    stats = _stats("powerlaw", 0.99, alpha=0.6)
    assert stats.row_nnz_cv > 1.0  # genuinely hub-heavy
    assert plan_spmm(stats, 64, candidates=FULL).path == "sell"


def test_banded_legacy_candidates_prefer_csr():
    """Without the sell form (the legacy GNN candidate set), a wide
    hyper-sparse band still escapes the blocked path: its diagonal
    block structure leaves most ELL slots padding."""
    stats = _stats("banded", 0.99, band_width=64)
    assert plan_spmm(stats, 64, candidates=LEGACY).path == "csr"


def test_spmv_plans_on_unit_width_surface():
    """At d=1 the streaming discount shrinks: a matrix that streams for
    SpMM can tip to the exact-nnz path for SpMV."""
    stats = _stats("uniform", 0.99)
    p_spmm = plan_spmm(stats, 64, candidates=FULL)
    p_spmv = plan_spmv(stats, candidates=FULL)
    assert p_spmv.op == "spmv"
    assert p_spmv.path in FULL
    # same cost surface at d=1: identical relative costs, scaled
    np.testing.assert_allclose(
        p_spmv.costs["csr"] / max(p_spmv.costs["dense"], 1),
        p_spmm.costs["csr"] / max(p_spmm.costs["dense"], 1), rtol=1e-9)
