"""SSD (mamba2) and RG-LRU recurrence correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.mamba2_2_7b import SMOKE_CONFIG as MAMBA_CFG
from repro.configs.recurrentgemma_2b import SMOKE_CONFIG as RG_CFG
from repro.models.rglru import (init_rglru, init_rglru_cache,
                                rglru_decode_step, rglru_forward)
from repro.models.ssm import (init_ssm, init_ssm_cache, ssd_chunked,
                              ssm_decode_step, ssm_forward)


def _naive_ssd(xh, dt, a_log, bm, cm):
    b, s, h, p = xh.shape
    a = -np.exp(np.asarray(a_log))
    st_ = np.zeros((b, h, p, bm.shape[-1]), np.float64)
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        da = np.exp(np.asarray(dt[:, t]) * a)
        st_ = st_ * da[..., None, None] + np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt[:, t]), np.asarray(xh[:, t]),
            np.asarray(bm[:, t]))
        ys[:, t] = np.einsum("bhpn,bn->bhp", st_, np.asarray(cm[:, t]))
    return ys, st_


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_vs_sequential(rng, chunk):
    B, S, H, P, N = 2, 64, 4, 16, 16
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)).astype(np.float32))
    a_log = jnp.asarray(np.log(rng.uniform(1, 8, H)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    y_ref, st_ref = _naive_ssd(xh, dt, a_log, bm, cm)
    y, st_ = ssd_chunked(xh, dt, a_log, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_), st_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 48]), chunk=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_ssd_chunked_property(s, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, P, N = 1, 2, 8, 8
    xh = jnp.asarray(rng.normal(size=(B, s, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (B, s, H)).astype(np.float32))
    a_log = jnp.asarray(np.log(rng.uniform(1, 8, H)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(B, s, N)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(B, s, N)).astype(np.float32))
    y_ref, _ = _naive_ssd(xh, dt, a_log, bm, cm)
    y, _ = ssd_chunked(xh, dt, a_log, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


def test_ssm_block_decode_equivalence(rng):
    cfg = MAMBA_CFG
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    out, final = ssm_forward(p, x, cfg, return_state=True)
    cache = init_ssm_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = ssm_decode_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(out), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(final["state"]), rtol=5e-4,
                               atol=5e-4)


def test_rglru_decode_and_continuation(rng):
    cfg = RG_CFG
    p = init_rglru(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    y, st_ = rglru_forward(p, x, cfg, return_state=True)
    cache = init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = rglru_decode_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y), rtol=2e-4, atol=2e-4)
    y1, s1 = rglru_forward(p, x[:, :8], cfg, return_state=True)
    y2 = rglru_forward(p, x[:, 8:], cfg, h0=s1["h"], conv_tail=s1["conv"])
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y),
        rtol=2e-4, atol=2e-4)


def test_rglru_decay_bounded(rng):
    """Property: the gated decay a_t stays in (0, 1] — stability."""
    cfg = RG_CFG
    p = init_rglru(jax.random.PRNGKey(0), cfg)
    from repro.models.rglru import _gates
    u = jnp.asarray(rng.normal(size=(2, 8, cfg.lru_width)) * 10,
                    jnp.float32)
    a, _ = _gates(p, u)
    assert float(a.min()) > 0.0 and float(a.max()) <= 1.0
