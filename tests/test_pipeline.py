"""GPipe pipeline over a mesh axis — subprocess with 4 fake devices."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax, jax.numpy as jnp
    from repro.train.pipeline import pipeline_apply

    from repro.sharding.specs import make_mesh
    mesh = make_mesh((4,), ("pod",))
    rng = np.random.default_rng(0)
    n_stages, n_micro, mb, d = 4, 8, 4, 16

    ws = jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32)
                     / np.sqrt(d))

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))
    out = pipeline_apply(stage_fn, ws, x, mesh, axis="pod")

    # reference: sequential application of all stages
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("pipeline OK")

    # gradient flows through the pipeline
    def loss(ws):
        return pipeline_apply(stage_fn, ws, x, mesh, axis="pod").sum()
    g = jax.grad(loss)(ws)
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).max()) > 0
    print("pipeline grad OK")
""")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "pipeline OK" in out.stdout
    assert "pipeline grad OK" in out.stdout
