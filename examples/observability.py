"""Observability quickstart: one serving run, one snapshot.

Drives a small adaptive serving workload through
``BatchServingEngine`` and shows everything ``repro.obs`` collected
along the way — dispatcher plan counts, per-lane compiles vs calls
(the retrace sentry), padding waste, serve latency percentiles, span
timings for each stage of the serve path, and the cost-model audit's
predicted-vs-measured rows.

Run from the repo root::

    PYTHONPATH=src python examples/observability.py
"""
import json

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.engine import BatchServeConfig, BatchServingEngine
from repro.sparse import SparseMatrix

BLOCK = (16, 16)
D = 16


def main() -> None:
    obs.reset()                       # scope the instruments to this run
    rng = np.random.default_rng(0)

    with BatchServingEngine(
            scfg=BatchServeConfig(max_batch=8, adaptive=True)) as eng, \
            obs.span("example.serve_mixed_traffic"):
        futs = []
        for _ in range(24):
            n = int(rng.choice((48, 48, 64, 96)))   # shape-skewed traffic
            dense = np.where(rng.random((n, n)) < 0.08,
                             rng.normal(size=(n, n)), 0.0).astype(np.float32)
            dense[0, 0] = dense[0, 0] or 1.0
            mat = SparseMatrix.from_dense(dense, formats=("ell", "csr"),
                                          block=BLOCK)
            h = jnp.asarray(
                rng.normal(size=(n, D)).astype(np.float32))
            futs.append(eng.submit(mat, h))
        eng.drain()
        for f in futs:
            f.result(timeout=60)
        rep = eng.report()

    # -- the engine's own view (canonical keys) -----------------------------
    print(f"served {rep['completed']} requests | "
          f"p50 {rep['p50_ms']:.1f} ms, p99 {rep['p99_ms']:.1f} ms | "
          f"compiles {rep['executor']['compiles']} | "
          f"waste {rep['executor']['waste']['waste_fraction']:.0%}")

    # -- one coherent snapshot of everything --------------------------------
    snap = obs.snapshot()
    c = snap["metrics"]["counters"]
    print("\nplans by (op, path, policy):")
    for labels, count in sorted(c["dispatch_plans_total"].items()):
        print(f"  {labels}: {count}")
    print("\ncompiles vs calls per executor lane:")
    for lane, compiles in sorted(c["executor_compiles_total"].items()):
        calls = c["executor_calls_total"].get(lane, 0)
        print(f"  {lane}: {compiles} compile(s), {calls} call(s)")
    print(f"\nunexpected retraces: "
          f"{snap['sentry']['unexpected_retraces']}")

    print("\nserve-path span timings:")
    for name, s in sorted(snap["spans"].items()):
        print(f"  {name}: n={s['count']} p50={s['p50_ms']:.2f}ms "
              f"max={s['max_ms']:.2f}ms")

    print("\ncost audit (predicted vs measured, per op/path/bucket):")
    for cell, agg in snap["audit"]["summary"].items():
        print(f"  {cell}: n={agg['n']} "
              f"measured_mean={agg['measured_ms_mean']}ms "
              f"predicted_mean={agg['predicted_mean']}")
    if snap["audit"]["mispredictions"]:
        print("  model mispredicted:",
              json.dumps(snap["audit"]["mispredictions"], indent=2))

    # -- exporters -----------------------------------------------------------
    prom = obs.to_prometheus()
    print(f"\nprometheus exposition: {len(prom.splitlines())} lines, e.g.")
    for line in prom.splitlines()[:6]:
        print(f"  {line}")
    print(f"jsonl export: {len(obs.to_jsonl().splitlines())} records")


if __name__ == "__main__":
    main()
