"""End-to-end LM training driver: ~100M-param model, few hundred steps.

Builds a gemma3-family model (5:1 local:global — the local layers run the
paper's banded block-sparse attention) scaled to ~100M params, and trains
it on the deterministic synthetic stream with the full production stack:
AdamW + cosine, grad accumulation, async checkpointing, straggler
monitor.

Usage:
  PYTHONPATH=src python examples/lm_train.py --steps 300
  PYTHONPATH=src python examples/lm_train.py --steps 50 --arch granite-20b
"""
import argparse
import dataclasses
import os

import jax

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, lm_data_iter
from repro.ft.checkpoint import Checkpointer
from repro.ft.health import StragglerDetector
from repro.models.transformer import init_lm
from repro.train.loop import (TrainConfig, init_train_state, make_train_step,
                              train_loop)
from repro.train.optimizer import OptConfig

# ~100M params: 8 layers x d512 x ff2048, 32k vocab, 5:1 local:global
LM100M = ModelConfig(
    name="lm100m-local-global",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window=256,
    attn_block=128,
    act="gelu",
    tie_embeddings=True,
    dtype="float32",
    long_context_ok=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default=None,
                    help="use a reduced assigned-arch config instead")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = LM100M if args.arch is None else dataclasses.replace(
        get_smoke_config(args.arch), dtype="float32")
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=2 if args.batch % 2 == 0 else 1)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = make_train_step(cfg, tcfg)
    data = lm_data_iter(cfg, shape, DataConfig(seed=0))

    os.makedirs(args.ckpt_dir, exist_ok=True)
    ck = Checkpointer(args.ckpt_dir, keep=2)
    det = StragglerDetector()

    def cb(i, params, state, metrics):
        if i % 20 == 0:
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"|g| {float(metrics['grad_norm']):.3f}")

    out = train_loop(params, state, step, data, args.steps,
                     checkpointer=ck, ckpt_every=100, health=det,
                     callback=cb)
    ck.wait()
    hist = out["history"]
    print(f"\nloss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} over "
          f"{args.steps} steps; median step {det.median:.3f}s; "
          f"checkpoints at {ck.all_steps()}")


if __name__ == "__main__":
    main()
