"""Fleet demo: a multi-worker serving fleet surviving a worker kill
mid-storm.

Brings up a :class:`~repro.serve.fleet.ServingFleet` (thread-backed
workers by default; ``--backend process`` spawns real OS processes that
die by SIGKILL), arms a seed-driven :class:`FaultPlan` that hard-kills
one worker at its Nth dispatch and delays a few heartbeats, then drives
a request storm through the outage and prints what happened: every
request completes with the correct result (the dead worker's in-flight
is re-routed from the router journal, exactly once), the supervisor
respawns the victim inside its restart budget, and the ``fleet_*``
recovery counters tell the story straight from ``obs.snapshot()``.

Run from the repo root::

    PYTHONPATH=src python examples/fleet_serving.py
    PYTHONPATH=src python examples/fleet_serving.py --soak --seed 13
    PYTHONPATH=src python examples/fleet_serving.py --backend process
"""
import argparse
import json

import numpy as np

from repro import obs
from repro.resilience import chaos
from repro.resilience.chaos import FaultPlan, FaultSpec
from repro.serve.fleet import FleetConfig, ServingFleet

D = 8


def _request(rng, n):
    dense = (rng.random((n, n)) < 0.1).astype(np.float32)
    h = rng.standard_normal((n, D)).astype(np.float32)
    return dense, h


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--soak", action="store_true",
                    help="60-request storm instead of 16")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--backend", default="thread",
                    choices=("thread", "process"))
    ap.add_argument("--workers", type=int, default=3)
    args = ap.parse_args()

    obs.reset()
    chaos.uninstall()
    rng = np.random.default_rng(args.seed)
    n_req = 60 if args.soak else 16
    sizes = (24, 32, 48)
    victim = f"w{min(2, args.workers)}"

    # the storm: the victim is SIGKILLed (process backend) / hard-killed
    # (thread backend) right after its 3rd dispatch lands — so it dies
    # with requests in flight — while heartbeats across the fleet get
    # delayed enough to exercise the late-beat path without tripping
    # the missed-heartbeat detector
    storm = [
        FaultSpec(site="fleet.worker", kind="kill_proc", at=3,
                  match={"worker": victim, "phase": "dispatch"}),
        FaultSpec(site="fleet.heartbeat", kind="delay", payload=0.04,
                  at=4, times=3),
    ]
    if args.soak and args.workers >= 2:
        # soak also hangs a second worker outright: it stops beating,
        # the missed-heartbeat detector declares it dead, and the
        # supervisor respawns it — the other half of the failure matrix
        storm.append(FaultSpec(site="fleet.worker", kind="hang",
                               payload=60.0, at=2,
                               match={"worker": "w1",
                                      "phase": "monitor"}))
    plan = FaultPlan(storm, seed=args.seed)

    cfg = FleetConfig(backend=args.backend, workers=args.workers,
                      hedge_after_ms=10_000.0, max_restarts_per_worker=2)
    stranded = wrong = ok = 0
    with ServingFleet(cfg) as fleet:
        up = fleet.wait_live(args.workers, timeout=300.0)
        assert up, f"fleet of {args.workers} never came up"
        # warm the lanes before arming the plan so the kill lands on a
        # serving worker, not a compiling one
        for n in sizes:
            fleet.infer(*_request(rng, n), timeout=300.0)

        chaos.install(plan)
        try:
            futs, refs = [], []
            for _ in range(n_req):
                dense, h = _request(rng, int(rng.choice(sizes)))
                futs.append(fleet.submit(dense, h))
                refs.append(dense @ h)
            for f, ref in zip(futs, refs):
                try:
                    out = f.result(timeout=300.0)
                except Exception:
                    wrong += 1  # resolved with an error, not stranded
                    continue
                if np.allclose(out, ref, rtol=2e-4, atol=2e-4):
                    ok += 1
                else:
                    wrong += 1
            stranded += sum(1 for f in futs if not f.done())
            rep = fleet.report()
        finally:
            chaos.uninstall()

    print(f"== worker-kill storm: {n_req} requests over "
          f"{args.workers} {args.backend} workers ==")
    print(f"completed correctly : {ok}")
    print(f"wrong/failed        : {wrong}")
    print(f"stranded futures    : {stranded}")
    print(f"requests lost       : {rep['fleet']['requests_lost']}")
    assert stranded == 0, "fleet contract: no future may strand"
    assert wrong == 0, "fleet contract: every request completes correctly"
    assert rep["fleet"]["requests_lost"] == 0

    print("\n== injected faults (plan.events) ==")
    for site, kind, hit in plan.events[:12]:
        print(f"  {site:18s} {kind:10s} hit #{hit}")
    if len(plan.events) > 12:
        print(f"  ... {len(plan.events) - 12} more")

    print("\n== worker states after recovery ==")
    for name, w in rep["workers"].items():
        print(f"  {name}: {w['status']} (generation {w['generation']}, "
              f"restarts {w['restarts']}, served {w['served']})")

    print("\n== fleet recovery counters (obs.snapshot) ==")
    counters = obs.snapshot()["metrics"]["counters"]
    for name in sorted(counters):
        if name.startswith(("fleet_", "chaos_")):
            for labels, v in counters[name].items():
                print(f"  {name}{{{labels}}} = {v}")
    print(f"\np50={rep['p50_ms']:.2f}ms p99={rep['p99_ms']:.2f}ms "
          f"over {rep['completed']} requests")
    print(json.dumps({"fleet": rep["fleet"]}, indent=2, default=str))


if __name__ == "__main__":
    main()
