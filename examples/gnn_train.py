"""End-to-end GNN training — the paper's driving application.

Trains a 3-layer GCN (hidden 128, feature dim 256 — the paper's §4.1
setting) and a GAT (SDDMM attention with d=2 per §4.4) on a synthetic
random graph, full-batch, on CPU.

Usage:  PYTHONPATH=src python examples/gnn_train.py [--kind gat] [--n 512]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gnn import CONFIG as GCFG
from repro.data.pipeline import random_graph
from repro.models.gnn import (build_graph, gat_forward, gcn_forward,
                              init_gat, init_gcn)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="gcn", choices=("gcn", "gat"))
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    adj = random_graph(args.n, avg_degree=8, seed=1)
    graph = build_graph(adj, GCFG)
    print(f"graph: {args.n} nodes, {int(adj.sum())} edges; "
          f"adjacency {graph.adj} "
          f"(Block-ELL occupancy {graph.ell.occupancy():.2f})")

    x = jnp.asarray(rng.normal(size=(args.n, GCFG.in_features))
                    .astype(np.float32))
    # planted community labels so the task is learnable
    labels = jnp.asarray((np.arange(args.n) * GCFG.n_classes // args.n)
                         .astype(np.int32))

    if args.kind == "gcn":
        params = init_gcn(jax.random.PRNGKey(0), GCFG)
        fwd = gcn_forward
    else:
        params = init_gat(jax.random.PRNGKey(0), GCFG)
        fwd = gat_forward

    def loss_fn(params):
        logits = fwd(params, graph, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], 1).mean()
        acc = (logits.argmax(-1) == labels).mean()
        return nll, acc

    @jax.jit
    def step(params):
        (l, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params = jax.tree_util.tree_map(
            lambda p, gg: p - args.lr * gg, params, g)
        return params, l, acc

    t0 = time.time()
    for i in range(args.steps):
        params, l, acc = step(params)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(l):.4f}  acc {float(acc):.3f}")
    print(f"{args.kind} trained {args.steps} steps in "
          f"{time.time() - t0:.1f}s")

    from repro.dispatch import last_plan
    from repro.sparse import plan_cache_stats
    plan = last_plan("spmm")
    print(f"aggregation dispatch: {plan.describe()}; "
          f"plan cache {plan_cache_stats()}")


if __name__ == "__main__":
    main()
