"""Quickstart: the paper's two kernels through the public API.

Runs on CPU in seconds:
  1. build a random sparse matrix (the paper's synthetic workload),
  2. SpMM  Y = A @ H   via Block-ELL (SELLPACK-like) format,
  3. SDDMM Y = A ⊙ (B @ C) via Block-COO,
  4. the same SpMM distributed 1.5D over a local mesh.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.formats import BlockELL, BlockCOO, CSR, \
    sellpack_stream_elements
from repro.core.spmm import spmm
from repro.core.sddmm import sddmm
from repro.data.pipeline import random_sparse_dense


def main():
    n, d, density = 1024, 256, 0.05
    print(f"== SpMM: N={n}, D={d}, density={density} ==")
    a_dense = random_sparse_dense(n, density, seed=0)
    h = random_sparse_dense(n, 1.0, seed=1)[:, :d].copy()

    ell = BlockELL.from_dense(a_dense, bm=64, bn=64)
    print(f"Block-ELL: {ell.n_block_rows} block-rows x W={ell.ell_width}, "
          f"occupancy {ell.occupancy():.2f}")
    y = spmm(ell, jnp.asarray(h), use_kernel=False)  # CPU jnp path
    err = np.abs(np.asarray(y) - a_dense @ h).max()
    print(f"SpMM max|err| vs dense = {err:.2e}")

    # the TPU Pallas kernel, executed in interpret mode for validation
    y_k = spmm(ell, jnp.asarray(h), interpret=True)
    print(f"Pallas kernel (interpret) max|err| = "
          f"{np.abs(np.asarray(y_k) - a_dense @ h).max():.2e}")

    print("\n== footprint (paper Fig. 8) ==")
    csr = CSR.from_dense(a_dense)
    streamed = sellpack_stream_elements(csr, max_y_chunk=256,
                                        max_v_per_pe=64)
    print(f"CSR nnz = {csr.nnz}; SELLPACK-like streamed elements = "
          f"{streamed} (ratio {streamed / csr.nnz:.2f})")

    print(f"\n== SDDMM: N={n}, K=2 (the paper's GAT case) ==")
    mask = (random_sparse_dense(n, density, seed=2) != 0).astype(np.float32)
    b = random_sparse_dense(n, 1.0, seed=3)[:, :2].copy()
    c = random_sparse_dense(n, 1.0, seed=4, m=2).copy()  # [2, n]
    coo = BlockCOO.from_dense(mask, bm=64, bn=64)
    out = sddmm(coo, jnp.asarray(b), jnp.asarray(c), use_kernel=False)
    err = np.abs(out.to_dense() - mask * (b @ c)).max()
    print(f"SDDMM max|err| vs dense = {err:.2e} "
          f"(computed only {coo.nnzb}/{(n // 64) ** 2} blocks)")

    print("\n== distributed 1.5D SpMM (paper §2.4) ==")
    n_dev = len(jax.devices())
    if n_dev >= 4:
        from repro.core.distributed import spmm_1p5d
        from repro.sharding.specs import make_mesh
        mesh = make_mesh((2, n_dev // 2), ("data", "model"))
        y_d = spmm_1p5d(ell, jnp.asarray(h), mesh)
        print(f"1.5D max|err| = "
              f"{np.abs(np.asarray(y_d) - a_dense @ h).max():.2e}")
    else:
        print(f"only {n_dev} device(s); run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "to exercise the mesh path")


if __name__ == "__main__":
    main()
