"""Quickstart: the paper's two kernels through the unified public API.

Everything goes through ``repro.sparse.SparseMatrix`` — one array type
over the CSR / Block-ELL / Block-COO formats with operator dispatch,
plan caching, and gradients:

  1. build a SparseMatrix from a random sparse operand (format chosen
     from its measured structure),
  2. SpMM  Y = A @ H       — routed by the sparsity-adaptive dispatcher,
  3. SDDMM via sample(A, B, C) — computed only at A's nonzeros,
  4. gradients: jax.grad through A @ H — SpMM's backward *is* SDDMM
     (and vice versa), the paper's kernels closing the training loop,
  5. the same SpMM distributed 1.5D over a local mesh,
  6. batched serving: many small graphs composed block-diagonally and
     served through the shape-bucketed micro-batching engine.

Runs on CPU in seconds:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import random_sparse_dense
from repro.dispatch import last_plan
from repro.sparse import SparseMatrix, matmul, plan_cache_stats, sample


def main():
    n, d, density = 1024, 256, 0.05
    print(f"== SpMM: N={n}, D={d}, density={density} ==")
    a_dense = random_sparse_dense(n, density, seed=0)
    h = jnp.asarray(random_sparse_dense(n, 1.0, seed=1)[:, :d].copy())

    A = SparseMatrix.from_dense(a_dense, format="auto")
    print(f"A = {A}  (format chosen from measured structure; "
          f"occupancy {A.stats.occupancy:.2f})")
    y = A @ h
    plan = last_plan("spmm")
    err = np.abs(np.asarray(y) - a_dense @ np.asarray(h)).max()
    print(f"A @ h -> path={plan.path} [{plan.reason[:40]}...]  "
          f"max|err| vs dense = {err:.2e}")

    # repeated calls hit the per-instance plan cache (no re-planning)
    for _ in range(3):
        A @ h
    print(f"plan cache after 4 calls: {plan_cache_stats()}")

    # the blocked form + TPU Pallas kernel, in interpret mode for
    # validation (.to() converts between formats on demand)
    A_ell = A.to("ell")
    y_k = matmul(A_ell, h, policy="ell", interpret=True)
    print(f"Pallas kernel (interpret) max|err| = "
          f"{np.abs(np.asarray(y_k) - a_dense @ np.asarray(h)).max():.2e}")

    print(f"\n== SDDMM: N={n}, K=2 (the paper's GAT case) ==")
    mask = (random_sparse_dense(n, density, seed=2) != 0).astype(np.float32)
    b = jnp.asarray(random_sparse_dense(n, 1.0, seed=3)[:, :2].copy())
    c = jnp.asarray(random_sparse_dense(n, 1.0, seed=4, m=2).copy())
    M = SparseMatrix.from_dense(mask, format="coo")
    s = sample(M, b, c)  # = M ⊙ (b @ c), only at M's nonzero blocks
    err = np.abs(s.to_dense() - mask * np.asarray(b @ c)).max()
    print(f"sample(M, b, c) max|err| vs dense = {err:.2e} "
          f"(path={last_plan('sddmm').path})")

    print("\n== gradients: the kernels are each other's backward ==")

    def loss(vals, hh):
        return jnp.sum(jnp.tanh(A.with_data(vals) @ hh))

    gv, gh = jax.grad(loss, argnums=(0, 1))(A.data, h)
    from repro.dispatch import dispatch_log
    vjp_ops = [(p.op, p.path) for p in dispatch_log() if p.policy == "vjp"]
    print(f"grad(A-values) shape {gv.shape}, grad(H) shape {gh.shape}; "
          f"backward ran: {vjp_ops[-2:]}  "
          "(dH is an SpMM on Aᵀ, dA is an SDDMM on A's pattern)")

    print("\n== distributed 1.5D SpMM (paper §2.4) ==")
    n_dev = len(jax.devices())
    if n_dev >= 4:
        from repro.core.distributed import spmm_1p5d
        from repro.sharding.specs import make_mesh
        mesh = make_mesh((2, n_dev // 2), ("data", "model"))
        y_d = spmm_1p5d(A_ell, h, mesh)  # accepts the SparseMatrix directly
        print(f"1.5D max|err| = "
              f"{np.abs(np.asarray(y_d) - a_dense @ np.asarray(h)).max():.2e}")
    else:
        print(f"only {n_dev} device(s); run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "to exercise the mesh path")

    print("\n== batched multi-graph serving (block-diag + buckets) ==")
    from repro.batch import BatchedSparseMatrix
    from repro.configs.paper_gnn import SMOKE_CONFIG as GCFG
    from repro.data.pipeline import random_graph
    from repro.models.gnn import build_graph, init_gcn
    from repro.serve.engine import BatchServeConfig, BatchServingEngine

    rng = np.random.default_rng(0)
    graphs = [build_graph(random_graph(nn, avg_degree=4, seed=nn), GCFG)
              for nn in (48, 80, 33)]
    # three graphs -> one block-diagonal operand -> ONE planned SpMM
    B = BatchedSparseMatrix.from_matrices([g.adj for g in graphs])
    hs = [jnp.asarray(rng.normal(size=(g.n_nodes, d)).astype(np.float32))
          for g in graphs]
    ys = B.unbatch(B @ B.batch_features(hs))
    print(f"{B}: per-graph outputs {[tuple(y.shape) for y in ys]}")

    params = init_gcn(jax.random.PRNGKey(0), GCFG)
    with BatchServingEngine.for_gcn(
            params, scfg=BatchServeConfig(max_batch=8,
                                          max_delay_ms=2.0)) as eng:
        futs = [eng.submit(graphs[i % 3],
                           rng.normal(size=(graphs[i % 3].n_nodes,
                                            GCFG.in_features))
                           .astype(np.float32))
                for i in range(16)]
        logits = [f.result() for f in futs]
        eng.drain()
        rep = eng.report()
    print(f"served {rep['completed']} mixed-shape requests: "
          f"{rep['req_per_s']:.0f} req/s, "
          f"p50 {rep['p50_ms']:.1f} ms, "
          f"compiles {rep['executor']['compiles']} "
          f"(buckets {rep['executor']['buckets']}), "
          f"padding waste {rep['executor']['waste']['waste_fraction']:.0%}")


if __name__ == "__main__":
    main()
