"""Quickstart: the paper's two kernels through the unified public API.

Everything goes through ``repro.sparse.SparseMatrix`` — one array type
over the CSR / Block-ELL / Block-COO formats with operator dispatch,
plan caching, and gradients:

  1. build a SparseMatrix from a random sparse operand (format chosen
     from its measured structure),
  2. SpMM  Y = A @ H       — routed by the sparsity-adaptive dispatcher,
  3. SDDMM via sample(A, B, C) — computed only at A's nonzeros,
  4. gradients: jax.grad through A @ H — SpMM's backward *is* SDDMM
     (and vice versa), the paper's kernels closing the training loop,
  5. the same SpMM distributed 1.5D over a local mesh.

Runs on CPU in seconds:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import random_sparse_dense
from repro.dispatch import last_plan
from repro.sparse import SparseMatrix, matmul, plan_cache_stats, sample


def main():
    n, d, density = 1024, 256, 0.05
    print(f"== SpMM: N={n}, D={d}, density={density} ==")
    a_dense = random_sparse_dense(n, density, seed=0)
    h = jnp.asarray(random_sparse_dense(n, 1.0, seed=1)[:, :d].copy())

    A = SparseMatrix.from_dense(a_dense, format="auto")
    print(f"A = {A}  (format chosen from measured structure; "
          f"occupancy {A.stats.occupancy:.2f})")
    y = A @ h
    plan = last_plan("spmm")
    err = np.abs(np.asarray(y) - a_dense @ np.asarray(h)).max()
    print(f"A @ h -> path={plan.path} [{plan.reason[:40]}...]  "
          f"max|err| vs dense = {err:.2e}")

    # repeated calls hit the per-instance plan cache (no re-planning)
    for _ in range(3):
        A @ h
    print(f"plan cache after 4 calls: {plan_cache_stats()}")

    # the blocked form + TPU Pallas kernel, in interpret mode for
    # validation (.to() converts between formats on demand)
    A_ell = A.to("ell")
    y_k = matmul(A_ell, h, policy="ell", interpret=True)
    print(f"Pallas kernel (interpret) max|err| = "
          f"{np.abs(np.asarray(y_k) - a_dense @ np.asarray(h)).max():.2e}")

    print(f"\n== SDDMM: N={n}, K=2 (the paper's GAT case) ==")
    mask = (random_sparse_dense(n, density, seed=2) != 0).astype(np.float32)
    b = jnp.asarray(random_sparse_dense(n, 1.0, seed=3)[:, :2].copy())
    c = jnp.asarray(random_sparse_dense(n, 1.0, seed=4, m=2).copy())
    M = SparseMatrix.from_dense(mask, format="coo")
    s = sample(M, b, c)  # = M ⊙ (b @ c), only at M's nonzero blocks
    err = np.abs(s.to_dense() - mask * np.asarray(b @ c)).max()
    print(f"sample(M, b, c) max|err| vs dense = {err:.2e} "
          f"(path={last_plan('sddmm').path})")

    print("\n== gradients: the kernels are each other's backward ==")

    def loss(vals, hh):
        return jnp.sum(jnp.tanh(A.with_data(vals) @ hh))

    gv, gh = jax.grad(loss, argnums=(0, 1))(A.data, h)
    from repro.dispatch import dispatch_log
    vjp_ops = [(p.op, p.path) for p in dispatch_log() if p.policy == "vjp"]
    print(f"grad(A-values) shape {gv.shape}, grad(H) shape {gh.shape}; "
          f"backward ran: {vjp_ops[-2:]}  "
          "(dH is an SpMM on Aᵀ, dA is an SDDMM on A's pattern)")

    print("\n== distributed 1.5D SpMM (paper §2.4) ==")
    n_dev = len(jax.devices())
    if n_dev >= 4:
        from repro.core.distributed import spmm_1p5d
        from repro.sharding.specs import make_mesh
        mesh = make_mesh((2, n_dev // 2), ("data", "model"))
        y_d = spmm_1p5d(A_ell, h, mesh)  # accepts the SparseMatrix directly
        print(f"1.5D max|err| = "
              f"{np.abs(np.asarray(y_d) - a_dense @ np.asarray(h)).max():.2e}")
    else:
        print(f"only {n_dev} device(s); run with "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "to exercise the mesh path")


if __name__ == "__main__":
    main()
