"""Chaos demo: a serving engine surviving a deterministic fault storm.

Arms a seed-driven :class:`FaultPlan` against the continuous batching
engine — a poison request co-batched with innocents, a transient
executor burst, latency spikes, and (with ``--kill-worker``) a dead
background worker — then drives traffic through the storm and prints
what happened: which requests completed (all the innocent ones, with
correct results), which were quarantined (only the tagged poison), and
every recovery action the resilience layer took, straight from
``obs.snapshot()``.

Run from the repo root::

    PYTHONPATH=src python examples/chaos_serving.py
    PYTHONPATH=src python examples/chaos_serving.py --soak   # 100 requests
"""
import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.resilience import (FaultPlan, FaultSpec, PoisonRequestError,
                              RetryPolicy, chaos)
from repro.serve.runtime import ContinuousBatchEngine, ContinuousConfig
from repro.sparse import SparseMatrix

BLOCK = (16, 16)
D = 16


def _graph(rng, n):
    dense = np.where(rng.random((n, n)) < 0.08,
                     rng.normal(size=(n, n)), 0.0).astype(np.float32)
    dense[0, 0] = dense[0, 0] or 1.0
    mat = SparseMatrix.from_dense(dense, formats=("ell", "csr"), block=BLOCK)
    return dense, mat


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--soak", action="store_true",
                    help="100-request storm instead of 16")
    ap.add_argument("--kill-worker", action="store_true",
                    help="run a background worker and chaos-kill it")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    obs.reset()
    rng = np.random.default_rng(args.seed)
    n_req = 100 if args.soak else 16
    poison_at = {3, n_req - 5}

    storm = [
        # the tagged requests poison every lane step they ride in —
        # bisection must isolate them without hurting their neighbors
        FaultSpec(site="continuous.execute", kind="poison", times=None,
                  match={"tags": "poison"}),
        # a transient infrastructure burst: retried with backoff
        FaultSpec(site="continuous.execute", kind="raise", at=3, times=2),
        # latency spikes: absorbed, visible in the latency percentiles
        FaultSpec(site="continuous.execute", kind="delay", payload=0.01,
                  at=6, times=3),
    ]
    if args.kill_worker:
        storm.append(FaultSpec(site="continuous.worker", kind="die",
                               at=2, times=1))

    cfg = ContinuousConfig(slots=4, adaptive=False, max_wait_ms=0.0,
                           background=args.kill_worker,
                           retry=RetryPolicy(max_attempts=3, base_ms=0.5),
                           seed=args.seed)
    plan = FaultPlan(storm, seed=args.seed)

    with chaos.active(plan), ContinuousBatchEngine(cfg=cfg) as eng:
        futs, refs, tags = [], [], []
        for i in range(n_req):
            n = int(rng.choice((48, 64, 96)))
            dense, mat = _graph(rng, n)
            h = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
            tag = "poison" if i in poison_at else None
            futs.append(eng.submit(mat, h, tag=tag))
            refs.append(dense @ np.asarray(h))
            tags.append(tag)
        eng.drain(timeout=300)

        ok = quarantined = wrong = stranded = 0
        for f, ref, tag in zip(futs, refs, tags):
            if not f.done():
                stranded += 1
                continue
            if f.exception() is not None:
                if isinstance(f.exception(), PoisonRequestError):
                    quarantined += 1
                else:
                    wrong += 1
                continue
            if np.allclose(f.result(), ref, rtol=2e-4, atol=2e-4):
                ok += 1
            else:
                wrong += 1
        rep = eng.report()

    print(f"== fault storm over {n_req} requests "
          f"({len(poison_at)} poisoned) ==")
    print(f"completed correctly : {ok}")
    print(f"quarantined (poison): {quarantined}")
    print(f"wrong/unexpected    : {wrong}")
    print(f"stranded futures    : {stranded}")
    assert stranded == 0, "resilience contract: no future may strand"
    assert wrong == 0, "resilience contract: innocents complete correctly"
    assert quarantined == len(poison_at)

    print("\n== injected faults (plan.events) ==")
    for site, kind, hit in plan.events[:12]:
        print(f"  {site:22s} {kind:8s} hit #{hit}")
    if len(plan.events) > 12:
        print(f"  ... {len(plan.events) - 12} more")

    print("\n== engine resilience report ==")
    print(json.dumps(rep["resilience"], indent=2, default=str))

    print("\n== recovery counters (obs.snapshot) ==")
    counters = obs.snapshot()["metrics"]["counters"]
    for name in sorted(counters):
        if name.startswith(("chaos_", "resilience_")):
            for labels, v in counters[name].items():
                print(f"  {name}{{{labels}}} = {v}")
    print(f"\np50={rep['p50_ms']:.2f}ms p99={rep['p99_ms']:.2f}ms "
          f"over {rep['completed']} requests")


if __name__ == "__main__":
    main()
