"""Batched serving with block-sparse (sliding-window) attention.

Spins up the ServingEngine on a small gemma3-family model whose local
layers use the paper's banded Block-ELL attention, prefillss a batch of
prompts and decodes continuations; verifies the ring-buffer local KV
cache (memory ∝ window, not context) against the full forward.

Usage:  PYTHONPATH=src python examples/sparse_attention_serve.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.transformer import forward_hidden, init_lm
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    cfg = dataclasses.replace(get_smoke_config("gemma3-4b"),
                              dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    B, S_prompt, n_new = 4, 96, 24
    prompts = rng.integers(0, cfg.vocab_size, (B, S_prompt)) \
        .astype(np.int32)
    eng = ServingEngine(params, cfg, ServeConfig(max_len=S_prompt + n_new))

    t0 = time.time()
    out = eng.generate(prompts, n_new)
    dt = time.time() - t0
    print(f"generated {B}x{n_new} tokens in {dt:.2f}s "
          f"({B * n_new / dt:.0f} tok/s on CPU)")
    print("sample:", out[0][:12], "...")

    # verify against teacher-forced full forward (greedy consistency)
    toks = np.concatenate([prompts, out], axis=1)
    hid, _, _ = forward_hidden(params, cfg, jnp.asarray(toks),
                               mode="train", remat=False)
    head = params["embed"].T
    logits = np.asarray(hid.astype(jnp.float32) @ head.astype(jnp.float32))
    greedy = logits[:, S_prompt - 1:-1].argmax(-1)
    match = (greedy == out).mean()
    print(f"greedy consistency vs full forward: {match * 100:.1f}% "
          f"(ring-buffer local KV cache, window={cfg.window})")
    assert match > 0.99, "decode path diverged from full forward"

    # cache footprint: ring buffer vs full-context cache
    n_local = sum(1 for i in range(cfg.n_layers)
                  if cfg.layer_pattern[i % cfg.period] == "local")
    full = S_prompt + n_new
    saved = n_local * (full - min(cfg.window, full))
    print(f"{n_local}/{cfg.n_layers} layers use windowed cache: "
          f"{saved} cache rows saved vs full-context KV")


if __name__ == "__main__":
    main()
