"""Elastic scaling: move a training state between meshes.

Checkpoints store unsharded host arrays (ft/checkpoint.py), so elasticity
reduces to re-placement: build the sharding tree for the NEW mesh from the
same logical-axis rules and device_put every leaf.  A job that loses a pod
restarts on the (2x smaller) mesh from the latest checkpoint with no
format conversion; scale-up is the same operation in reverse.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def sharding_tree_for(tree, mesh: Mesh, spec_fn) -> object:
    """Pytree of NamedShardings; spec_fn(path, leaf) -> PartitionSpec."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    shardings = [NamedSharding(mesh, spec_fn(path, leaf))
                 for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def replicated_tree(tree, mesh: Mesh):
    return sharding_tree_for(tree, mesh, lambda path, leaf: P())


def reshard(tree, new_mesh: Mesh, spec_fn=None):
    """Re-place a live pytree onto a new mesh (gather + scatter)."""
    spec_fn = spec_fn or (lambda path, leaf: P())
    target = sharding_tree_for(tree, new_mesh, spec_fn)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(jax.device_get(x), s), tree, target)
