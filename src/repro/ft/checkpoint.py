"""Atomic, async checkpointing for arbitrary pytrees.

Layout:  <dir>/step_<N>/  with one .npy per leaf (path-encoded filename)
plus metadata.json (treedef, step, mesh shape, config name).  Writes go to
a temp directory renamed into place, so a crash mid-write never corrupts
the latest checkpoint; a background thread makes saves non-blocking
(training continues while the previous step serializes).

Restore is mesh-independent: leaves are saved unsharded (gathered), so a
checkpoint from a 256-chip run restores onto 512 chips or 1 CPU —
the elastic-scaling path (ft/resharding.py) re-places them.

Resilience: a crash mid-write (chaos site ``checkpoint.write``) only
ever loses the *in-flight* save — the previous published step survives
the atomic-rename protocol.  An async save that fails records the error
(``last_error``/``failed_saves``) instead of silently dying with its
thread, and :meth:`restore` skips corrupt ``step_<N>`` directories
(truncated metadata, partial ``.npy``, shape drift) falling back to the
newest intact step, counting each skip in
``resilience_ckpt_corrupt_total``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro import obs
from repro.resilience import chaos

_SEP = "__"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out, treedef


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    return f"x:{p}"


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None
        self.failed_saves = 0
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, meta: Optional[Dict] = None,
             block: bool = False):
        # gather to host BEFORE handing off to the writer thread
        leaves, _ = _flatten_with_paths(tree)
        host_leaves = {k: np.asarray(v) for k, v in leaves.items()}
        self.wait()  # one in-flight save at a time
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write_guarded,
                args=(step, host_leaves, meta or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_leaves, meta or {})

    def _write_guarded(self, step: int, host_leaves, meta: Dict):
        """Async writer body: a failed save must not die silently with
        its thread — the error is recorded and the previously published
        step keeps serving restores."""
        try:
            self._write(step, host_leaves, meta)
        except BaseException as exc:  # noqa: BLE001 — recorded, not lost
            self.last_error = exc
            self.failed_saves += 1
            obs.counter("resilience_ckpt_save_failures_total").inc()

    def _write(self, step: int, host_leaves: Dict[str, np.ndarray],
               meta: Dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_")
        try:
            for key, arr in host_leaves.items():
                np.save(os.path.join(tmp, key + ".npy"), arr)
            with open(os.path.join(tmp, "metadata.json"), "w") as f:
                json.dump({"step": step, **meta}, f)
            # chaos: a crash here loses only this in-flight save — the
            # temp dir is swept and the previous step stays published
            chaos.hook("checkpoint.write", step=step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "metadata.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: Optional[int] = None,
                sharding_tree=None):
        """Restore into the structure of ``target_tree``.

        ``sharding_tree``: optional pytree of jax.sharding.Sharding — leaves
        are device_put with it (the elastic re-placement hook).

        With ``step=None`` (the default), corrupt step directories —
        truncated metadata, partial/unreadable ``.npy`` leaves, shape
        drift — are skipped, falling back to the newest *intact* step.
        An explicit ``step=`` loads exactly that step and raises on
        corruption.
        """
        if step is not None:
            return self._load_step(step, target_tree, sharding_tree)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        last_exc: Optional[BaseException] = None
        for s in reversed(steps):
            try:
                tree = self._load_step(s, target_tree, sharding_tree)
            except Exception as exc:  # noqa: BLE001 — corrupt: try older
                last_exc = exc
                obs.counter("resilience_ckpt_corrupt_total").inc()
                continue
            if s != steps[-1]:
                obs.counter("resilience_recoveries_total",
                            site="checkpoint").inc()
            return tree
        raise FileNotFoundError(
            f"no intact checkpoint in {self.directory}: all of "
            f"{steps} are corrupt (last error: {last_exc!r})")

    def _load_step(self, step: int, target_tree, sharding_tree=None):
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "metadata.json")) as f:
            json.load(f)  # truncated metadata = incomplete publish
        keys, treedef = _flatten_with_paths(target_tree)
        shardings = None
        if sharding_tree is not None:
            shardings, _ = _flatten_with_paths(sharding_tree)
        leaves = {}
        for key, ref in keys.items():
            arr = np.load(os.path.join(d, key + ".npy"))
            if arr.shape != tuple(ref.shape):
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != "
                    f"expected {ref.shape}")
            if shardings is not None:
                leaves[key] = jax.device_put(arr, shardings[key])
            else:
                leaves[key] = jax.numpy.asarray(arr, dtype=ref.dtype)
        return jax.tree_util.tree_unflatten(
            treedef, [leaves[k] for k in keys])

    def metadata(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.directory, f"step_{step:08d}",
                               "metadata.json")) as f:
            return json.load(f)
