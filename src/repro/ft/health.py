"""Straggler detection & failure handling policy.

On a real multi-pod deployment each host runs this monitor; the decisions
(flag, hot-spare swap, checkpoint-restart) are driven from per-step wall
times and heartbeats.  The detection logic is hardware-independent and is
exercised by unit tests with synthetic timings; the *actuation* on this
CPU container is simulated (``SimulatedCluster``) — restart-from-
checkpoint is tested for real in tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class HealthConfig:
    window: int = 50  # ring buffer of recent step times
    straggler_sigma: float = 3.0  # flag if mean-step > mu + sigma*std
    straggler_ratio: float = 1.5  # ... or > ratio * median
    heartbeat_timeout_s: float = 60.0


class StragglerDetector:
    """Per-step wall-time ring buffer with robust outlier detection.

    ``cfg=None`` builds a private :class:`HealthConfig` — a shared
    module-level default instance would alias mutable config state
    across every detector in the process (the classic
    mutable-dataclass-default bug: tuning one detector's thresholds
    silently retunes all of them).
    """

    def __init__(self, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg if cfg is not None else HealthConfig()
        self.times: Deque[float] = collections.deque(
            maxlen=self.cfg.window)
        self.flags: List[int] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler event."""
        is_straggler = False
        if len(self.times) >= 8:
            xs = sorted(self.times)
            med = xs[len(xs) // 2]
            mu = sum(self.times) / len(self.times)
            var = sum((t - mu) ** 2 for t in self.times) / len(self.times)
            sd = var ** 0.5
            if dt > max(self.cfg.straggler_ratio * med,
                        mu + self.cfg.straggler_sigma * sd):
                is_straggler = True
                self.flags.append(step)
        self.times.append(dt)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.times:
            return None
        xs = sorted(self.times)
        return xs[len(xs) // 2]


class Heartbeat:
    """Host-level liveness: worker marks, coordinator checks."""

    def __init__(self, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg if cfg is not None else HealthConfig()
        # keyed by host id — an int rank or a fleet worker name
        self.last: Dict[Any, float] = {}

    def beat(self, host: Any, now: Optional[float] = None):
        self.last[host] = now if now is not None else time.monotonic()

    def forget(self, host: Any) -> None:
        """Drop a retired host so it can never read as dead."""
        self.last.pop(host, None)

    def dead_hosts(self, now: Optional[float] = None) -> List[Any]:
        now = now if now is not None else time.monotonic()
        return [h for h, t in self.last.items()
                if now - t > self.cfg.heartbeat_timeout_s]


class SimulatedCluster:
    """Failure-injection harness used by fault-tolerance tests.

    Models hosts with hot spares: on failure the coordinator swaps in a
    spare (or shrinks the mesh if none remain — elastic path) and the run
    resumes from the latest checkpoint.
    """

    def __init__(self, n_hosts: int, n_spares: int = 1):
        self.active = list(range(n_hosts))
        self.spares = list(range(n_hosts, n_hosts + n_spares))
        self.events: List[Tuple[str, int]] = []

    def fail(self, host: int) -> str:
        """Returns the recovery decision: 'swap' or 'shrink'."""
        self.active.remove(host)
        if self.spares:
            spare = self.spares.pop(0)
            self.active.append(spare)
            self.events.append(("swap", spare))
            return "swap"
        self.events.append(("shrink", host))
        return "shrink"

    @property
    def world_size(self) -> int:
        return len(self.active)
