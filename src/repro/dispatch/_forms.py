"""Internal multi-form operand used by the legacy dispatcher.

One logical matrix, every execution form, converted lazily on the host
and memoized — the machinery behind the deprecated public
``dispatch.SparseOperand`` wrapper.  New code should use
``repro.sparse.SparseMatrix`` (which carries forms as pytree children
and plans per instance); this class remains so the legacy
``dispatch_spmm``/``dispatch_sddmm`` entry points keep their behavior.

Conversions are host-side (numpy); this type is NOT a pytree and must
not cross a ``jax.jit`` boundary.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR, BlockELL
from repro.dispatch.stats import MatrixStats

Array = Any


class LazyForms:
    """Lazily-converted bundle of {dense, CSR arrays, Block-ELL} forms."""

    def __init__(
        self,
        dense: Optional[np.ndarray] = None,
        *,
        ell: Optional[BlockELL] = None,
        csr: Optional[CSR] = None,
        block_m: int = 64,
        block_n: int = 64,
        ell_width: Optional[int] = None,
    ):
        if dense is None and ell is None and csr is None:
            raise ValueError("SparseOperand needs at least one form")
        self._dense = np.asarray(dense) if dense is not None else None
        self._ell = ell
        self._csr = csr
        self.block_m = ell.bm if ell is not None else block_m
        self.block_n = ell.bn if ell is not None else block_n
        self._ell_width = ell_width
        self._csr_arrays: Optional[Tuple[Array, Array, Array]] = None
        self._dense_jnp = None
        self._stats: Optional[MatrixStats] = None

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_dense(cls, dense: np.ndarray, *, block_m: int = 64,
                   block_n: int = 64,
                   ell_width: Optional[int] = None) -> "LazyForms":
        return cls(dense, block_m=block_m, block_n=block_n,
                   ell_width=ell_width)

    @classmethod
    def from_blockell(cls, ell: BlockELL) -> "LazyForms":
        return cls(ell=ell)

    # -- logical shape ------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, int]:
        """Logical dense shape (unpadded if built from a dense matrix)."""
        if self._dense is not None:
            return self._dense.shape
        if self._csr is not None:
            return self._csr.shape
        return self._ell.shape

    # -- forms (memoized) ---------------------------------------------------

    def dense(self) -> np.ndarray:
        if self._dense is None:
            if self._ell is not None:
                self._dense = self._ell.to_dense()
            else:
                self._dense = self._csr.to_dense()
        return self._dense

    def dense_jnp(self):
        if self._dense_jnp is None:
            self._dense_jnp = jnp.asarray(self.dense())
        return self._dense_jnp

    def ell(self) -> BlockELL:
        if self._ell is None:
            self._ell = BlockELL.from_dense(
                self.dense(), bm=self.block_m, bn=self.block_n,
                ell_width=self._ell_width)
        return self._ell

    def csr(self) -> CSR:
        if self._csr is None:
            self._csr = CSR.from_dense(self.dense())
        return self._csr

    def csr_arrays(self) -> Tuple[Array, Array, Array]:
        """(row_ids, col_ids, values) device arrays for the element path."""
        if self._csr_arrays is None:
            from repro.sparse.paths import csr_to_device_arrays

            self._csr_arrays = csr_to_device_arrays(self.csr())
        return self._csr_arrays

    # -- stats --------------------------------------------------------------

    def stats(self) -> MatrixStats:
        if self._stats is None:
            if self._csr is not None:
                nnz = self._csr.nnz
            elif self._dense is not None:
                nnz = int(np.count_nonzero(self._dense))
            else:
                nnz = None  # count from the ELL blocks
            self._stats = MatrixStats.from_blockell(self.ell(), nnz=nnz)
        return self._stats
