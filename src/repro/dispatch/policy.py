"""Dispatch policy vocabulary and configuration.

Execution-path names (shared by SpMM and SDDMM):

  * ``ell``   — the blocked streaming path: Block-ELL for SpMM, Block-COO
                for SDDMM.  Pallas kernel on TPU, jnp reference elsewhere.
  * ``sell``  — the SELL-C-σ path: rows sorted by nnz within σ-windows,
                packed into width-adaptive slices, only live tiles
                launched.  Kills the >99 % padding cliff of ``ell``;
                exact-nnz work like ``csr`` but scatter-free and
                load-balanced.  Needs a carried ``sell`` form.
  * ``csr``   — the element-granular scalar path: CSR gather/segment-sum
                for SpMM, element-COO for SDDMM.  Exact nnz work, no MXU.
  * ``dense`` — densified fallback (the paper's Fig. 2 failure mode; only
                competitive near full density).

Policy names accepted by the public APIs:

  * ``auto``     — analytic cost model picks the path (default).
  * ``autotune`` — time the candidate paths once, cache the winner per
                   (op, shape, dtype, sparsity-bucket) key.
  * one of the path names — force that path.
"""
from __future__ import annotations

import dataclasses

PATH_ELL = "ell"
PATH_SELL = "sell"
PATH_CSR = "csr"
PATH_DENSE = "dense"
PATHS = (PATH_ELL, PATH_SELL, PATH_CSR, PATH_DENSE)

# Op tag of the one-pass fused SDDMM→softmax→SpMM pipeline.  Not a
# storage path — a fused plan still names one of the layout paths above
# — but the cost model prices it as ONE stream of the topology (the
# unfused composition streams it three times), and plans carry this tag
# in ``Plan.op`` so ``dispatch_log()`` shows fused decisions distinctly.
PATH_FUSED_ATTN = "fused_attn"

POLICY_AUTO = "auto"
POLICY_AUTOTUNE = "autotune"
POLICIES = (POLICY_AUTO, POLICY_AUTOTUNE) + PATHS

# historical aliases (SDDMM literature calls the paths by format name)
_ALIASES = {
    "block": PATH_ELL,
    "blockell": PATH_ELL,
    "blockcoo": PATH_ELL,
    "coo": PATH_CSR,
    "element": PATH_CSR,
    "scalar": PATH_CSR,
    "sellcs": PATH_SELL,
    "sell-c-sigma": PATH_SELL,
}


def normalize_policy(policy: str) -> str:
    """Canonicalize a policy/path name; raise on unknown names."""
    p = str(policy).lower()
    p = _ALIASES.get(p, p)
    if p not in POLICIES:
        raise ValueError(
            f"unknown dispatch policy {policy!r}; expected one of "
            f"{POLICIES + tuple(_ALIASES)}")
    return p


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Tunables of the dispatch layer (see dispatch/cost_model.py for the
    cost-model constants themselves)."""

    # autotune measurement
    autotune_warmup: int = 1
    autotune_iters: int = 3
    # sparsity buckets per density decade for the autotune cache key
    buckets_per_decade: int = 2
    # kernel-vs-reference inside the ell path: None = TPU backends only
    use_kernel: bool | None = None


DEFAULT_CONFIG = DispatchConfig()
