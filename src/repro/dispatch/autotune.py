"""Empirical autotune pass: time candidate paths, cache the winner.

The cache key deliberately buckets sparsity (log-density buckets) so one
measurement serves a whole sparsity regime: dispatching a 90%-sparse and
a 91%-sparse operand of the same shape/dtype should not trigger two
timing passes.  Keys are plain tuples so the cache can be serialized to
JSON for reuse across processes (the CS-3 analog: the host compiles one
routing table per workload family, not per matrix).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.dispatch.stats import sparsity_bucket

AutotuneKey = Tuple  # (op, m, n, inner_dim, dtype_str, sparsity_bucket)


def make_key(op: str, shape: Tuple[int, int], inner_dim: int, dtype,
             density: float, *, buckets_per_decade: int = 2) -> AutotuneKey:
    return (
        str(op),
        int(shape[0]),
        int(shape[1]),
        int(inner_dim),
        str(dtype),
        sparsity_bucket(density, buckets_per_decade),
    )


@dataclasses.dataclass
class Measurement:
    path: str
    timings_us: Dict[str, float]


class AutotuneCache:
    """Thread-safe (key -> winning path) cache with JSON persistence.

    Besides the per-key timing entries, the cache can carry one
    calibrated :class:`~repro.dispatch.cost_model.CostModel` (see
    :func:`calibrate`) — ``save``/``load`` round-trip it, so a backend's
    measured cost constants persist across processes alongside the
    timing winners.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[AutotuneKey, Measurement] = {}
        self.cost_model = None  # Optional[CostModel], set by calibrate()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: AutotuneKey) -> Optional[Measurement]:
        with self._lock:
            m = self._entries.get(key)
            if m is None:
                self.misses += 1
            else:
                self.hits += 1
            return m

    def put(self, key: AutotuneKey, m: Measurement) -> None:
        with self._lock:
            self._entries[key] = m

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.cost_model = None
            self.hits = 0
            self.misses = 0

    # -- persistence --------------------------------------------------------

    def to_json(self) -> str:
        import dataclasses as _dc

        with self._lock:
            entries = [
                {"key": list(k), "path": m.path, "timings_us": m.timings_us}
                for k, m in self._entries.items()
            ]
            cm = (_dc.asdict(self.cost_model)
                  if self.cost_model is not None else None)
        return json.dumps({"entries": entries, "cost_model": cm},
                          indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def load(self, path: str) -> None:
        from repro.dispatch.cost_model import CostModel

        with open(path) as f:
            payload = json.load(f)
        # legacy payloads were a bare entry list (no calibration)
        entries = payload if isinstance(payload, list) \
            else payload.get("entries", [])
        cm = None if isinstance(payload, list) \
            else payload.get("cost_model")
        with self._lock:
            for row in entries:
                self._entries[tuple(row["key"])] = Measurement(
                    path=row["path"], timings_us=row["timings_us"])
            if cm is not None:
                self.cost_model = CostModel(**cm)


def _time_us(fn: Callable[[], object], warmup: int, iters: int) -> float:
    out = None
    for _ in range(max(warmup, 0)):
        out = fn()
    if out is not None:
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure(candidates: Dict[str, Callable[[], object]], *,
            warmup: int = 1, iters: int = 3) -> Measurement:
    """Time each candidate thunk; return the winner + all timings.

    Candidates that raise are recorded as +inf (a path can legitimately
    be unavailable, e.g. the Pallas kernel on an unsupported shape).
    """
    timings: Dict[str, float] = {}
    last_exc: Optional[Exception] = None
    for name, thunk in candidates.items():
        try:
            timings[name] = _time_us(thunk, warmup, iters)
        except Exception as exc:  # noqa: BLE001 - unavailable path, not fatal
            timings[name] = float("inf")
            last_exc = exc
    finite = {p: t for p, t in timings.items() if t != float("inf")}
    if not finite:
        raise RuntimeError(
            "autotune: every candidate path failed") from last_exc
    best = min(finite, key=finite.get)
    return Measurement(path=best, timings_us=timings)


def calibrate(
    *,
    n: int = 512,
    d: int = 64,
    densities: Tuple[float, ...] = (0.5, 0.05, 0.005),
    seed: int = 0,
    warmup: int = 1,
    iters: int = 3,
    cache: Optional[AutotuneCache] = None,
):
    """Microbenchmark the per-element path costs on the running backend.

    The analytic cost model prices each path as (elements streamed) x
    (a per-element constant); the shipped constants encode the *paper's*
    hardware asymmetry, which a CPU container or a different TPU
    generation will not match exactly.  This pass times every execution
    path on synthetic operands across a few sparsity regimes, normalizes
    each timing by the volume that path streams, and expresses it
    relative to the dense path's per-element time — exactly the
    ``c_ell`` / ``c_sell`` / ``c_csr`` constants, but measured.

    Returns the tuned :class:`~repro.dispatch.cost_model.CostModel`
    (median across densities; a path with no valid measurement keeps its
    shipped constant).  When ``cache`` is given the model is attached to
    it, so ``AutotuneCache.save``/``load`` persist the calibration.
    """
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
    from repro.sparse import SparseMatrix, autodiff

    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    # time what the dispatcher would actually run on this backend: the
    # Pallas kernels on TPU, the jnp references elsewhere
    use_kernel = jax.default_backend() == "tpu"
    ratios: Dict[str, list] = {"ell": [], "sell": [], "csr": []}
    for density in densities:
        dense = np.where(rng.random((n, n)) < density,
                         rng.normal(size=(n, n)), 0.0).astype(np.float32)
        a = SparseMatrix.from_dense(dense, formats=("ell", "sell", "csr"))
        stats = a.stats
        thunks = {
            p: (lambda p=p: autodiff.spmm_exec(
                (p, use_kernel, False, None, None), a, h))
            for p in ("ell", "sell", "csr", "dense")
        }
        m = measure(thunks, warmup=warmup, iters=iters)
        t = m.timings_us
        if t.get("dense", float("inf")) == float("inf"):
            continue
        per_dense = t["dense"] / max(stats.dense_elements * d, 1)
        streamed = {"ell": stats.stored_elements,
                    "sell": stats.sell_stored_elements,
                    "csr": stats.nnz}
        for p, vol in streamed.items():
            tp = t.get(p, float("inf"))
            if tp != float("inf") and vol > 0 and per_dense > 0:
                ratios[p].append((tp / (vol * d)) / per_dense)

    def _tuned(path: str, shipped: float) -> float:
        if not ratios[path]:
            return shipped
        # floor at a small positive constant so a noisy fast run can
        # never make a sparse path look cheaper than free
        return max(float(np.median(ratios[path])), 1e-3)

    cm = CostModel(
        c_ell=_tuned("ell", DEFAULT_COST_MODEL.c_ell),
        c_sell=_tuned("sell", DEFAULT_COST_MODEL.c_sell),
        c_csr=_tuned("csr", DEFAULT_COST_MODEL.c_csr),
    )
    if cache is not None:
        cache.cost_model = cm
    return cm


# Process-global cache used by the dispatcher's `autotune` policy.
GLOBAL_CACHE = AutotuneCache()
