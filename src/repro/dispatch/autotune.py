"""Empirical autotune pass: time candidate paths, cache the winner.

The cache key deliberately buckets sparsity (log-density buckets) so one
measurement serves a whole sparsity regime: dispatching a 90%-sparse and
a 91%-sparse operand of the same shape/dtype should not trigger two
timing passes.  Keys are plain tuples so the cache can be serialized to
JSON for reuse across processes (the CS-3 analog: the host compiles one
routing table per workload family, not per matrix).
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.dispatch.stats import sparsity_bucket

AutotuneKey = Tuple  # (op, m, n, inner_dim, dtype_str, sparsity_bucket)


def make_key(op: str, shape: Tuple[int, int], inner_dim: int, dtype,
             density: float, *, buckets_per_decade: int = 2) -> AutotuneKey:
    return (
        str(op),
        int(shape[0]),
        int(shape[1]),
        int(inner_dim),
        str(dtype),
        sparsity_bucket(density, buckets_per_decade),
    )


@dataclasses.dataclass
class Measurement:
    path: str
    timings_us: Dict[str, float]


class AutotuneCache:
    """Thread-safe (key -> winning path) cache with JSON persistence."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[AutotuneKey, Measurement] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: AutotuneKey) -> Optional[Measurement]:
        with self._lock:
            m = self._entries.get(key)
            if m is None:
                self.misses += 1
            else:
                self.hits += 1
            return m

    def put(self, key: AutotuneKey, m: Measurement) -> None:
        with self._lock:
            self._entries[key] = m

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    # -- persistence --------------------------------------------------------

    def to_json(self) -> str:
        with self._lock:
            payload = [
                {"key": list(k), "path": m.path, "timings_us": m.timings_us}
                for k, m in self._entries.items()
            ]
        return json.dumps(payload, indent=2, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def load(self, path: str) -> None:
        with open(path) as f:
            payload = json.load(f)
        with self._lock:
            for row in payload:
                self._entries[tuple(row["key"])] = Measurement(
                    path=row["path"], timings_us=row["timings_us"])


def _time_us(fn: Callable[[], object], warmup: int, iters: int) -> float:
    out = None
    for _ in range(max(warmup, 0)):
        out = fn()
    if out is not None:
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure(candidates: Dict[str, Callable[[], object]], *,
            warmup: int = 1, iters: int = 3) -> Measurement:
    """Time each candidate thunk; return the winner + all timings.

    Candidates that raise are recorded as +inf (a path can legitimately
    be unavailable, e.g. the Pallas kernel on an unsupported shape).
    """
    timings: Dict[str, float] = {}
    last_exc: Optional[Exception] = None
    for name, thunk in candidates.items():
        try:
            timings[name] = _time_us(thunk, warmup, iters)
        except Exception as exc:  # noqa: BLE001 - unavailable path, not fatal
            timings[name] = float("inf")
            last_exc = exc
    finite = {p: t for p, t in timings.items() if t != float("inf")}
    if not finite:
        raise RuntimeError(
            "autotune: every candidate path failed") from last_exc
    best = min(finite, key=finite.get)
    return Measurement(path=best, timings_us=timings)


# Process-global cache used by the dispatcher's `autotune` policy.
GLOBAL_CACHE = AutotuneCache()
