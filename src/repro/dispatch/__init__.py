"""Sparsity-adaptive SpMM/SDDMM dispatch (the paper's crossover, live).

See DESIGN.md for the policy, cost-model inputs, and autotune cache key.
"""
from repro.dispatch.autotune import (AutotuneCache, GLOBAL_CACHE, calibrate,
                                     make_key, measure)
from repro.dispatch.cost_model import CostModel, DEFAULT_COST_MODEL
from repro.dispatch.dispatcher import (Plan, clear_log, dispatch_log,
                                       dispatch_sddmm, dispatch_spmm,
                                       last_plan, log_capacity,
                                       plan_fused_attention, plan_sddmm,
                                       plan_spmm, record_plan,
                                       set_log_capacity)
from repro.dispatch.operand import SparseOperand
from repro.dispatch.policy import (DEFAULT_CONFIG, DispatchConfig, PATHS,
                                   PATH_CSR, PATH_DENSE, PATH_ELL,
                                   PATH_FUSED_ATTN, PATH_SELL, POLICIES,
                                   POLICY_AUTO, POLICY_AUTOTUNE,
                                   normalize_policy)
from repro.dispatch.stats import MatrixStats, sparsity_bucket

__all__ = [
    "AutotuneCache", "GLOBAL_CACHE", "calibrate", "make_key", "measure",
    "CostModel", "DEFAULT_COST_MODEL",
    "Plan", "clear_log", "dispatch_log", "dispatch_sddmm", "dispatch_spmm",
    "last_plan", "log_capacity", "plan_fused_attention", "plan_sddmm",
    "plan_spmm", "record_plan", "set_log_capacity",
    "SparseOperand",
    "DEFAULT_CONFIG", "DispatchConfig", "PATHS", "PATH_CSR", "PATH_DENSE",
    "PATH_ELL", "PATH_FUSED_ATTN", "PATH_SELL", "POLICIES", "POLICY_AUTO",
    "POLICY_AUTOTUNE", "normalize_policy",
    "MatrixStats", "sparsity_bucket",
]
