"""Sparsity-adaptive dispatch for SpMM and SDDMM.

Every public sparse matmul in the repo routes through here.  A call is
resolved in three steps:

  1. **Stats** — host-side structure statistics of the sparse operand
     (density, stored/padded stream volume, ELL occupancy).
  2. **Plan** — a ``Plan`` naming the execution path, chosen by (a) an
     explicit policy ("ell" / "csr" / "dense"), (b) the analytic cost
     model ("auto"), or (c) a timed autotune pass with a per-(shape,
     dtype, sparsity-bucket) cache ("autotune").
  3. **Execute** — run the chosen path.  The blocked path further
     resolves kernel-vs-reference: the Pallas kernel on TPU backends (or
     when explicitly requested / interpreted), the jnp reference
     elsewhere.

Plans are host decisions: under ``jax.jit`` the operand's arrays are
tracers, so callers either dispatch outside jit (the serving engine
does) or plan once from static ``MatrixStats`` carried in pytree aux
metadata (the GNN layer does).  A traced operand with policy "auto"
falls back to the blocked path — the only one that needs no host
conversion — and records the fallback in the plan's reason.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from repro.core.formats import BlockCOO, BlockELL
from repro.dispatch import autotune as autotune_mod
from repro.dispatch._forms import LazyForms
from repro.dispatch.autotune import AutotuneCache, make_key, measure
from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
from repro.dispatch.policy import (DEFAULT_CONFIG, DispatchConfig, PATHS,
                                   PATH_CSR, PATH_DENSE, PATH_ELL,
                                   PATH_FUSED_ATTN, PATH_SELL, POLICY_AUTO,
                                   POLICY_AUTOTUNE, normalize_policy)
from repro.dispatch.stats import MatrixStats

Array = Any


@dataclasses.dataclass(frozen=True)
class Plan:
    """One resolved dispatch decision (also the reporting record)."""

    op: str                      # "spmm" | "sddmm" | "fused_attn"
    path: str                    # ell | sell | csr | dense
    policy: str                  # policy that produced this plan
    reason: str                  # human-readable why
    use_kernel: bool             # ell path only: Pallas kernel vs jnp ref
    interpret: bool
    costs: Optional[Dict[str, float]] = None       # analytic model output
    timings_us: Optional[Dict[str, float]] = None  # autotune output
    stats: Optional[MatrixStats] = None
    # fused-pipeline tag: the epilogue description for a fused SpMM
    # ("relu+bias"), "attn" for the one-pass attention; None = unfused
    fused: Optional[str] = None

    def describe(self) -> str:
        extra = ""
        if self.fused is not None:
            extra += f" fused={self.fused}"
        if self.stats is not None:
            extra += (f" density={self.stats.density:.2e}"
                      f" blowup={self.stats.padded_stream_blowup:.1f}")
        return f"{self.op}->{self.path} [{self.policy}: {self.reason}]{extra}"


# Bounded record of recent decisions, for benchmarks / engines to report.
# Serving worker threads append concurrently with benchmark readers, so
# every access goes through the lock; the ring's capacity is explicit
# and adjustable (shrinking drops the oldest entries).
DEFAULT_LOG_CAPACITY = 256

_LOG_LOCK = threading.Lock()
_LOG: "collections.deque[Plan]" = collections.deque(
    maxlen=DEFAULT_LOG_CAPACITY)


def dispatch_log() -> Tuple[Plan, ...]:
    with _LOG_LOCK:
        return tuple(_LOG)


def last_plan(op: Optional[str] = None) -> Optional[Plan]:
    with _LOG_LOCK:
        for plan in reversed(_LOG):
            if op is None or plan.op == op:
                return plan
    return None


def clear_log() -> None:
    with _LOG_LOCK:
        _LOG.clear()


def log_capacity() -> int:
    return _LOG.maxlen or 0


def set_log_capacity(capacity: int) -> None:
    """Resize the plan ring (keeps the newest entries that still fit)."""
    global _LOG
    capacity = int(capacity)
    if capacity < 1:
        raise ValueError(f"log capacity must be >= 1, got {capacity}")
    with _LOG_LOCK:
        _LOG = collections.deque(_LOG, maxlen=capacity)


def _record(plan: Plan) -> Plan:
    with _LOG_LOCK:
        _LOG.append(plan)
    obs.counter("dispatch_plans_total", op=plan.op, path=plan.path,
                policy=plan.policy).inc()
    return plan


def record_plan(plan: Plan) -> Plan:
    """Append an externally-made plan to the dispatch log (reporting)."""
    return _record(plan)


def _audit_run(plan: Plan, run):
    """Execute ``run()`` and record predicted-vs-measured in the audit.

    Timing blocks on the result (cheap: callers materialize it anyway);
    traced outputs (a concrete operand dispatched under jit over the
    dense side) cannot be timed and are skipped.
    """
    t0 = time.perf_counter()
    out = run()
    if not _is_traced(*jax.tree_util.tree_leaves(out)):
        try:
            jax.block_until_ready(out)
        except Exception:  # non-array leaves: time without the barrier
            pass
        obs.AUDIT.record(plan, (time.perf_counter() - t0) * 1e3)
    return out


def _is_traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def _default_use_kernel(config: DispatchConfig) -> bool:
    if config.use_kernel is not None:
        return config.use_kernel
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Planning (pure decision; usable at trace time from static stats)
# ---------------------------------------------------------------------------


def plan_spmm(
    stats: MatrixStats,
    d: int,
    *,
    policy: str = POLICY_AUTO,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    candidates: Optional[Tuple[str, ...]] = None,
) -> Plan:
    """Pure planning from static stats (safe at jit trace time).

    ``candidates`` restricts the choice to the paths the caller can
    actually execute (e.g. a Graph carries only the ell + csr forms).
    """
    return _plan("spmm", cost_model.spmm_costs(stats, d), stats,
                 policy=policy, config=config, use_kernel=use_kernel,
                 interpret=interpret, candidates=candidates)


def plan_spmv(
    stats: MatrixStats,
    *,
    policy: str = POLICY_AUTO,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    candidates: Optional[Tuple[str, ...]] = None,
) -> Plan:
    """Plan y = A @ x for a vector operand (SpMM at d = 1).

    The cost surface is the SpMM one evaluated at unit feature width —
    with no D to amortize the stream over, the scalar paths close most
    of their per-element disadvantage and hyper-sparse operands tip to
    csr much earlier.  A dedicated op tag keeps the dispatch log honest
    about which front-end ran.
    """
    return _plan("spmv", cost_model.spmm_costs(stats, 1), stats,
                 policy=policy, config=config, use_kernel=use_kernel,
                 interpret=interpret, candidates=candidates)


def plan_sddmm(
    stats: MatrixStats,
    k: int,
    *,
    policy: str = POLICY_AUTO,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    candidates: Optional[Tuple[str, ...]] = None,
) -> Plan:
    return _plan("sddmm", cost_model.sddmm_costs(stats, k), stats,
                 policy=policy, config=config, use_kernel=use_kernel,
                 interpret=interpret, candidates=candidates)


def plan_fused_attention(
    stats: MatrixStats,
    k: int,
    d: int,
    *,
    policy: str = POLICY_AUTO,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    candidates: Optional[Tuple[str, ...]] = None,
) -> Plan:
    """Plan the one-pass fused SDDMM→softmax→SpMM attention pipeline.

    ``k`` is the score inner width (the SDDMM's K), ``d`` the value
    feature width (the SpMM's D).  The fused pipeline streams the
    topology once at combined width ``k + d`` — see
    ``CostModel.fused_attn_costs`` — instead of the unfused
    composition's three passes, so the layout choice is made on the
    single-stream cost surface.
    """
    plan = _plan(PATH_FUSED_ATTN,
                 cost_model.fused_attn_costs(stats, k, d), stats,
                 policy=policy, config=config, use_kernel=use_kernel,
                 interpret=interpret, candidates=candidates)
    return dataclasses.replace(
        plan, fused="attn",
        reason=plan.reason if plan.policy in PATHS
        else f"one-stream fused pricing (k={k}, d={d}): {plan.reason}")


def _plan(op, costs, stats, *, policy, config, use_kernel, interpret,
          candidates=None) -> Plan:
    policy = normalize_policy(policy)
    if policy == POLICY_AUTOTUNE:
        # pure planning cannot time candidates; be honest about what ran
        policy = POLICY_AUTO
    if candidates:
        costs = {p: c for p, c in costs.items() if p in candidates}
    uk = use_kernel if use_kernel is not None \
        else _default_use_kernel(config)
    if policy in (PATH_ELL, PATH_SELL, PATH_CSR, PATH_DENSE):
        if candidates and policy not in candidates:
            raise ValueError(
                f"policy {policy!r} not among available paths {candidates}")
        return Plan(op=op, path=policy, policy=policy, reason="forced",
                    use_kernel=uk, interpret=interpret, costs=costs,
                    stats=stats)
    path = CostModel.pick(costs)
    reason = (f"cost model: {path} cheapest of "
              + ", ".join(f"{p}={c:.3g}" for p, c in sorted(costs.items())))
    return Plan(op=op, path=path, policy=policy, reason=reason,
                use_kernel=uk, interpret=interpret, costs=costs, stats=stats)


# ---------------------------------------------------------------------------
# SpMM dispatch
# ---------------------------------------------------------------------------


def _as_spmm_operand(a) -> Tuple[Optional[LazyForms], Optional[BlockELL]]:
    """Returns (operand, raw_ell).  operand is None for traced input."""
    from repro.sparse.matrix import SparseMatrix

    if isinstance(a, SparseMatrix):
        if "ell" in a.formats:
            return LazyForms.from_blockell(a.form("ell")), None
        return LazyForms.from_dense(a.to_dense()), None
    if isinstance(a, LazyForms):
        return a, None
    if isinstance(a, BlockELL):
        if _is_traced(a.blocks, a.indices):
            return None, a
        return LazyForms.from_blockell(a), None
    arr = np.asarray(a) if not _is_traced(a) else None
    if arr is None:
        raise TypeError(
            "dispatch_spmm: traced dense operand; pass a BlockELL (blocked "
            "fallback) or plan outside jit with plan_spmm + static stats")
    return LazyForms.from_dense(arr), None


def _run_spmm_path(path: str, op: LazyForms, h, *, use_kernel: bool,
                   interpret: bool, bd=None, out_dtype=None):
    from repro.kernels.spmm.ops import spmm_blockell
    from repro.sparse.paths import spmm_dense
    from repro.sparse.paths import spmm_elements as spmm_csr

    m = op.shape[0]
    if h.shape[0] != op.shape[1]:
        raise ValueError(
            f"spmm: H has {h.shape[0]} rows but A has {op.shape[1]} "
            f"columns (A shape {op.shape})")
    if path == PATH_ELL:
        ell = op.ell()
        n_pad = ell.shape[1]
        hh = h
        if h.shape[0] != n_pad:  # operand narrower than its block padding
            hh = jnp.zeros((n_pad,) + h.shape[1:], h.dtype) \
                .at[: h.shape[0]].set(h)
        y = spmm_blockell(ell, hh, bd=bd, out_dtype=out_dtype,
                          use_kernel=use_kernel or interpret,
                          interpret=interpret)
        return y[:m]
    if path == PATH_CSR:
        row_ids, col_ids, values = op.csr_arrays()
        y = spmm_csr(row_ids, col_ids, values, h[: op.shape[1]], m)
        return y.astype(out_dtype) if out_dtype else y
    if path == PATH_DENSE:
        y = spmm_dense(op.dense_jnp(), h[: op.shape[1]])
        return y.astype(out_dtype) if out_dtype else y
    raise ValueError(f"unknown spmm path {path!r}")


def dispatch_spmm(
    a,
    h,
    *,
    policy: str = POLICY_AUTO,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    bd: Optional[int] = None,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    cache: Optional[AutotuneCache] = None,
):
    """Y = A @ H through the sparsity-adaptive dispatch layer.

    ``a``: BlockELL, SparseMatrix, SparseOperand, or a concrete dense
    matrix.  Explicit ``use_kernel``/``interpret`` force the blocked
    path (the legacy kwarg rule, consolidated in
    ``repro.sparse.legacy.coerce_kernel_kwargs``).
    """
    from repro.sparse.legacy import coerce_kernel_kwargs

    policy, use_kernel, interpret, _ = coerce_kernel_kwargs(
        policy, use_kernel, interpret)
    h_was_1d = h.ndim == 1
    if h_was_1d:
        h = h[:, None]
    operand, raw_ell = _as_spmm_operand(a)

    if operand is None:  # traced BlockELL: blocked path is the only option
        from repro.kernels.spmm.ops import spmm_blockell

        if policy in (PATH_SELL, PATH_CSR, PATH_DENSE):
            raise TypeError(
                f"dispatch_spmm: policy {policy!r} needs host-visible "
                "operand data, but the BlockELL is traced (inside jit); "
                "dispatch outside jit or use the ell path")
        uk = use_kernel if use_kernel is not None \
            else _default_use_kernel(config)
        _record(Plan(op="spmm", path=PATH_ELL, policy=policy,
                     reason="traced operand: blocked path only",
                     use_kernel=uk, interpret=interpret))
        return spmm_blockell(raw_ell, h, bd=bd, out_dtype=out_dtype,
                             use_kernel=uk or interpret,
                             interpret=interpret)

    d = h.shape[1]
    if policy in (PATH_ELL, PATH_CSR, PATH_DENSE):
        # forced path: no stats needed (skips the host nonzero count)
        uk = use_kernel if use_kernel is not None \
            else _default_use_kernel(config)
        plan = Plan(op="spmm", path=policy, policy=policy, reason="forced",
                    use_kernel=uk, interpret=interpret)
        _record(plan)
        y = _audit_run(plan, lambda: _run_spmm_path(
            policy, operand, h, use_kernel=uk, interpret=interpret,
            bd=bd, out_dtype=out_dtype))
        return y[:, 0] if h_was_1d else y

    stats = operand.stats()

    if policy == POLICY_AUTOTUNE:
        cache = cache if cache is not None else autotune_mod.GLOBAL_CACHE
        key = make_key("spmm", stats.shape, d, h.dtype, stats.density,
                       buckets_per_decade=config.buckets_per_decade)
        uk = use_kernel if use_kernel is not None \
            else _default_use_kernel(config)
        hit = cache.get(key)
        if hit is None:
            candidates = {
                p: (lambda p=p: _run_spmm_path(
                    p, operand, h, use_kernel=uk, interpret=interpret,
                    bd=bd, out_dtype=out_dtype))
                for p in (PATH_ELL, PATH_CSR, PATH_DENSE)
            }
            hit = measure(candidates, warmup=config.autotune_warmup,
                          iters=config.autotune_iters)
            cache.put(key, hit)
            reason = "autotune: measured " + ", ".join(
                f"{p}={t:.0f}us" for p, t in sorted(hit.timings_us.items()))
        else:
            reason = "autotune: cached winner"
        plan = Plan(op="spmm", path=hit.path, policy=POLICY_AUTOTUNE,
                    reason=reason, use_kernel=uk,
                    interpret=interpret, timings_us=hit.timings_us,
                    stats=stats)
    else:
        # the legacy LazyForms operand carries no sell packing, so the
        # SELL-C-σ path is not a candidate here (SparseMatrix is)
        plan = plan_spmm(stats, d, policy=policy, cost_model=cost_model,
                         config=config, use_kernel=use_kernel,
                         interpret=interpret,
                         candidates=(PATH_ELL, PATH_CSR, PATH_DENSE))
    _record(plan)
    y = _audit_run(plan, lambda: _run_spmm_path(
        plan.path, operand, h, use_kernel=plan.use_kernel,
        interpret=plan.interpret, bd=bd, out_dtype=out_dtype))
    return y[:, 0] if h_was_1d else y


# ---------------------------------------------------------------------------
# SDDMM dispatch
# ---------------------------------------------------------------------------


def _coo_element_coords(coo: BlockCOO):
    """Host-side element coordinates of a concrete BlockCOO's nonzeros."""
    blocks = np.asarray(coo.blocks)
    rows = np.asarray(coo.rows)
    cols = np.asarray(coo.cols)
    e, i, j = np.nonzero(blocks)
    gr = rows[e] * coo.bm + i
    gc = cols[e] * coo.bn + j
    return e, i, j, gr.astype(np.int32), gc.astype(np.int32)


def _run_sddmm_path(path: str, coo: BlockCOO, b, c, *, use_kernel: bool,
                    interpret: bool, bk=None, out_dtype=None) -> BlockCOO:
    from repro.kernels.sddmm.ops import sddmm_blockcoo
    from repro.sparse.paths import sddmm_element_dots as sddmm_coo

    if path == PATH_ELL:
        return sddmm_blockcoo(coo, b, c, bk=bk, out_dtype=out_dtype,
                              use_kernel=use_kernel or interpret,
                              interpret=interpret)
    out_dtype = out_dtype or jnp.result_type(coo.blocks.dtype, b.dtype)
    if path == PATH_CSR:
        e, i, j, gr, gc = _coo_element_coords(coo)
        dots = sddmm_coo(jnp.asarray(gr), jnp.asarray(gc), b, c)
        vals = (jnp.asarray(np.asarray(coo.blocks)[e, i, j])
                .astype(jnp.float32) * dots.astype(jnp.float32))
        out_blocks = jnp.zeros(coo.blocks.shape, jnp.float32) \
            .at[e, i, j].set(vals).astype(out_dtype)
        return BlockCOO(rows=coo.rows, cols=coo.cols, blocks=out_blocks,
                        shape=coo.shape)
    if path == PATH_DENSE:
        m, n = coo.shape
        bm, bn = coo.bm, coo.bn
        full = b.astype(jnp.float32) @ c.astype(jnp.float32)  # [M, N]
        tiles = full.reshape(m // bm, bm, n // bn, bn).transpose(0, 2, 1, 3)
        gathered = tiles[coo.rows, coo.cols]  # [nnzb, bm, bn]
        out_blocks = (coo.blocks.astype(jnp.float32)
                      * gathered).astype(out_dtype)
        return BlockCOO(rows=coo.rows, cols=coo.cols, blocks=out_blocks,
                        shape=coo.shape)
    raise ValueError(f"unknown sddmm path {path!r}")


def dispatch_sddmm(
    a,
    b,
    c,
    *,
    policy: str = POLICY_AUTO,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    bk: Optional[int] = None,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    cache: Optional[AutotuneCache] = None,
) -> BlockCOO:
    """Y = A (.) (B @ C) through the dispatch layer; returns BlockCOO.

    ``a``: BlockCOO (mask/values of A) or a concrete dense matrix, which
    is tiled with 64x64 blocks.  Path vocabulary matches SpMM: "ell" is
    the blocked (Block-COO) path, "csr" the element-COO path, "dense"
    the full-product-then-sample fallback.
    """
    from repro.sparse.legacy import coerce_kernel_kwargs

    policy, use_kernel, interpret, _ = coerce_kernel_kwargs(
        policy, use_kernel, interpret)
    if not isinstance(a, BlockCOO):
        from repro.sparse.matrix import SparseMatrix

        if isinstance(a, SparseMatrix):
            a = a.form("coo") if "coo" in a.formats \
                else BlockCOO.from_dense(a.to_dense(), 64, 64)
        elif _is_traced(a):
            raise TypeError("dispatch_sddmm: traced dense operand")
        else:
            a = BlockCOO.from_dense(np.asarray(a), 64, 64)

    # A's BlockCOO shape is block-padded; pad B/C to match so every path
    # (block reshape, element gather, dense product) sees aligned shapes.
    # The padded regions of A are zero, so they contribute nothing.
    mp, np_pad = a.shape
    if b.shape[0] != mp:
        if b.shape[0] > mp:
            raise ValueError(
                f"sddmm: B has {b.shape[0]} rows but A has {mp}")
        b = jnp.zeros((mp, b.shape[1]), b.dtype).at[: b.shape[0]].set(b)
    if c.shape[1] != np_pad:
        if c.shape[1] > np_pad:
            raise ValueError(
                f"sddmm: C has {c.shape[1]} columns but A has {np_pad}")
        c = jnp.zeros((c.shape[0], np_pad), c.dtype) \
            .at[:, : c.shape[1]].set(c)

    traced = _is_traced(a.blocks, a.rows, a.cols)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    if traced:  # blocked path is the only tracer-safe one
        if policy in (PATH_SELL, PATH_CSR, PATH_DENSE):
            raise TypeError(
                f"dispatch_sddmm: policy {policy!r} needs host-visible "
                "operand data, but the BlockCOO is traced (inside jit); "
                "dispatch outside jit or use the ell path")
        _record(Plan(op="sddmm", path=PATH_ELL, policy=policy,
                     reason="traced operand: blocked path only",
                     use_kernel=uk, interpret=interpret))
        return _run_sddmm_path(PATH_ELL, a, b, c, use_kernel=uk,
                               interpret=interpret, bk=bk,
                               out_dtype=out_dtype)

    k = b.shape[1]
    if policy in (PATH_ELL, PATH_CSR, PATH_DENSE):
        # forced path: no stats needed (skips the host nonzero count)
        plan = Plan(op="sddmm", path=policy, policy=policy, reason="forced",
                    use_kernel=uk, interpret=interpret)
        _record(plan)
        return _audit_run(plan, lambda: _run_sddmm_path(
            policy, a, b, c, use_kernel=uk, interpret=interpret, bk=bk,
            out_dtype=out_dtype))

    stats = MatrixStats.from_blockcoo(a)

    if policy == POLICY_AUTOTUNE:
        cache = cache if cache is not None else autotune_mod.GLOBAL_CACHE
        key = make_key("sddmm", stats.shape, k, b.dtype, stats.density,
                       buckets_per_decade=config.buckets_per_decade)
        hit = cache.get(key)
        if hit is None:
            candidates = {
                p: (lambda p=p: _run_sddmm_path(
                    p, a, b, c, use_kernel=uk, interpret=interpret,
                    bk=bk, out_dtype=out_dtype).blocks)
                for p in (PATH_ELL, PATH_CSR, PATH_DENSE)
            }
            hit = measure(candidates, warmup=config.autotune_warmup,
                          iters=config.autotune_iters)
            cache.put(key, hit)
            reason = "autotune: measured " + ", ".join(
                f"{p}={t:.0f}us" for p, t in sorted(hit.timings_us.items()))
        else:
            reason = "autotune: cached winner"
        plan = Plan(op="sddmm", path=hit.path, policy=POLICY_AUTOTUNE,
                    reason=reason, use_kernel=uk, interpret=interpret,
                    timings_us=hit.timings_us, stats=stats)
    else:
        plan = plan_sddmm(stats, k, policy=policy, cost_model=cost_model,
                          config=config, use_kernel=use_kernel,
                          interpret=interpret,
                          candidates=(PATH_ELL, PATH_CSR, PATH_DENSE))
    _record(plan)
    return _audit_run(plan, lambda: _run_sddmm_path(
        plan.path, a, b, c, use_kernel=plan.use_kernel,
        interpret=plan.interpret, bk=bk, out_dtype=out_dtype))
