"""Analytic cost model for SpMM/SDDMM path selection.

Costs are *relative*: each path's cost is (elements it must stream and
multiply) x (a per-element cost constant).  The constants encode the
hardware asymmetry the paper measures:

  * the dense path runs the MXU flat out but touches every element
    (``c_dense`` = 1.0 per element, the unit);
  * the blocked streaming path (Block-ELL / Block-COO) also feeds the
    MXU but pays gather/index overhead and computes its *padding*
    (``c_ell`` slightly above 1.0, applied to stored-including-padding
    elements — the paper's padded-stream volume);
  * the element-level CSR/COO path does exact nnz work but retires ~one
    MAC per scalar op instead of a full MXU lane (``c_csr`` >> 1,
    applied to true nonzeros only).

The paper's crossover falls out directly: the streaming path wins while
its padded-stream blow-up (stored/nnz) stays below ``c_csr / c_ell``;
beyond ~99% sparsity the blow-up explodes past that ratio and the scalar
path takes over (Fig. 9's hyper-sparsity cliff).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.dispatch.policy import PATH_CSR, PATH_DENSE, PATH_ELL, PATH_SELL
from repro.dispatch.stats import MatrixStats


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-element relative cost constants (unitless, dense == 1.0)."""

    c_dense: float = 1.0
    # stored-element cost of the blocked path: MXU-fed but pays the
    # index gather; > c_dense so a fully-dense matrix prefers `dense`.
    c_ell: float = 1.05
    # per-nonzero cost of the scalar path: no MXU, one lane of work per
    # element.  c_csr / c_ell is the padded-stream blow-up at which the
    # scalar path overtakes the streaming path (the paper's crossover).
    c_csr: float = 12.0
    # per-slot cost of the SELL-C-σ path: gather-granular like csr, but
    # scatter-free (slice-local dense reduction) and load-balanced, so
    # each slot is cheaper than a csr nonzero.  Applied to the packed
    # slot volume (real + slice padding): where the Block-ELL blow-up
    # explodes past c_sell/c_ell, sell takes over instead of falling off
    # the cliff; where the matrix is dense enough for blocked streaming
    # (blow-up below ~c_sell/c_ell), ell still wins.
    c_sell: float = 9.0

    def spmm_costs(self, stats: MatrixStats, d: int) -> Dict[str, float]:
        """Relative cost of Y[M,D] = A[M,N] @ H[N,D] per path.

        The ELL path is priced off ``ell_stream_estimate`` — stored
        volume floored by M x max_row_nnz — so a hub-heavy matrix whose
        global density looks streaming-friendly is still charged for
        the width its heaviest row forces on every row.
        """
        d = max(int(d), 1)
        return {
            PATH_DENSE: self.c_dense * stats.dense_elements * d,
            PATH_ELL: self.c_ell * stats.ell_stream_estimate * d,
            PATH_SELL: self._sell_cost(stats, d),
            PATH_CSR: self.c_csr * stats.nnz * d,
        }

    def sddmm_costs(self, stats: MatrixStats, k: int) -> Dict[str, float]:
        """Relative cost of Y = A (.) (B[M,K] @ C[K,N]) per path."""
        k = max(int(k), 1)
        return {
            PATH_DENSE: self.c_dense * stats.dense_elements * k,
            PATH_ELL: self.c_ell * stats.stored_elements * k,
            PATH_SELL: self._sell_cost(stats, k),
            PATH_CSR: self.c_csr * stats.nnz * k,
        }

    def fused_attn_costs(self, stats: MatrixStats, k: int, d: int
                         ) -> Dict[str, float]:
        """Relative cost of the one-pass fused attention pipeline.

        The unfused SDDMM→softmax→SpMM composition streams the topology
        three times (score it, normalize it, aggregate with it); the
        fused kernel streams every live tile exactly once, doing the
        k-wide score dot and the d-wide V accumulation while the tile is
        resident — so each path is priced at ONE stream of its layout's
        stored volume at the combined inner width ``k + d``.
        """
        inner = max(int(k), 1) + max(int(d), 1)
        return {
            PATH_DENSE: self.c_dense * stats.dense_elements * inner,
            PATH_ELL: self.c_ell * stats.ell_stream_estimate * inner,
            PATH_SELL: self._sell_cost(stats, inner),
            PATH_CSR: self.c_csr * stats.nnz * inner,
        }

    def _sell_cost(self, stats: MatrixStats, inner: int) -> float:
        # sell_stored_elements == 0 with nonzeros present means the slot
        # volume was never measured (e.g. stats built from a transposed
        # operand): the path is unpriceable, never auto-picked.
        if stats.sell_stored_elements <= 0 and stats.nnz > 0:
            return float("inf")
        return self.c_sell * stats.sell_stored_elements * inner

    @staticmethod
    def pick(costs: Dict[str, float]) -> str:
        """Cheapest path; ties broken dense < ell < sell < csr."""
        order = {PATH_DENSE: 0, PATH_ELL: 1, PATH_SELL: 2, PATH_CSR: 3}
        return min(costs, key=lambda p: (costs[p], order[p]))


DEFAULT_COST_MODEL = CostModel()
