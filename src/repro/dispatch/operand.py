"""Deprecated public operand wrapper — use ``repro.sparse.SparseMatrix``.

``SparseOperand`` was the pre-``repro.sparse`` multi-format wrapper.
Constructing one still works (it forwards to the internal machinery the
legacy dispatcher keeps using) but emits a ``DeprecationWarning``; the
replacement carries its forms as pytree children, adds operators,
gradients, and per-instance plan caching::

    from repro.sparse import SparseMatrix
    A = SparseMatrix.from_dense(dense)        # instead of SparseOperand
    y = A @ h                                 # instead of dispatch_spmm
"""
from __future__ import annotations

from repro.dispatch._forms import LazyForms
from repro.sparse.legacy import warn_deprecated


class SparseOperand(LazyForms):
    """Deprecated; see ``repro.sparse.SparseMatrix``."""

    def __init__(self, *args, **kwargs):
        warn_deprecated(
            "dispatch.SparseOperand",
            "use repro.sparse.SparseMatrix (multi-form via "
            "SparseMatrix.from_dense(a, formats=(...)))")
        super().__init__(*args, **kwargs)
