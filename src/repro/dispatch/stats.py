"""Host-side matrix statistics that drive dispatch decisions.

Everything in here is plain Python numbers computed from *concrete*
(host-visible) sparse operands.  A ``MatrixStats`` is cheap to carry
around as static metadata (e.g. in a pytree aux field), so consumers
that run under ``jax.jit`` can still plan at trace time.

The central quantity is the paper's padded-stream blow-up: the ratio of
elements the Block-ELL/SELLPACK-style layout actually streams (real +
padding) to the true nonzero count.  The crossover of the paper's Fig. 9
is exactly the sparsity where that blow-up exceeds the per-element cost
advantage the streaming path has over the scalar CSR path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.formats import (BlockCOO, BlockELL, CSR,
                                blockell_stream_elements,
                                sell_slot_volume)


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """Sparsity-structure summary of one sparse operand."""

    shape: Tuple[int, int]        # logical (padded) dense shape
    nnz: int                      # element-level nonzeros
    stored_elements: int          # elements the blocked layout streams
    block_m: int
    block_n: int
    n_block_rows: int
    ell_width: int                # ELL width W (0 for COO layouts)
    occupancy: float              # real blocks / stored slots (1 = no pad)
    # slots the SELL-C-σ packing would stream (real + slice padding) at
    # the default (C, σ); 0 = not measured (sell path unpriceable)
    sell_stored_elements: int = 0

    @property
    def dense_elements(self) -> int:
        return int(self.shape[0]) * int(self.shape[1])

    @property
    def density(self) -> float:
        return self.nnz / max(self.dense_elements, 1)

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    @property
    def padded_stream_blowup(self) -> float:
        """Streamed elements per true nonzero (>= 1; inf for empty A)."""
        if self.nnz == 0:
            return float("inf")
        return self.stored_elements / self.nnz

    # -- constructors -------------------------------------------------------

    @staticmethod
    def from_blockell(ell: BlockELL, nnz: Optional[int] = None
                      ) -> "MatrixStats":
        """Stats of a concrete BlockELL (host transfer of `blocks` if
        ``nnz`` is not supplied)."""
        blocks = np.asarray(ell.blocks)  # [nbr, W, bm, bn]
        if nnz is None:
            nnz = int(np.count_nonzero(blocks))
        # element-row nonzero counts: sum over (slot, block-col) axes
        row_nnz = np.count_nonzero(blocks, axis=(1, 3)).reshape(-1)
        nbr, w = ell.n_block_rows, ell.ell_width
        return MatrixStats(
            shape=ell.shape,
            nnz=int(nnz),
            stored_elements=int(blockell_stream_elements(ell))
            - nbr * w,  # count data words only, not the index words
            block_m=ell.bm,
            block_n=ell.bn,
            n_block_rows=nbr,
            ell_width=w,
            occupancy=ell.occupancy(),
            sell_stored_elements=sell_slot_volume(row_nnz),
        )

    @staticmethod
    def from_blockcoo(coo: BlockCOO, nnz: Optional[int] = None
                      ) -> "MatrixStats":
        blocks = np.asarray(coo.blocks)
        if nnz is None:
            nnz = int(np.count_nonzero(blocks))
        nnzb = coo.nnzb
        real = int((blocks.reshape(nnzb, -1) != 0).any(axis=1).sum())
        e, i, _ = np.nonzero(blocks)
        grows = np.asarray(coo.rows)[e].astype(np.int64) * coo.bm + i
        row_nnz = np.bincount(grows, minlength=coo.shape[0])
        return MatrixStats(
            shape=coo.shape,
            nnz=int(nnz),
            stored_elements=int(nnzb * coo.bm * coo.bn),
            block_m=coo.bm,
            block_n=coo.bn,
            n_block_rows=coo.shape[0] // coo.bm,
            ell_width=0,
            occupancy=real / max(nnzb, 1),
            sell_stored_elements=sell_slot_volume(row_nnz),
        )

    @staticmethod
    def from_csr(csr: CSR, block_m: int = 1, block_n: int = 1
                 ) -> "MatrixStats":
        """Element-granular stats (stored == nnz: CSR streams no padding)."""
        return MatrixStats(
            shape=csr.shape,
            nnz=csr.nnz,
            stored_elements=csr.nnz,
            block_m=block_m,
            block_n=block_n,
            n_block_rows=csr.shape[0],
            ell_width=0,
            occupancy=1.0,
            sell_stored_elements=sell_slot_volume(np.diff(csr.indptr)),
        )


def sparsity_bucket(density: float, per_decade: int = 2) -> int:
    """Discretize density into log10 buckets for autotune cache keys.

    ``per_decade`` buckets per density decade: densities within the same
    bucket share one autotune measurement.  Density 0 maps to the last
    bucket (hyper-sparse).
    """
    if density <= 0:
        return 9 * per_decade
    return int(np.clip(np.floor(-np.log10(density) * per_decade),
                       0, 9 * per_decade))
