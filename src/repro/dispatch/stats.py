"""Host-side matrix statistics that drive dispatch decisions.

Everything in here is plain Python numbers computed from *concrete*
(host-visible) sparse operands.  A ``MatrixStats`` is cheap to carry
around as static metadata (e.g. in a pytree aux field), so consumers
that run under ``jax.jit`` can still plan at trace time.

The central quantity is the paper's padded-stream blow-up: the ratio of
elements the Block-ELL/SELLPACK-style layout actually streams (real +
padding) to the true nonzero count.  The crossover of the paper's Fig. 9
is exactly the sparsity where that blow-up exceeds the per-element cost
advantage the streaming path has over the scalar CSR path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.formats import (BlockCOO, BlockELL, CSR, _cdiv,
                                blockell_stream_elements,
                                sell_slot_volume)


def _structure_features(shape: Tuple[int, int], rows: np.ndarray,
                        cols: np.ndarray, row_nnz: np.ndarray
                        ) -> Dict[str, float]:
    """Row-skew and band-locality features from element coordinates.

    ``bandwidth_frac`` is the 95th percentile of the *normalized*
    diagonal distance |i/(m-1) - j/(n-1)|: ~0 for banded/diagonal
    matrices, ~0.78 for uniform-random structure (the p95 of |U - V|
    for independent uniforms).  All features are 0 for an empty matrix.
    """
    if len(rows) == 0:
        return {"row_nnz_mean": 0.0, "row_nnz_cv": 0.0, "max_row_nnz": 0,
                "bandwidth_frac": 0.0}
    m, n = shape
    mean = float(row_nnz.mean())
    cv = float(row_nnz.std() / mean) if mean > 0 else 0.0
    r_norm = rows.astype(np.float64) / max(m - 1, 1)
    c_norm = cols.astype(np.float64) / max(n - 1, 1)
    band = float(np.percentile(np.abs(r_norm - c_norm), 95))
    return {"row_nnz_mean": mean, "row_nnz_cv": cv,
            "max_row_nnz": int(row_nnz.max()), "bandwidth_frac": band}


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """Sparsity-structure summary of one sparse operand."""

    shape: Tuple[int, int]        # logical (padded) dense shape
    nnz: int                      # element-level nonzeros
    stored_elements: int          # elements the blocked layout streams
    block_m: int
    block_n: int
    n_block_rows: int
    ell_width: int                # ELL width W (0 for COO layouts)
    occupancy: float              # real blocks / stored slots (1 = no pad)
    # slots the SELL-C-σ packing would stream (real + slice padding) at
    # the default (C, σ); 0 = not measured (sell path unpriceable)
    sell_stored_elements: int = 0
    # -- structure features (0 = not measured, e.g. transposed stats) --
    row_nnz_mean: float = 0.0     # nnz per logical row
    row_nnz_cv: float = 0.0       # row-nnz coefficient of variation
    max_row_nnz: int = 0          # heaviest row (hub detection)
    bandwidth_frac: float = 0.0   # p95 normalized diagonal distance

    @property
    def dense_elements(self) -> int:
        return int(self.shape[0]) * int(self.shape[1])

    @property
    def density(self) -> float:
        return self.nnz / max(self.dense_elements, 1)

    @property
    def sparsity(self) -> float:
        return 1.0 - self.density

    @property
    def padded_stream_blowup(self) -> float:
        """Streamed elements per true nonzero (>= 1; inf for empty A)."""
        if self.nnz == 0:
            return float("inf")
        return self.stored_elements / self.nnz

    @property
    def ell_stream_estimate(self) -> int:
        """Elements the ELL-style streaming path must move, floored by
        row structure: every row streams at least the heaviest row's
        slot count (the global width is >= max_row_nnz / block_n slots
        per block-row), so a single hub row prices the whole layout.
        Falls back to ``stored_elements`` when row structure was never
        measured (``max_row_nnz == 0``)."""
        if self.max_row_nnz <= 0:
            return self.stored_elements
        m_pad = self.n_block_rows * max(self.block_m, 1)
        return max(self.stored_elements, m_pad * self.max_row_nnz)

    def with_capacity(self, capacity: int) -> "MatrixStats":
        """Stats restated at a mutable overlay's **slot capacity**.

        A :class:`repro.serve.runtime.DeltaGraph` patches edge deltas
        into reserved slack slots without changing any array shape, so
        the stats its served matrix carries must stay *constant* between
        repacks — otherwise every delta would change the jit aux and
        retrace every consumer.  The stable choice is to price the
        overlay at its capacity (live + slack slots): conservative for
        every per-element path, and exactly what the layout streams once
        tombstones and free slots are counted.  The planner re-prices
        from exact live stats at repack boundaries (see
        ``DeltaGraph.exact_stats``).
        """
        cap = int(capacity)
        if cap < self.nnz:
            raise ValueError(
                f"capacity {cap} < live nnz {self.nnz}; an overlay "
                "cannot hold fewer slots than stored elements")
        return dataclasses.replace(
            self, nnz=cap,
            stored_elements=max(self.stored_elements, cap),
            sell_stored_elements=(max(self.sell_stored_elements, cap)
                                  if self.sell_stored_elements else 0))

    # -- constructors -------------------------------------------------------

    @staticmethod
    def from_coords(shape: Tuple[int, int], rows: np.ndarray,
                    cols: np.ndarray, block_m: int = 1, block_n: int = 1,
                    nnz: Optional[int] = None) -> "MatrixStats":
        """Blocked-layout stats from element coordinates (no blocks
        built).  This is the one shared granularity: every constructor
        below reduces to it, so stats of the same matrix agree across
        storage forms."""
        m, n = int(shape[0]), int(shape[1])
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        cols = np.asarray(cols, dtype=np.int64).reshape(-1)
        if nnz is None:
            nnz = len(rows)
        bm, bn = int(block_m), int(block_n)
        nbr, nbc = _cdiv(m, bm), _cdiv(n, bn)
        bids = (rows // bm) * nbc + cols // bn
        ub = np.unique(bids)
        counts = np.bincount((ub // nbc).astype(np.int64), minlength=nbr)
        width = max(int(counts.max()) if len(counts) else 0, 1)
        row_nnz = np.bincount(rows, minlength=m)
        return MatrixStats(
            shape=(nbr * bm, nbc * bn),
            nnz=int(nnz),
            stored_elements=int(nbr * width * bm * bn),
            block_m=bm,
            block_n=bn,
            n_block_rows=nbr,
            ell_width=width,
            occupancy=len(ub) / max(nbr * width, 1),
            sell_stored_elements=sell_slot_volume(row_nnz),
            **_structure_features((m, n), rows, cols, row_nnz),
        )

    @staticmethod
    def from_blockell(ell: BlockELL, nnz: Optional[int] = None
                      ) -> "MatrixStats":
        """Stats of a concrete BlockELL (host transfer of `blocks` if
        ``nnz`` is not supplied)."""
        blocks = np.asarray(ell.blocks)  # [nbr, W, bm, bn]
        if nnz is None:
            nnz = int(np.count_nonzero(blocks))
        # global element coordinates of the stored nonzeros
        br, slot, i, j = np.nonzero(blocks)
        grows = br.astype(np.int64) * ell.bm + i
        gcols = (np.asarray(ell.indices, dtype=np.int64)[br, slot] * ell.bn
                 + j)
        row_nnz = np.bincount(grows, minlength=ell.shape[0])
        nbr, w = ell.n_block_rows, ell.ell_width
        return MatrixStats(
            shape=ell.shape,
            nnz=int(nnz),
            stored_elements=int(blockell_stream_elements(ell))
            - nbr * w,  # count data words only, not the index words
            block_m=ell.bm,
            block_n=ell.bn,
            n_block_rows=nbr,
            ell_width=w,
            occupancy=ell.occupancy(),
            sell_stored_elements=sell_slot_volume(row_nnz),
            **_structure_features(ell.shape, grows, gcols, row_nnz),
        )

    @staticmethod
    def from_blockcoo(coo: BlockCOO, nnz: Optional[int] = None
                      ) -> "MatrixStats":
        blocks = np.asarray(coo.blocks)
        if nnz is None:
            nnz = int(np.count_nonzero(blocks))
        nnzb = coo.nnzb
        real = int((blocks.reshape(nnzb, -1) != 0).any(axis=1).sum())
        e, i, j = np.nonzero(blocks)
        grows = np.asarray(coo.rows)[e].astype(np.int64) * coo.bm + i
        gcols = np.asarray(coo.cols)[e].astype(np.int64) * coo.bn + j
        row_nnz = np.bincount(grows, minlength=coo.shape[0])
        return MatrixStats(
            shape=coo.shape,
            nnz=int(nnz),
            stored_elements=int(nnzb * coo.bm * coo.bn),
            block_m=coo.bm,
            block_n=coo.bn,
            n_block_rows=coo.shape[0] // coo.bm,
            ell_width=0,
            occupancy=real / max(nnzb, 1),
            sell_stored_elements=sell_slot_volume(row_nnz),
            **_structure_features(coo.shape, grows, gcols, row_nnz),
        )

    @staticmethod
    def from_csr(csr: CSR, block_m: int = 1, block_n: int = 1
                 ) -> "MatrixStats":
        """Stats of a host CSR, priced at the same blocked granularity
        as every other constructor (see :meth:`from_coords`).

        With the default 1x1 block this is element-ELL pricing: the
        streaming layout's width is the heaviest row's nnz, so
        ``stored_elements == M * max_row_nnz`` — NOT ``nnz``.  Pricing
        the ELL path at raw nnz made the same matrix auto-plan
        differently depending on which form its stats were measured
        from (csr-built stats always picked ell).
        """
        rows = np.repeat(np.arange(csr.shape[0], dtype=np.int64),
                         np.diff(csr.indptr))
        return MatrixStats.from_coords(
            csr.shape, rows, np.asarray(csr.indices, dtype=np.int64),
            block_m=block_m, block_n=block_n, nnz=csr.nnz)


def sparsity_bucket(density: float, per_decade: int = 2) -> int:
    """Discretize density into log10 buckets for autotune cache keys.

    ``per_decade`` buckets per density decade: densities within the same
    bucket share one autotune measurement.  Density 0 maps to the last
    bucket (hyper-sparse).
    """
    if density <= 0:
        return 9 * per_decade
    return int(np.clip(np.floor(-np.log10(density) * per_decade),
                       0, 9 * per_decade))
