"""DLMC-style synthetic matrix corpus (see ``generators``)."""
from repro.corpus.generators import (CorpusSpec, FAMILIES, default_corpus,
                                     make_dense, make_matrix)

__all__ = ["CorpusSpec", "FAMILIES", "default_corpus", "make_dense",
           "make_matrix"]
