"""Synthetic DLMC-style matrix corpus.

Every benchmark and dispatch decision in this repo was historically made
on uniform-random sparsity — the one structure the paper's target
workloads (GNNs, recommenders, pruned transformers) do *not* have.
This module generates the missing structures behind one
``CorpusSpec -> dense / SparseMatrix`` factory:

  * ``uniform``       — iid Bernoulli mask (the legacy baseline);
  * ``powerlaw``      — Zipf row degrees (hub-heavy graph adjacency,
                        the structure that breaks global-width ELL);
  * ``rmat``          — R-MAT recursive quadrant sampling (skewed AND
                        community-clustered, à la Graph500);
  * ``banded``        — nonzeros confined to a diagonal band, with a
                        diagonal-dominant guarantee (stencils, tridiag
                        systems, tracking graphs);
  * ``block_pruned``  — dense blocks surviving structured magnitude
                        pruning (DLMC transformer-weight patterns).

Generators are deterministic under ``spec.seed`` and hit the requested
global sparsity exactly (up to family-capacity clamps, e.g. a band can
hold only so many nonzeros).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

FAMILIES = ("uniform", "powerlaw", "rmat", "banded", "block_pruned")


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    """One corpus matrix: a family plus its structural knobs."""

    family: str
    shape: Tuple[int, int] = (256, 256)
    sparsity: float = 0.9
    seed: int = 0
    # powerlaw: Zipf exponent of the row-degree distribution (larger =
    # more hub-concentrated)
    alpha: float = 1.2
    # banded: half-bandwidth (nonzeros satisfy |i - j| <= band_width)
    band_width: int = 16
    # block_pruned: granule of the structured pruning mask
    block: Tuple[int, int] = (8, 8)
    # rmat: quadrant probabilities (a, b, c, d), Graph500 defaults
    rmat_probs: Tuple[float, float, float, float] = (0.57, 0.19, 0.19, 0.05)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"unknown corpus family {self.family!r}; "
                f"expected one of {FAMILIES}")
        if not 0.0 <= self.sparsity <= 1.0:
            raise ValueError(f"sparsity must be in [0, 1], "
                             f"got {self.sparsity}")

    @property
    def name(self) -> str:
        m, n = self.shape
        return f"{self.family}_{m}x{n}_s{self.sparsity:g}_seed{self.seed}"

    @property
    def target_nnz(self) -> int:
        m, n = self.shape
        return int(round(m * n * (1.0 - self.sparsity)))


def _values(rng: np.random.Generator, k: int) -> np.ndarray:
    v = rng.standard_normal(k).astype(np.float32)
    return np.where(v == 0, np.float32(1.0), v)


def _fill(shape, rows, cols, vals) -> np.ndarray:
    a = np.zeros(shape, np.float32)
    a[rows, cols] = vals
    return a


def _uniform(spec: CorpusSpec, rng: np.random.Generator) -> np.ndarray:
    m, n = spec.shape
    idx = rng.choice(m * n, size=min(spec.target_nnz, m * n), replace=False)
    return _fill(spec.shape, idx // n, idx % n, _values(rng, len(idx)))


def _zipf_row_counts(spec: CorpusSpec, rng: np.random.Generator
                     ) -> np.ndarray:
    """Per-row nnz targets: Zipf weights, exact total, capped at n."""
    m, n = spec.shape
    k = min(spec.target_nnz, m * n)
    w = (np.arange(m, dtype=np.float64) + 1.0) ** (-spec.alpha)
    rng.shuffle(w)  # hubs land on random rows, not row 0..h
    raw = k * w / w.sum()
    counts = np.floor(raw).astype(np.int64)
    # distribute the rounding deficit to the largest remainders, then
    # push any per-row overflow (count > n) down the weight order
    deficit = k - int(counts.sum())
    if deficit > 0:
        order = np.argsort(-(raw - counts), kind="stable")
        counts[order[:deficit]] += 1
    counts = np.minimum(counts, n)
    overflow = k - int(counts.sum())
    while overflow > 0:
        room = np.flatnonzero(counts < n)
        if len(room) == 0:
            break
        take = room[np.argsort(-w[room], kind="stable")][:overflow]
        counts[take] += 1
        overflow = k - int(counts.sum())
    return counts


def _powerlaw(spec: CorpusSpec, rng: np.random.Generator) -> np.ndarray:
    m, n = spec.shape
    counts = _zipf_row_counts(spec, rng)
    rows = np.repeat(np.arange(m, dtype=np.int64), counts)
    cols = np.concatenate([
        rng.choice(n, size=c, replace=False) for c in counts if c
    ]) if counts.sum() else np.zeros(0, np.int64)
    return _fill(spec.shape, rows, cols, _values(rng, len(rows)))


def _rmat(spec: CorpusSpec, rng: np.random.Generator) -> np.ndarray:
    m, n = spec.shape
    k = min(spec.target_nnz, m * n)
    bits_r = max(int(np.ceil(np.log2(max(m, 1)))), 1)
    bits_c = max(int(np.ceil(np.log2(max(n, 1)))), 1)
    bits = max(bits_r, bits_c)
    a, b, c, _ = spec.rmat_probs
    seen: set = set()
    rows, cols = [], []
    # oversample per round; duplicates and out-of-range coords are
    # rejected, so a few rounds converge on the target count
    for _round in range(64):
        need = k - len(rows)
        if need <= 0:
            break
        draw = max(2 * need, 64)
        u = rng.random((draw, bits))
        i = np.zeros(draw, np.int64)
        j = np.zeros(draw, np.int64)
        for lvl in range(bits):
            ul = u[:, lvl]
            right = ((ul >= a) & (ul < a + b)) | (ul >= a + b + c)
            down = ul >= a + b
            i = (i << 1) | down.astype(np.int64)
            j = (j << 1) | right.astype(np.int64)
        ok = (i < m) & (j < n)
        for ii, jj in zip(i[ok], j[ok]):
            key = (int(ii), int(jj))
            if key not in seen:
                seen.add(key)
                rows.append(ii)
                cols.append(jj)
                if len(rows) >= k:
                    break
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    return _fill(spec.shape, rows, cols, _values(rng, len(rows)))


def _banded(spec: CorpusSpec, rng: np.random.Generator) -> np.ndarray:
    m, n = spec.shape
    bw = max(int(spec.band_width), 0)
    i = np.repeat(np.arange(m, dtype=np.int64), 2 * bw + 1)
    j = i + np.tile(np.arange(-bw, bw + 1, dtype=np.int64), m)
    ok = (j >= 0) & (j < n)
    band_i, band_j = i[ok], j[ok]
    k = min(spec.target_nnz, len(band_i))  # band capacity clamp
    diag = band_i == band_j
    diag_idx = np.flatnonzero(diag)
    off_idx = np.flatnonzero(~diag)
    # diagonal first (diagonal dominance), then random in-band fill
    take_diag = diag_idx[:k]
    rest = k - len(take_diag)
    take_off = rng.choice(off_idx, size=rest, replace=False) if rest else \
        np.zeros(0, np.int64)
    sel = np.concatenate([take_diag, take_off])
    vals = _values(rng, len(sel))
    # make the kept diagonal entries dominate their row sums
    vals[: len(take_diag)] = np.abs(vals[: len(take_diag)]) + 2.0 * bw
    return _fill(spec.shape, band_i[sel], band_j[sel], vals)


def _block_pruned(spec: CorpusSpec, rng: np.random.Generator) -> np.ndarray:
    m, n = spec.shape
    bm, bn = spec.block
    if m % bm or n % bn:
        raise ValueError(
            f"block_pruned needs shape divisible by block, got "
            f"{spec.shape} / {spec.block}")
    gm, gn = m // bm, n // bn
    kb = int(round(min(spec.target_nnz, m * n) / (bm * bn)))
    kb = min(kb, gm * gn)
    keep = rng.choice(gm * gn, size=kb, replace=False)
    tiles = np.zeros((gm, gn, bm, bn), np.float32)
    tiles[keep // gn, keep % gn] = _values(rng, kb * bm * bn) \
        .reshape(kb, bm, bn)
    return tiles.transpose(0, 2, 1, 3).reshape(m, n)


_GENERATORS = {
    "uniform": _uniform,
    "powerlaw": _powerlaw,
    "rmat": _rmat,
    "banded": _banded,
    "block_pruned": _block_pruned,
}


def make_dense(spec: CorpusSpec) -> np.ndarray:
    """Concrete dense [M, N] float32 realization of one spec."""
    rng = np.random.default_rng(spec.seed)
    return _GENERATORS[spec.family](spec, rng)


def make_matrix(spec: CorpusSpec, *,
                formats: Optional[Tuple[str, ...]] = ("ell", "sell", "csr"),
                format: str = "auto",
                block: Tuple[int, int] = (64, 64)):
    """``CorpusSpec -> SparseMatrix`` factory.

    Defaults to carrying all three sparse forms so every execution path
    is a dispatch candidate; pass ``formats=None`` to let the auto
    format picker choose a single form from the measured structure.
    """
    from repro.sparse.matrix import SparseMatrix

    return SparseMatrix.from_dense(make_dense(spec), formats=formats,
                                   format=format, block=block)


def default_corpus(quick: bool = True, seed: int = 0):
    """The standard sweep: every family at moderate and hyper sparsity."""
    shape = (256, 256) if quick else (1024, 1024)
    bw = 16 if quick else 48
    specs = []
    for sparsity in (0.9, 0.99):
        for family in FAMILIES:
            specs.append(CorpusSpec(
                family=family, shape=shape, sparsity=sparsity, seed=seed,
                band_width=bw))
    return specs
