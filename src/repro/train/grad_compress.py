"""Gradient compression for cross-pod reduction.

At 1000+ node scale the inter-pod (DCN) all-reduce dominates step time for
DP-heavy configs.  Two standard compressors, both with exact shape-
preserving decompress so they drop into the train step between grad
computation and the optimizer:

  * int8 stochastic-free symmetric quantization (8x volume reduction on
    the wire; here modeled as a quantize->dequantize round trip).
  * top-k with error feedback: only the largest k-fraction of entries are
    reduced; the residual is fed back next step so the compressor is
    contractive (EF-SGD / Deep Gradient Compression).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_roundtrip(g):
    """Symmetric per-tensor int8 quantize -> dequantize."""
    a = jnp.max(jnp.abs(g))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_int8(grads):
    return jax.tree_util.tree_map(int8_roundtrip, grads)


def _topk_one(g, residual, k_frac: float):
    acc = g.astype(jnp.float32) + residual
    flat = acc.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    sent = flat * mask
    new_residual = (flat - sent).reshape(g.shape)
    return sent.reshape(g.shape), new_residual


def init_residual(params):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)


def compress_topk_ef(grads, residual, k_frac: float = 0.05):
    """Top-k sparsification with error feedback.

    Returns (compressed grads, new residual).  The compressed tensor is
    dense-shaped but k-sparse — on the wire it would ship (indices,
    values); volume ratio ~ 2*k_frac of dense.
    """
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    outs = [_topk_one(g, r, k_frac) for g, r in zip(flat_g, flat_r)]
    sent = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_res = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return sent, new_res
