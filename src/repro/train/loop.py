"""Training step + loop with microbatching, compression, checkpoints."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.models.transformer import lm_loss
from repro.resilience import chaos
from repro.resilience.errors import FATAL, classify
from repro.train.grad_compress import (compress_int8, compress_topk_ef,
                                       init_residual)
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1  # grad accumulation steps per optimizer step
    compression: str = "none"  # none | int8 | topk_ef
    topk_frac: float = 0.05
    aux_weight: float = 0.01
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save dot outputs)
    # cast f32 master params to compute dtype ONCE at step entry, so FSDP
    # weight all-gathers move bf16 instead of f32 (§Perf hypothesis)
    cast_params_once: bool = False
    compute_dtype: str = "bfloat16"


def init_train_state(params, tcfg: TrainConfig):
    state = {"opt": init_opt_state(params)}
    if tcfg.compression == "topk_ef":
        state["residual"] = init_residual(params)
    return state


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    loss_fn: Optional[Callable] = None):
    """Returns train_step(params, state, batch) -> (params, state, metrics).

    Microbatching: batch's leading dim is split into ``tcfg.microbatches``
    slices; grads are accumulated in f32 before the (single) optimizer
    update — grad-accumulation for memory, and the unit the GPipe wrapper
    schedules over stages.
    """
    loss_fn = loss_fn or (
        lambda p, b: lm_loss(p, cfg, b, aux_weight=tcfg.aux_weight,
                             remat=tcfg.remat,
                             remat_policy=tcfg.remat_policy))
    if tcfg.cast_params_once:
        base_loss = loss_fn
        cdt = jnp.dtype(tcfg.compute_dtype)

        def loss_fn(p, b):  # noqa: F811
            pc = jax.tree_util.tree_map(
                lambda x: x.astype(cdt)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
            return base_loss(pc, b)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, state, batch):
        nm = tcfg.microbatches
        if nm == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, mb)
                grads_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                batch)
            zero = jax.tree_util.tree_map(
                lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), mbs)
            loss = loss / nm
            grads = jax.tree_util.tree_map(lambda g: g / nm, grads)

        state = dict(state)
        if tcfg.compression == "int8":
            grads = compress_int8(grads)
        elif tcfg.compression == "topk_ef":
            grads, state["residual"] = compress_topk_ef(
                grads, state["residual"], tcfg.topk_frac)

        params, state["opt"], opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.opt)
        metrics = {"loss": loss, **opt_metrics}
        return params, state, metrics

    return train_step


def train_loop(params, state, train_step, data_iter, n_steps: int, *,
               log_every: int = 10, checkpointer=None, ckpt_every: int = 0,
               health=None, callback=None, data_factory=None,
               max_recoveries: int = 0) -> Dict[str, Any]:
    """Host-side loop: timing, straggler detection, periodic checkpoints.

    Crash recovery (see DESIGN.md "Resilience"): with a ``checkpointer``,
    ``data_factory`` and ``max_recoveries > 0``, an exception escaping a
    step restores params/state from the newest intact checkpoint, rewinds
    the data stream with ``data_factory(restored_step)`` (a fresh
    iterator positioned at that step), and replays — deterministic data
    plus a deterministic step function reconverge to the same final
    loss.  With no checkpoint published yet, recovery restarts from the
    *initial* params/state (step 0).  Each recovery counts in
    ``resilience_recoveries_total{site="train"}``; the total is returned
    under ``"recoveries"``.
    """
    can_recover = (checkpointer is not None and data_factory is not None
                   and max_recoveries > 0)
    if can_recover:
        # keep the step-0 state restorable before the first checkpoint
        # (donation would otherwise invalidate these buffers)
        init_snapshot = jax.tree_util.tree_map(np.asarray, (params, state))
    history = []
    recoveries = 0
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))
    step = 0
    while step < n_steps:
        try:
            chaos.hook("train.step", step=step)
            batch = next(data_iter)
            t0 = time.perf_counter()
            with obs.span("train.step", step=step):
                params, state, metrics = step_fn(params, state, batch)
                loss = float(metrics["loss"])  # blocks; keeps timing honest
        except Exception as exc:  # noqa: BLE001 — classified below
            if not can_recover or recoveries >= max_recoveries \
                    or classify(exc) == FATAL:
                raise
            recoveries += 1
            obs.counter("resilience_recoveries_total", site="train").inc()
            checkpointer.wait()  # let any in-flight save publish
            restored = checkpointer.latest_step()
            if restored is None:
                restored = 0
                params, state = jax.tree_util.tree_map(
                    jnp.asarray, init_snapshot)
            else:
                tree = checkpointer.restore(
                    {"params": params, "state": state})
                params, state = tree["params"], tree["state"]
            data_iter = data_factory(restored)
            history = [h for h in history if h["step"] < restored]
            step = restored
            continue
        dt = time.perf_counter() - t0
        obs.histogram("train_step_ms").observe(dt * 1e3)
        obs.gauge("train_loss").set(loss)
        if health is not None and health.record(step, dt):
            obs.counter("train_stragglers_total").inc()
        if step % log_every == 0:
            history.append({"step": step, "loss": loss, "time_s": dt})
        if checkpointer is not None and ckpt_every and \
                (step + 1) % ckpt_every == 0:
            checkpointer.save(step + 1, {"params": params, "state": state})
        if callback is not None:
            callback(step, params, state, metrics)
        step += 1
    return {"params": params, "state": state, "history": history,
            "recoveries": recoveries}
