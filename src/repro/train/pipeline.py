"""GPipe-style pipeline parallelism over a mesh axis.

The framework's default distribution is FSDP+TP(+pod-DP); at 1000+ node
scale an inter-pod *pipeline* axis trades the cross-pod gradient
all-reduce for point-to-point activation transfers.  This module provides
a self-contained shard_map GPipe: each rank along ``axis`` owns one
contiguous stage of layer periods; microbatches stream through with
ppermute handoffs (1F1B-ish schedule: forward fill, steady state,
drain).

It is exercised by tests on a local mesh (tests/test_pipeline.py) and is
a config option for the trainer, not the default dry-run path.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh: Mesh,
                   *, axis: str = "pod"):
    """Run microbatches through pipeline stages laid out along ``axis``.

    stage_fn(params, x) -> x          (one stage's computation)
    stage_params: pytree with a leading [n_stages] axis (sharded over
        ``axis`` — each rank holds its own stage's params).
    x_micro: [n_micro, mb, ...] microbatched input (replicated).
    Returns [n_micro, mb, ...] outputs (replicated), computed as
    stage_{S-1}(... stage_0(x)).

    Schedule: n_micro + n_stages - 1 ticks.  At tick t, stage s processes
    microbatch (t - s) if 0 <= t - s < n_micro; activations ppermute to
    s+1 between ticks.  Bubble fraction = (S-1)/(n_micro + S - 1).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def local(params_stacked, xs):
        params = jax.tree_util.tree_map(lambda a: a[0], params_stacked)
        sid = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)  # activation register
        outs = jnp.zeros((n_micro,) + mb_shape, xs.dtype)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = t - sid
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests a fresh microbatch from xs
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(sid == 0, feed, buf)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage records finished microbatches
            outs = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, n_micro - 1), 0),
                lambda o: o,
                outs)
            # hand activations to the next stage
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                      jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    other = tuple(a for a in mesh.axis_names if a != axis)
    in_specs = (
        jax.tree_util.tree_map(lambda _: P(axis), stage_params),
        P(),
    )
    fn = shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    del other
    return fn(stage_params, x_micro)
