"""AdamW + warmup-cosine schedule + global-norm clipping (pure JAX).

Optimizer state pytrees mirror the parameter pytree, so whatever sharding
the params carry (FSDP over `data`, TP over `model`) is inherited by m/v —
ZeRO-style sharded optimizer state falls out of the sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree_util.tree_map(  # noqa: E731
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(step, cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        p_new = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
