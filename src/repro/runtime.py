"""Global execution-mode flags (cost-model compiles vs production).

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count (verified empirically — see DESIGN.md §6 / EXPERIMENTS.md §Method).
The production path uses lax.scan over layer periods (small HLO, fast
compiles, exact memory analysis), which would silently under-report
FLOPs/bytes/collectives.  For roofline extraction the dry-run therefore
recompiles a 1-period and a 2-period variant of the model in COST MODE —
all loops unrolled to straight-line HLO so cost_analysis is exact — and
extrapolates:  cost(n) = cost(1p) + (n-1) * (cost(2p) - cost(1p)).

``cost_mode()`` flips every loop site (period scan, flash-attention chunk
loops, chunked CE, SSD chunk scan, whisper encoder stack) to its unrolled
form.  ``causal_skip`` additionally enables static causal block skipping
in unrolled flash attention (q-chunk i only visits kv-chunks 0..i) — the
§Perf optimization measured against the masked-all-blocks baseline.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

_state = threading.local()


def unrolled() -> bool:
    return getattr(_state, "unroll", False)


def causal_skip() -> bool:
    return getattr(_state, "causal_skip", False)


def attn_chunk_override() -> Optional[int]:
    return getattr(_state, "attn_chunk", None)


@contextlib.contextmanager
def cost_mode(*, causal_skip: bool = False,
              attn_chunk: Optional[int] = None):
    prev = (getattr(_state, "unroll", False),
            getattr(_state, "causal_skip", False),
            getattr(_state, "attn_chunk", None))
    _state.unroll, _state.causal_skip, _state.attn_chunk = \
        True, causal_skip, attn_chunk
    try:
        yield
    finally:
        _state.unroll, _state.causal_skip, _state.attn_chunk = prev
