"""Deprecated-key shim for unified ``report()`` schemas.

PR 8 unified the report key vocabulary across the serving stack
(``p50_ms``/``p99_ms`` for latency percentiles, ``waste`` for the
padding ledger, ``compiles`` everywhere a compile counter appears).
Reports are plain dicts holding only the canonical keys; wrapping them
in :func:`renamed_keys` keeps the old spellings readable for one
deprecation cycle — reading an old key returns the canonical value and
emits a ``DeprecationWarning`` naming the replacement.

The shim is a ``dict`` subclass storing canonical keys only, so
``json.dumps``, iteration, and ``.keys()`` all see the new schema; only
``[]`` / ``get`` / ``in`` honor the aliases.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Mapping


class ReportDict(dict):
    """dict whose deprecated key aliases still resolve (with a warning)."""

    def __init__(self, data: Mapping[str, Any],
                 aliases: Mapping[str, str]):
        super().__init__(data)
        for old, new in aliases.items():
            if new not in data:
                raise KeyError(
                    f"alias target {new!r} (for deprecated {old!r}) is "
                    f"not a report key: {sorted(data)}")
        self._aliases: Dict[str, str] = dict(aliases)

    def _resolve(self, key):
        new = self._aliases.get(key)
        if new is not None and not dict.__contains__(self, key):
            warnings.warn(
                f"report key {key!r} is deprecated; use {new!r}",
                DeprecationWarning, stacklevel=3)
            return new
        return key

    def __getitem__(self, key):
        return dict.__getitem__(self, self._resolve(key))

    def get(self, key, default=None):
        return dict.get(self, self._resolve(key), default)

    def __contains__(self, key):
        return dict.__contains__(self, key) or (
            key in self._aliases
            and dict.__contains__(self, self._aliases[key]))


def renamed_keys(data: Mapping[str, Any],
                 aliases: Mapping[str, str]) -> ReportDict:
    """Wrap a canonical report so old key spellings keep working.

    ``aliases`` maps deprecated name -> canonical name.
    """
    return ReportDict(data, aliases)
