"""repro.obs — unified observability: metrics, traces, sentry, audit.

One import gives every layer the same four instruments:

* ``obs.counter/gauge/histogram(name, **labels)`` — series in the
  process-wide :data:`REGISTRY` (``snapshot()``, ``to_prometheus()``,
  ``to_jsonl()``).
* ``obs.span(name, **tags)`` — timed spans with parent propagation
  through the serve and train paths (:data:`TRACER`).
* :data:`SENTRY` — compiles-vs-calls per executor lane; any compile
  past a lane's warmup is an ``unexpected_retrace`` event.
* :data:`AUDIT` — predicted-vs-measured cost trail per
  (op, path, stats-bucket).

``obs.snapshot()`` is the one-call export: metrics + span summary +
sentry lanes/events + audit rows.  ``obs.reset()`` clears everything
(tests, per-run scoping).

The singletons are module-level so the dispatcher, the bucketed
executor, the serving engines, and the train loop all write into one
sink without plumbing a handle through every constructor; code that
needs isolation (a multi-worker tier with one registry per worker)
instantiates the classes directly.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.obs.audit import AuditRow, CostAudit, stats_bucket
from repro.obs.compat import ReportDict, renamed_keys
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.sentry import RetraceEvent, RetraceSentry, instrumented_jit
from repro.obs.tracing import SpanRecord, Tracer

REGISTRY = MetricsRegistry()
TRACER = Tracer(registry=REGISTRY)
SENTRY = RetraceSentry(registry=REGISTRY)
AUDIT = CostAudit(registry=REGISTRY)

# bound convenience entry points (the common call sites)
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
span = TRACER.span


def snapshot() -> Dict[str, Any]:
    """One coherent view of everything the process observed.

    Stable schema (pinned in ``tests/test_obs.py``)::

        {"metrics":  {"counters": ..., "gauges": ..., "histograms": ...},
         "spans":    {name: {"count", "total_ms", "p50_ms", "max_ms"}},
         "sentry":   {"lanes", "compiles", "calls",
                      "unexpected_retraces", "events"},
         "audit":    {"rows", "summary", "mispredictions"}}
    """
    return {
        "metrics": REGISTRY.snapshot(),
        "spans": TRACER.summary(),
        "sentry": SENTRY.report(),
        "audit": AUDIT.report(),
    }


def to_prometheus() -> str:
    """Prometheus text exposition of the metrics registry."""
    return REGISTRY.to_prometheus()


def to_jsonl() -> str:
    """JSON-lines export: metric series followed by span records."""
    return REGISTRY.to_jsonl() + TRACER.to_jsonl()


def reset() -> None:
    """Clear every instrument (tests / per-run scoping)."""
    REGISTRY.reset()
    TRACER.clear()
    SENTRY.clear()
    AUDIT.clear()


__all__ = [
    "AUDIT", "AuditRow", "CostAudit", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "REGISTRY", "ReportDict", "RetraceEvent",
    "RetraceSentry", "SENTRY", "SpanRecord", "TRACER", "Tracer",
    "counter", "gauge", "histogram", "instrumented_jit", "renamed_keys",
    "reset", "snapshot", "span", "stats_bucket", "to_jsonl",
    "to_prometheus",
]
