"""Thread-safe metrics registry: counters, gauges, histograms.

One process-wide :class:`MetricsRegistry` (``repro.obs.REGISTRY``) is the
single sink every layer reports into — the dispatcher counts plans, the
bucketed executor counts compiles/calls/evictions, the serving engines
observe latencies, the ladder counts refits, the padding ledger streams
its volume counters.  The scattered per-object ``report()`` methods stay
as *views*; the registry is the substrate a multi-worker tier scrapes.

Metrics are keyed by ``(name, labels)`` where labels are a small
``str -> str`` mapping (``op="spmm", path="ell"``).  Keep label
cardinality bounded: one series exists per distinct label set.

Exporters:

* :meth:`MetricsRegistry.snapshot` — nested plain-dict view (stable
  schema, pinned in ``tests/test_obs.py``).
* :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
  (histograms as summaries with p50/p90/p99 quantiles).
* :meth:`MetricsRegistry.to_jsonl` — one JSON object per series per
  line, for log shipping.
"""
from __future__ import annotations

import collections
import json
import threading
from typing import Any, Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]   # sorted (k, v) pairs


def _label_key(labels: Mapping[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    """Stable flat form used as the snapshot dict key ("" = unlabeled)."""
    return ",".join(f"{k}={v}" for k, v in key)


class Counter:
    """Monotonic counter (increments only)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increments must be >= 0, got {n}")
        with self._lock:
            self.value += n


class Gauge:
    """Point-in-time value (set / add)."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += float(n)

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)


class Histogram:
    """Streaming distribution: exact count/sum/min/max plus a bounded
    reservoir of the most recent observations for quantiles.

    The reservoir is a ring (default 2048): quantiles reflect *recent*
    behavior, which is what serving dashboards want, while count/sum
    stay exact over the process lifetime.
    """

    __slots__ = ("_lock", "count", "sum", "min", "max", "_recent")

    def __init__(self, lock: threading.RLock, reservoir: int = 2048):
        self._lock = lock
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._recent: Deque[float] = collections.deque(maxlen=reservoir)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self._recent.append(v)

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self._recent:
                return 0.0
            return float(np.percentile(np.asarray(self._recent), q))

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
            arr = np.asarray(self._recent)
            p50, p90, p99 = np.percentile(arr, (50, 90, 99))
            return {
                "count": self.count,
                "sum": round(self.sum, 6),
                "min": round(self.min, 6),
                "max": round(self.max, 6),
                "mean": round(self.sum / self.count, 6),
                "p50": round(float(p50), 6),
                "p90": round(float(p90), 6),
                "p99": round(float(p99), 6),
            }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of labeled metric series (thread-safe)."""

    def __init__(self, reservoir: int = 2048):
        self._lock = threading.RLock()
        self._reservoir = int(reservoir)
        # name -> kind; (name, label_key) -> metric object
        self._kinds: Dict[str, str] = {}
        self._series: Dict[Tuple[str, LabelKey], Any] = {}

    # -- get-or-create -------------------------------------------------------

    def _get(self, kind: str, name: str, labels: Mapping[str, Any]):
        key = (name, _label_key(labels))
        with self._lock:
            existing = self._kinds.get(name)
            if existing is None:
                self._kinds[name] = kind
            elif existing != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {existing}, "
                    f"requested as {kind}")
            metric = self._series.get(key)
            if metric is None:
                if kind == "histogram":
                    metric = Histogram(self._lock, self._reservoir)
                else:
                    metric = _KINDS[kind](self._lock)
                self._series[key] = metric
            return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # -- reading -------------------------------------------------------------

    def value(self, name: str, **labels) -> Optional[float]:
        """Current value of one series (None when it does not exist)."""
        key = (name, _label_key(labels))
        with self._lock:
            metric = self._series.get(key)
            if metric is None:
                return None
            if isinstance(metric, Histogram):
                return float(metric.count)
            return metric.value

    def total(self, name: str) -> float:
        """Sum of a counter/gauge over every label set (0 when absent)."""
        with self._lock:
            return sum(m.value for (n, _), m in self._series.items()
                       if n == name and not isinstance(m, Histogram))

    def snapshot(self) -> Dict[str, Dict[str, Dict[str, Any]]]:
        """Stable nested view: kind -> name -> label_str -> value/summary."""
        out: Dict[str, Dict[str, Dict[str, Any]]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            for (name, lkey), metric in sorted(self._series.items()):
                kind = self._kinds[name]
                ls = _label_str(lkey)
                if kind == "counter":
                    out["counters"].setdefault(name, {})[ls] = metric.value
                elif kind == "gauge":
                    out["gauges"].setdefault(name, {})[ls] = metric.value
                else:
                    out["histograms"].setdefault(name, {})[ls] = \
                        metric.summary()
        return out

    # -- exporters -----------------------------------------------------------

    @staticmethod
    def _prom_name(name: str) -> str:
        return "".join(c if (c.isalnum() or c == "_") else "_"
                       for c in name)

    @staticmethod
    def _prom_labels(lkey: LabelKey, extra: str = "") -> str:
        parts = [f'{MetricsRegistry._prom_name(k)}="{v}"' for k, v in lkey]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (histograms as summaries)."""
        lines: List[str] = []
        with self._lock:
            by_name: Dict[str, List[Tuple[LabelKey, Any]]] = {}
            for (name, lkey), metric in sorted(self._series.items()):
                by_name.setdefault(name, []).append((lkey, metric))
            for name, series in by_name.items():
                kind = self._kinds[name]
                pn = self._prom_name(name)
                lines.append(f"# TYPE {pn} "
                             f"{'summary' if kind == 'histogram' else kind}")
                for lkey, metric in series:
                    if kind == "histogram":
                        s = metric.summary()
                        for q, k in ((0.5, "p50"), (0.9, "p90"),
                                     (0.99, "p99")):
                            lab = self._prom_labels(
                                lkey, f'quantile="{q}"')
                            lines.append(f"{pn}{lab} {s[k]}")
                        lab = self._prom_labels(lkey)
                        lines.append(f"{pn}_sum{lab} {s['sum']}")
                        lines.append(f"{pn}_count{lab} {s['count']}")
                    else:
                        lab = self._prom_labels(lkey)
                        lines.append(f"{pn}{lab} {metric.value}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_jsonl(self) -> str:
        """One JSON object per series per line (log-shipping format)."""
        lines: List[str] = []
        with self._lock:
            for (name, lkey), metric in sorted(self._series.items()):
                kind = self._kinds[name]
                rec: Dict[str, Any] = {
                    "name": name, "type": kind, "labels": dict(lkey)}
                if kind == "histogram":
                    rec.update(metric.summary())
                else:
                    rec["value"] = metric.value
                lines.append(json.dumps(rec, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        """Drop every series (tests / per-run scoping)."""
        with self._lock:
            self._kinds.clear()
            self._series.clear()
