"""Cost-model audit: predicted path costs vs measured wall time.

The dispatch layer picks execution paths from an analytic cost model
(``repro.dispatch.cost_model``).  Mispredictions — the ELL hub-row case
PR 6 fixed, a miscalibrated constant, a backend where the model was
never measured — previously only surfaced when a bench run happened to
sweep the offending regime.  The audit keeps a bounded trail of every
dispatched plan's **predicted cost vector** alongside the **wall time
measured at execution**, keyed per (op, path, stats bucket), so the
``summary()`` exposes exactly the evidence a learned autotuner
(ROADMAP open item 4) trains on, and ``mispredictions()`` lists the
buckets where the model's ranking disagrees with the measurements.

Stats buckets are coarse on purpose (shape rounded to a power of two,
density rounded to a decade): rows aggregate across calls instead of
one row per exact shape.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Deque, Dict, List, Mapping, Optional, Tuple

import collections

from repro.obs.registry import MetricsRegistry


def stats_bucket(stats: Any) -> str:
    """Coarse aggregation key for audit rows ("n4096/d1e-2")."""
    if stats is None:
        return "unknown"
    m, n = stats.shape
    side = max(int(m), int(n), 1)
    n_pow2 = 1 << max(side - 1, 1).bit_length()
    density = float(getattr(stats, "density", 0.0))
    if density <= 0.0:
        dens = "d0"
    else:
        dens = f"d1e{int(math.floor(math.log10(density) + 0.5))}"
    return f"n{n_pow2}/{dens}"


@dataclasses.dataclass(frozen=True)
class AuditRow:
    """One executed plan: what the model predicted, what the clock said."""

    op: str
    path: str
    bucket: str                  # stats_bucket(...) or a serving bucket label
    measured_ms: float
    predicted: Optional[float]   # model cost of the chosen path
    costs: Optional[Tuple[Tuple[str, float], ...]]  # full cost vector
    policy: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {
            "op": self.op,
            "path": self.path,
            "bucket": self.bucket,
            "measured_ms": round(self.measured_ms, 4),
            "predicted": self.predicted,
            "costs": dict(self.costs) if self.costs is not None else None,
            "policy": self.policy,
        }


class CostAudit:
    """Bounded ring of :class:`AuditRow` with per-cell aggregation."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 capacity: int = 2048):
        self.registry = registry
        self._rows: Deque[AuditRow] = collections.deque(maxlen=capacity)
        self._lock = threading.RLock()

    # -- recording -----------------------------------------------------------

    def record(self, plan: Any, measured_ms: float,
               bucket: Optional[str] = None) -> None:
        """Record one executed dispatch ``Plan`` (predicted costs taken
        from ``plan.costs``; ``bucket`` defaults to the plan's stats
        bucket)."""
        costs = getattr(plan, "costs", None)
        self.record_raw(
            op=plan.op, path=plan.path, measured_ms=measured_ms,
            bucket=bucket if bucket is not None
            else stats_bucket(getattr(plan, "stats", None)),
            costs=costs, policy=getattr(plan, "policy", ""))

    def record_raw(self, *, op: str, path: str, measured_ms: float,
                   bucket: str, costs: Optional[Mapping[str, float]] = None,
                   policy: str = "") -> None:
        predicted = None
        frozen = None
        if costs:
            frozen = tuple(sorted((str(k), float(v))
                                  for k, v in costs.items()
                                  if math.isfinite(float(v))))
            predicted = dict(frozen).get(path)
        row = AuditRow(op=op, path=path, bucket=bucket,
                       measured_ms=float(measured_ms), predicted=predicted,
                       costs=frozen, policy=policy)
        with self._lock:
            self._rows.append(row)
        if self.registry is not None:
            self.registry.histogram("audit_measured_ms", op=op, path=path) \
                .observe(measured_ms)

    # -- reading -------------------------------------------------------------

    def rows(self) -> Tuple[AuditRow, ...]:
        with self._lock:
            return tuple(self._rows)

    def summary(self) -> Dict[str, Dict[str, Any]]:
        """Aggregate per "op/path/bucket": call count, measured wall-time
        mean, and mean predicted cost of the chosen path."""
        cells: Dict[Tuple[str, str, str], List[AuditRow]] = {}
        with self._lock:
            for r in self._rows:
                cells.setdefault((r.op, r.path, r.bucket), []).append(r)
        out: Dict[str, Dict[str, Any]] = {}
        for (op, path, bucket), rows in sorted(cells.items()):
            ms = [r.measured_ms for r in rows]
            preds = [r.predicted for r in rows if r.predicted is not None]
            out[f"{op}/{path}/{bucket}"] = {
                "n": len(rows),
                "measured_ms_mean": round(sum(ms) / len(ms), 4),
                "measured_ms_max": round(max(ms), 4),
                "predicted_mean": (round(sum(preds) / len(preds), 4)
                                   if preds else None),
            }
        return out

    def mispredictions(self) -> List[Dict[str, Any]]:
        """Cells where the model's cheapest path is measurably not the
        fastest executed path of the same (op, bucket).

        Only (op, bucket) cells where at least two distinct paths ran
        can be judged — with one path there is nothing to rank against.
        """
        by_cell: Dict[Tuple[str, str], Dict[str, List[AuditRow]]] = {}
        with self._lock:
            for r in self._rows:
                by_cell.setdefault((r.op, r.bucket), {}) \
                    .setdefault(r.path, []).append(r)
        out = []
        for (op, bucket), paths in sorted(by_cell.items()):
            if len(paths) < 2:
                continue
            measured = {p: sum(r.measured_ms for r in rs) / len(rs)
                        for p, rs in paths.items()}
            predicted = {p: sum(r.predicted for r in rs) / len(rs)
                         for p, rs in paths.items()
                         if all(r.predicted is not None for r in rs)}
            pred_ranked = {p: c for p, c in predicted.items()
                           if p in measured}
            if len(pred_ranked) < 2:
                continue
            pred_best = min(pred_ranked, key=pred_ranked.get)
            meas_best = min(measured, key=measured.get)
            if pred_best != meas_best:
                out.append({
                    "op": op, "bucket": bucket,
                    "predicted_best": pred_best,
                    "measured_best": meas_best,
                    "measured_ms": {p: round(v, 4)
                                    for p, v in sorted(measured.items())},
                    "predicted": {p: round(v, 4)
                                  for p, v in sorted(pred_ranked.items())},
                })
        return out

    def report(self) -> Dict[str, Any]:
        return {
            "rows": [r.as_dict() for r in self.rows()],
            "summary": self.summary(),
            "mispredictions": self.mispredictions(),
        }

    def clear(self) -> None:
        with self._lock:
            self._rows.clear()
