"""Retrace sentry: compiles-vs-calls accounting per executor lane.

The serving stack's central compilation invariant — O(#buckets)
compiles, zero retraces at steady state — was previously enforced only
by hand-pinned trace-count tests (PR 3's ``compiles == buckets`` pins,
PR 7's 1000-delta zero-retrace pin).  The sentry turns the invariant
into an always-on runtime check: every jitted executor lane (a
``(bucket, batch, d, form)`` cell, or any label a caller picks) records
its compiles and calls, and **any compile after the lane's warmup
budget is flagged as an ``unexpected_retrace`` event** — visible in
``obs.snapshot()`` the moment a shape/static-aux leak sneaks back in,
instead of waiting for a bench run or a test that happens to pin it.

Eviction is the one legitimate reason a lane recompiles: the owner of
the compile cache calls :meth:`RetraceSentry.forget` when it drops an
executor, resetting that lane's warmup budget.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Deque, Dict, Optional, Tuple

import collections

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class RetraceEvent:
    """One compile observed past a lane's warmup budget."""

    lane: str
    compiles: int      # lane compile count including this one
    calls: int         # lane calls when the retrace happened
    note: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {"lane": self.lane, "compiles": self.compiles,
                "calls": self.calls, "note": self.note}


class _LaneState:
    __slots__ = ("compiles", "calls", "budget")

    def __init__(self, budget: int):
        self.compiles = 0
        self.calls = 0
        self.budget = budget


class RetraceSentry:
    """Per-lane compile/call counters with an unexpected-retrace alarm.

    ``warmup`` is the per-lane compile budget (default 1: the first
    trace of a lane is expected, everything after is an event).
    Thread-safe — compiles are recorded from inside jit tracing on
    whatever thread called the executor.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 warmup: int = 1, capacity: int = 256):
        self.registry = registry
        self.warmup = int(warmup)
        self._lanes: Dict[str, _LaneState] = {}
        self._events: Deque[RetraceEvent] = collections.deque(
            maxlen=capacity)
        self._lock = threading.RLock()

    def _lane(self, lane: str) -> _LaneState:
        st = self._lanes.get(lane)
        if st is None:
            st = self._lanes[lane] = _LaneState(self.warmup)
        return st

    # -- recording -----------------------------------------------------------

    def record_compile(self, lane: str, note: str = "") -> bool:
        """Count one trace of ``lane``; returns True when it was
        unexpected (past the lane's warmup budget)."""
        with self._lock:
            st = self._lane(lane)
            st.compiles += 1
            unexpected = st.compiles > st.budget
            if unexpected:
                self._events.append(RetraceEvent(
                    lane=lane, compiles=st.compiles, calls=st.calls,
                    note=note))
            if self.registry is not None:
                self.registry.counter("executor_compiles_total",
                                      lane=lane).inc()
                if unexpected:
                    self.registry.counter("unexpected_retrace_total",
                                          lane=lane).inc()
            return unexpected

    def record_call(self, lane: str) -> None:
        with self._lock:
            self._lane(lane).calls += 1
            if self.registry is not None:
                self.registry.counter("executor_calls_total",
                                      lane=lane).inc()

    def forget(self, lane: str) -> None:
        """The lane's executor was evicted: its next compile is a warm-up
        again, not a retrace (the budget grows by one warmup)."""
        with self._lock:
            st = self._lanes.get(lane)
            if st is not None:
                st.budget = st.compiles + self.warmup

    # -- reading -------------------------------------------------------------

    def lanes(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {lane: {"compiles": st.compiles, "calls": st.calls,
                           "budget": st.budget}
                    for lane, st in sorted(self._lanes.items())}

    def events(self) -> Tuple[RetraceEvent, ...]:
        with self._lock:
            return tuple(self._events)

    def unexpected(self, lane: Optional[str] = None) -> int:
        """Number of unexpected-retrace events (optionally one lane's)."""
        with self._lock:
            return sum(1 for e in self._events
                       if lane is None or e.lane == lane)

    def report(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "lanes": self.lanes(),
                "compiles": sum(s.compiles for s in self._lanes.values()),
                "calls": sum(s.calls for s in self._lanes.values()),
                "unexpected_retraces": len(self._events),
                "events": [e.as_dict() for e in self._events],
            }

    def clear(self) -> None:
        with self._lock:
            self._lanes.clear()
            self._events.clear()


def instrumented_jit(fn: Callable, lane: str, *,
                     sentry: Optional[RetraceSentry] = None,
                     **jit_kwargs) -> Callable:
    """``jax.jit(fn)`` with the sentry watching its trace/call counts.

    A drop-in wrapper for consumers outside the bucketed-executor stack
    (e.g. a ``DeltaGraph`` SpMM consumer): every call records a lane
    call, every trace of the wrapped body records a lane compile — so a
    static-aux leak that starts retracing the consumer shows up as
    ``unexpected_retrace`` events without a hand-pinned test.
    """
    import jax

    from repro import obs as _obs

    s = sentry if sentry is not None else _obs.SENTRY

    def traced(*args, **kwargs):
        s.record_compile(lane)
        return fn(*args, **kwargs)

    exe = jax.jit(traced, **jit_kwargs)

    def call(*args, **kwargs):
        s.record_call(lane)
        return exe(*args, **kwargs)

    call.__wrapped__ = exe
    return call
