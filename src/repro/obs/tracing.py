"""Span-based tracing for the serve and train paths.

``span("serve.compose", bucket=...)`` opens a timed span; nesting
propagates parentage through a thread-local stack, so one admitted
request's trace reads ``serve.flush`` → ``serve.compose`` →
``serve.execute`` → ``serve.complete`` with parent/child links intact.
Completed spans land in a bounded ring on the :class:`Tracer` and their
durations feed the ``span_ms{name=...}`` histogram of the attached
:class:`~repro.obs.registry.MetricsRegistry`, so the latency breakdown
is visible both as individual traces and as aggregate percentiles.

The canonical serve-path span taxonomy (see DESIGN.md "Observability"):

  serve.admit     — request admission (queue / lane seating)
  serve.bucket    — bucket / ladder decision for one request group
  serve.flush     — one micro-batch flush (batch engine)
  serve.lane_step — one continuous-engine lane execution
  serve.compose   — block-diagonal composition + feature concat
  serve.execute   — the jitted executor call (compile time included on
                    the first call of a lane — the sentry separates it)
  serve.complete  — unbatch, trim, future resolution
  train.step      — one optimizer step of ``train_loop``
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import json
import threading
import time
from typing import Any, Deque, Dict, Iterator, Mapping, Optional, Tuple

import collections

from repro.obs.registry import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span (immutable; rings and exporters share it)."""

    name: str
    tags: Tuple[Tuple[str, str], ...]
    trace_id: int                 # id of the root span of this tree
    span_id: int
    parent_id: Optional[int]      # None for a root span
    t_wall: float                 # wall-clock start (time.time)
    dur_ms: float

    def as_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "tags": dict(self.tags),
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_wall": self.t_wall,
            "dur_ms": round(self.dur_ms, 4),
        }


class _ActiveSpan:
    __slots__ = ("name", "tags", "trace_id", "span_id", "parent_id",
                 "t_wall", "t0")

    def __init__(self, name, tags, trace_id, span_id, parent_id):
        self.name = name
        self.tags = tags
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_wall = time.time()
        self.t0 = time.perf_counter()


class Tracer:
    """Bounded ring of completed spans + thread-local parent stacks."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 capacity: int = 4096):
        self.registry = registry
        self._ring: Deque[SpanRecord] = collections.deque(maxlen=capacity)
        self._lock = threading.RLock()
        self._ids = itertools.count(1)
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **tags) -> Iterator[_ActiveSpan]:
        """Open a timed span; nested calls chain parent ids per thread."""
        stack = self._stack()
        parent = stack[-1] if stack else None
        span_id = next(self._ids)
        sp = _ActiveSpan(
            name=name,
            tags=tuple(sorted((str(k), str(v)) for k, v in tags.items())),
            trace_id=parent.trace_id if parent else span_id,
            span_id=span_id,
            parent_id=parent.span_id if parent else None)
        stack.append(sp)
        try:
            yield sp
        finally:
            stack.pop()
            dur_ms = (time.perf_counter() - sp.t0) * 1e3
            rec = SpanRecord(name=sp.name, tags=sp.tags,
                             trace_id=sp.trace_id, span_id=sp.span_id,
                             parent_id=sp.parent_id, t_wall=sp.t_wall,
                             dur_ms=dur_ms)
            with self._lock:
                self._ring.append(rec)
            if self.registry is not None:
                # label key is "span", not "name": the registry's
                # positional ``name`` parameter reserves that spelling
                self.registry.histogram("span_ms", span=name) \
                    .observe(dur_ms)

    def current(self) -> Optional[_ActiveSpan]:
        stack = self._stack()
        return stack[-1] if stack else None

    # -- reading -------------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> Tuple[SpanRecord, ...]:
        with self._lock:
            return tuple(s for s in self._ring
                         if name is None or s.name == name)

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name count and duration stats over the ring."""
        agg: Dict[str, list] = {}
        with self._lock:
            for s in self._ring:
                agg.setdefault(s.name, []).append(s.dur_ms)
        out = {}
        for name in sorted(agg):
            ds = sorted(agg[name])
            n = len(ds)
            out[name] = {
                "count": n,
                "total_ms": round(sum(ds), 4),
                "p50_ms": round(ds[n // 2], 4),
                "max_ms": round(ds[-1], 4),
            }
        return out

    def to_jsonl(self) -> str:
        with self._lock:
            recs = list(self._ring)
        return "\n".join(json.dumps(r.as_dict(), sort_keys=True)
                         for r in recs) + ("\n" if recs else "")

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
