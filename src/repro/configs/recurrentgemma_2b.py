"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — Griffin: RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]

Hybrid: local-attention layers use the paper's banded block-sparse path;
RG-LRU layers are linear recurrences (associative scan).  long_500k RUNS.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window=2048,
    lru_width=2560,
    act="gelu",
    tie_embeddings=True,
    long_context_ok=True,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,  # one period + (rglru, rglru) remainder
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    layer_pattern=("rglru", "rglru", "local"),
    window=64,
    attn_block=32,
    lru_width=64,
    act="gelu",
    tie_embeddings=True,
    long_context_ok=True,
)
