"""whisper-small [audio] — 12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865 — encoder-decoder; conv frontend STUB per assignment
(input_specs() provides precomputed frame embeddings [B, 1500, 768]).
[arXiv:2212.04356; unverified]

Shape interpretation (DESIGN.md §Arch-applicability): the assigned seq_len
applies to the decoder token stream; the encoder consumes whisper's native
1500 frame embeddings.  long_500k skipped (full attention; 500k is out of
the enc-dec family's operating range).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    # whisper's native 1500 frames padded to 1536 (divisible by the 512
    # attention chunk) so the encoder takes the memory-bounded flash path
    encoder_seq=1536,
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    encoder_layers=2,
    encoder_seq=32,
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)
