"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 (+1 shared).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

128 experts stress EP: the dispatch matrix is 8x sparser than Scout's —
the paper's hyper-sparsity cliff regime (EXPERIMENTS.md §Roofline).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    # Maverick interleaves dense and MoE layers (1:1) — that is how 128
    # experts yield ~400B total yet 17B active.
    layer_pattern=("attn", "moe"),
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    act="silu",
    rope_theta=500000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    layer_pattern=("attn", "moe"),
    n_experts=8,
    top_k=1,
    n_shared_experts=1,
    act="silu",
)
