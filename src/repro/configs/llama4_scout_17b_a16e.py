"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (+1 shared), early fusion (text backbone
only; multimodal frontend out of scope per assignment).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Technique note (DESIGN.md §4): top-1 routing *is* the paper's hyper-sparse
SpMM (one nonzero per row of the dispatch matrix); implemented as
sort-based capacity dispatch, the comm-optimal form of that SpMM.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    layer_pattern=("moe",),
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    act="silu",
    rope_theta=500000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    layer_pattern=("moe",),
    n_experts=4,
    top_k=1,
    n_shared_experts=1,
    act="silu",
)
