"""--arch <id> registry. One module per assigned architecture."""
from __future__ import annotations

import importlib

ARCHS = (
    "llama4-scout-17b-a16e",
    "llama4-maverick-400b-a17b",
    "nemotron-4-15b",
    "granite-20b",
    "qwen1.5-110b",
    "gemma3-4b",
    "mamba2-2.7b",
    "recurrentgemma-2b",
    "internvl2-26b",
    "whisper-small",
    "paper-gnn",  # the paper's own application (GCN/GAT)
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCHS}


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str):
    return _load(arch).CONFIG


def get_smoke_config(arch: str):
    return _load(arch).SMOKE_CONFIG
