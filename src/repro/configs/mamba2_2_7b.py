"""mamba2-2.7b [ssm] — 64L d_model=2560 attn-free vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

Attention-free: attention-sparsity technique inapplicable (DESIGN.md
§Arch-applicability); SSD chunked scan implemented natively.  O(1)/token
decode state => long_500k RUNS.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    long_context_ok=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=512,
    layer_pattern=("ssm",),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=32,
    conv_width=4,
    tie_embeddings=True,
    long_context_ok=True,
)
