from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.configs.registry import ARCHS, get_config, get_smoke_config

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "get_smoke_config"]
