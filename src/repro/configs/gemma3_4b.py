"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global layer pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

The local layers are the paper's technique in production: banded
block-sparse attention (core.attention.local_block_attention).  Sub-
quadratic in depth-averaged cost => long_500k cell RUNS.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    act="gelu",
    tie_embeddings=True,
    rope_theta=1000000.0,
    long_context_ok=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=7,  # one full period + remainder (local) — exercises both paths
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window=64,
    attn_block=32,
    act="gelu",
    tie_embeddings=True,
    long_context_ok=True,
)
