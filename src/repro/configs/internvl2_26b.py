"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend (STUB per assignment: input_specs()
provides precomputed patch embeddings) + InternLM2 backbone.
[arXiv:2404.16821; hf]

long_500k skipped (full-attention backbone).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    vision_tokens=256,
    act="silu",
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    vision_tokens=8,
    act="silu",
)
