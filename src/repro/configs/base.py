"""Config system.

``ModelConfig`` is the single config type covering every assigned
architecture family (dense / MoE / SSM / hybrid / enc-dec / VLM).  Layer
heterogeneity (gemma3's 5 local : 1 global, recurrentgemma's 2 recurrent :
1 local) is expressed as a ``layer_pattern`` — a period of layer *kinds*
that repeats down the stack; models scan over whole periods so compiled HLO
size is O(period), not O(n_layers).

Every architecture file in this package defines ``CONFIG`` (the exact card
from the assignment) and ``SMOKE_CONFIG`` (same family, tiny dims) and is
selectable via ``--arch <id>`` (see repro.configs.registry).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # layer pattern (kinds: "attn" full, "local" windowed, "ssm", "rglru")
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # sliding-window size for "local" layers

    # MoE
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_bf16_intra: bool = False  # bf16 intra-chunk quadratic (§Perf P8)
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: Optional[int] = None

    # enc-dec (whisper): decoder cross-attends to encoder states
    encoder_layers: int = 0
    encoder_seq: int = 0  # frame-embedding count from the (stubbed) frontend

    # VLM: number of precomputed patch-embedding prefix tokens (stub)
    vision_tokens: int = 0

    # misc
    qkv_bias: bool = False
    act: str = "silu"  # silu | relu2 | gelu
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # paper technique knobs
    attn_block: int = 512  # block size for block-sparse / flash chunking
    long_context_ok: bool = False  # sub-quadratic => long_500k cell runs

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        assert self.n_layers >= len(self.layer_pattern)

    # -- derived -------------------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def remainder_kinds(self) -> Tuple[str, ...]:
        r = self.n_layers % self.period
        return self.layer_pattern[:r]

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Total parameter count (analytic; used for MODEL_FLOPS=6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        mlp = d * f * (3 if self.gated_mlp else 2)
        moe = self.n_experts * mlp + d * self.n_experts \
            + self.n_shared_experts * mlp
        ssm = 0
        if "ssm" in self.layer_pattern:
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = di + 2 * ds
            ssm = (d * (2 * di + 2 * ds + nh)  # in_proj
                   + conv_dim * (self.conv_width + 1)  # conv w + bias
                   + 3 * nh  # A_log, D, dt_bias
                   + di * d  # out_proj
                   + di)  # gate norm
        rglru = 0
        if "rglru" in self.layer_pattern:
            w = self.lru_width or d
            rglru = (2 * d * w + w * d  # in (x & gate) + out proj
                     + w * (self.conv_width + 1)  # conv w + bias
                     + 2 * w * w + 2 * w  # gates (w + b)
                     + w)  # Lambda
        per_kind = {
            "attn": attn + mlp + 2 * d,
            "local": attn + mlp + 2 * d,
            "moe": attn + moe + 2 * d,
            "ssm": ssm + d,
            "rglru": rglru + mlp + 2 * d,
        }
        layers = 0
        for i in range(self.n_layers):
            layers += per_kind[self.layer_pattern[i % self.period]]
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = layers + emb + d  # final norm
        if self.vision_tokens:
            total += d * d  # vision projector
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * d) + d
            total += self.n_layers * (attn + d)  # cross-attention per dec layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = d * f * (3 if self.gated_mlp else 2)
        inactive = (self.n_experts - self.top_k) * mlp
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.layer_pattern[i % self.period] == "moe")
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
