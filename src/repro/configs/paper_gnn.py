"""paper-gnn — the paper's own application: a 3-layer GCN/GAT with hidden
size 128 and feature dim d=256 (paper §4.1: D=256, Fig 2: hidden 128),
running on synthetic random graphs via the SpMM/SDDMM substrate.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "paper-gnn"
    kind: str = "gcn"  # gcn | gat
    n_layers: int = 3
    in_features: int = 256  # paper's D
    hidden: int = 128  # paper Fig. 2 hidden channel size
    n_classes: int = 16
    # sparse-format knobs (the paper's myc / mcpp analogs)
    block_m: int = 64
    block_n: int = 64


CONFIG = GNNConfig()
SMOKE_CONFIG = GNNConfig(name="paper-gnn-smoke", in_features=32, hidden=16,
                         n_classes=4, block_m=16, block_n=16)
