from repro.kernels.bsattn.ops import block_sparse_flash_attention
from repro.kernels.bsattn.ref import block_sparse_attention_ref

__all__ = ["block_sparse_flash_attention", "block_sparse_attention_ref"]
