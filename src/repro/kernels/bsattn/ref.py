"""Pure-jnp oracle for block-sparse flash attention.

Computes dense masked attention where the mask is the union of the
Block-ELL kv-block lists intersected with the causal/window predicate —
exactly what the fused kernel computes blockwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def dense_mask_from_ell(ell_idx: np.ndarray, valid: np.ndarray, s: int,
                        block_q: int, block_kv: int,
                        causal: bool = True,
                        window: int | None = None) -> np.ndarray:
    """bool[s, s] mask implied by (ell_idx, valid) + causal/window."""
    nq, w = ell_idx.shape
    mask = np.zeros((s, s), bool)
    for qi in range(nq):
        for sl in range(w):
            if not valid[qi, sl]:
                continue
            ki = int(ell_idx[qi, sl])
            mask[qi * block_q:(qi + 1) * block_q,
                 ki * block_kv:(ki + 1) * block_kv] = True
    qpos = np.arange(s)[:, None]
    kpos = np.arange(s)[None, :]
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def block_sparse_attention_ref(q, k, v, mask, *, scale=None):
    """q: [BH, S, D]; k/v: [BHkv, S, D]; mask: bool[S, S]."""
    bh, s, d = q.shape
    bkv = k.shape[0]
    g = bh // bkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(bkv, g, s, d).astype(jnp.float32)
    logits = jnp.einsum("hgqd,hkd->hgqk", qg,
                        k.astype(jnp.float32)) * scale
    logits = jnp.where(jnp.asarray(mask)[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows produce uniform p over NEG_INF logits; zero them
    any_valid = jnp.asarray(mask).any(axis=1)[None, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("hgqk,hkd->hgqd", p, v.astype(jnp.float32))
    return out.reshape(bh, s, d).astype(q.dtype)
