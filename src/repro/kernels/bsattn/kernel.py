"""Fused block-sparse FlashAttention Pallas TPU kernel.

This is the paper's §5 future-work item (3) realized: SDDMM (scores only
at nonzero mask blocks), softmax, and SpMM (scores x V) fused into a
single VMEM pass, so the sampled score matrix never round-trips HBM.

Block sparsity is carried exactly like the SpMM kernel's SELLPACK-like
format: each q block-row has a fixed-width (ELL) list of kv block ids,
padded with invalid slots — uniform streams, static grid.  Within a
block, the causal/window predicate is evaluated from absolute positions,
so diagonal (partially masked) blocks need no special casing.

Grid: (BH, n_q_blocks, W)   [W innermost => online-softmax accumulation]
  q:   [BH, S, D]    -> tile (1, bq, D)  at (bh, qi, 0)
  k/v: [BHkv, S, D]  -> tile (1, bk, D)  at (bh // group, idx[qi, w], 0)
  out: [BH, S, D]    -> tile (1, bq, D)  at (bh, qi, 0), revisited over W
Scratch: acc [bq, D] f32, m/l [bq] f32 (flash statistics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _bsattn_kernel(idx_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   n_slots: int, block_q: int, block_kv: int, scale: float,
                   causal: bool, window: int):
    qi = pl.program_id(1)
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ki = idx_ref[qi, w]
    is_valid = valid_ref[qi, w] > 0

    q_blk = q_ref[0, :, :]
    k_blk = k_ref[0, :, :]
    s = jax.lax.dot_general(
        q_blk, k_blk,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # [bq, bk]

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.full((block_q, block_kv), is_valid)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, :, :],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(w == n_slots - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_kv", "causal", "window", "scale",
                     "interpret"),
)
def bsattn_kernel(
    ell_idx,  # int32[nq, W] kv block ids
    valid,  # int32[nq, W] 1 = real slot, 0 = padding
    q,  # [BH, S, D]
    k,  # [BHkv, S, D]
    v,  # [BHkv, S, D]
    *,
    block_q: int = 512,
    block_kv: int = 512,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    interpret: bool = False,
):
    bh, s, d = q.shape
    bkv = k.shape[0]
    group = bh // bkv
    nq, n_slots = ell_idx.shape
    assert s % block_q == 0 and s % block_kv == 0
    assert nq == s // block_q
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))

    grid = (bh, nq, n_slots)
    kernel = functools.partial(
        _bsattn_kernel, n_slots=n_slots, block_q=block_q,
        block_kv=block_kv, scale=scale, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, d),
                             lambda bh_, qi, w, idx, val: (bh_, qi, 0)),
                pl.BlockSpec(
                    (1, block_kv, d),
                    lambda bh_, qi, w, idx, val, g=group:
                    (bh_ // g, idx[qi, w], 0)),
                pl.BlockSpec(
                    (1, block_kv, d),
                    lambda bh_, qi, w, idx, val, g=group:
                    (bh_ // g, idx[qi, w], 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, block_q, d), lambda bh_, qi, w, idx, val: (bh_, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="block_sparse_flash_attention",
    )(ell_idx, valid, q, k, v)
