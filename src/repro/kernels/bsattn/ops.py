"""Public wrapper: banded (sliding-window) and custom block-sparse masks."""
from __future__ import annotations

import numpy as np

from repro.kernels.bsattn.kernel import bsattn_kernel


def banded_ell(s: int, block_q: int, block_kv: int, window: int):
    """ELL kv-block lists for causal sliding-window attention.

    Constant width (the paper's equal-length streams): block-row i lists
    kv blocks [i - w_blocks + 1 .. i], clipped, with validity flags.
    """
    nq = s // block_q
    w_blocks = window // block_kv + 1 if window > 0 else s // block_kv
    rows = np.arange(nq)[:, None] * (block_q // block_kv)
    ell = rows - np.arange(w_blocks - 1, -1, -1)[None, :]
    valid = ell >= 0
    return (np.where(valid, ell, 0).astype(np.int32),
            valid.astype(np.int32))


def block_sparse_flash_attention(q, k, v, *, window: int = 0,
                                 causal: bool = True, block_q: int = 512,
                                 block_kv: int = 512, ell_idx=None,
                                 valid=None, interpret: bool = False):
    """Fused SDDMM->softmax->SpMM attention over a block-sparse mask.

    q: [BH, S, D]; k/v: [BHkv, S, D] (GQA: BH % BHkv == 0; the kernel
    gathers the right kv head via index arithmetic, never materializing
    repeated KV).  Default mask: causal sliding window of ``window``
    (banded Block-ELL, constant width).  Custom patterns: pass
    ``ell_idx``/``valid`` [n_q_blocks, W].
    """
    s = q.shape[1]
    if ell_idx is None:
        import jax.numpy as jnp
        ell_np, val_np = banded_ell(s, block_q, block_kv, window)
        ell_idx, valid = jnp.asarray(ell_np), jnp.asarray(val_np)
    return bsattn_kernel(ell_idx, valid, q, k, v, block_q=block_q,
                         block_kv=block_kv, causal=causal, window=window,
                         interpret=interpret)
