"""Pallas-TPU API compatibility shims.

The compiler-params container was renamed across pallas releases
(``TPUCompilerParams`` in the 0.4.x line, ``CompilerParams`` later); all
kernels build theirs through ``tpu_compiler_params`` so the name guard
lives in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None
) or getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kw):
    """Construct pallas-TPU compiler params under either API name."""
    return _COMPILER_PARAMS_CLS(**kw)
