"""Epilogue vocabulary for fused SpMM.

An :class:`Epilogue` is a small *hashable* spec of the elementwise tail
applied to the SpMM accumulator before the single output flush:

    out = act(A @ H + bias + residual)

It is static plan metadata (part of the dispatch plan key and the
``custom_vjp`` nondiff config), so the same spec is usable inside a
Pallas kernel body (trace-time Python) and in the jnp reference paths.
The bias/residual *arrays* are separate differentiable operands — the
spec only records which of them participate (``has_bias`` /
``has_residual``) and the activation.

Activation gradients are evaluated from the *output* sign
(``act_grad_from_out``): for relu / leaky_relu the pre-activation sign
is recoverable from the post-activation sign, so the backward pass needs
no extra residual beyond the forward output.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

ACTS = ("identity", "relu", "leaky_relu")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Hashable spec of the fused SpMM tail: act(y + bias + residual)."""

    act: str = "identity"
    negative_slope: float = 0.01   # leaky_relu only
    has_bias: bool = False
    has_residual: bool = False

    def __post_init__(self):
        if self.act not in ACTS:
            raise ValueError(
                f"unknown epilogue activation {self.act!r}; expected one "
                f"of {ACTS}")

    def describe(self) -> str:
        parts = [self.act] if self.act != "identity" else []
        if self.has_bias:
            parts.append("bias")
        if self.has_residual:
            parts.append("residual")
        return "+".join(parts) or "identity"


def normalize_epilogue(epilogue, bias, residual) -> Optional[Epilogue]:
    """Canonicalize the public (epilogue, bias, residual) kwargs.

    ``epilogue`` may be an activation name, an :class:`Epilogue`, or
    None; supplying ``bias``/``residual`` alone implies an identity-act
    epilogue.  Returns None when there is nothing to fuse.
    """
    if epilogue is None and bias is None and residual is None:
        return None
    if epilogue is None:
        epi = Epilogue()
    elif isinstance(epilogue, Epilogue):
        epi = epilogue
    else:
        epi = Epilogue(act=str(epilogue),
                       negative_slope=0.2 if epilogue == "leaky_relu"
                       else 0.01)
    has_bias = bias is not None
    has_residual = residual is not None
    if epi.has_bias != has_bias or epi.has_residual != has_residual:
        epi = dataclasses.replace(epi, has_bias=has_bias,
                                  has_residual=has_residual)
    return epi


def apply_act(z, act: str, negative_slope: float):
    """The epilogue activation on an accumulator tile (f32 in, f32 out)."""
    if act == "identity":
        return z
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "leaky_relu":
        return jnp.where(z >= 0, z, negative_slope * z)
    raise ValueError(f"unknown epilogue activation {act!r}")


def act_grad_from_out(out, act: str, negative_slope: float):
    """d act/dz evaluated from the *post*-activation value.

    Valid because relu/leaky_relu (slope > 0) preserve the sign of z:
    out > 0 <=> z > 0 and out >= 0 <=> z >= 0.
    """
    if act == "identity":
        return jnp.ones_like(out)
    if act == "relu":
        return jnp.where(out > 0, 1.0, 0.0).astype(out.dtype)
    if act == "leaky_relu":
        return jnp.where(out >= 0, 1.0, negative_slope).astype(out.dtype)
    raise ValueError(f"unknown epilogue activation {act!r}")


def apply_epilogue(y, epi: Optional[Epilogue], bias=None, residual=None):
    """Reference application of the epilogue to a [M, D] product.

    This is what the non-kernel execution paths run after their SpMM —
    XLA fuses the elementwise tail into the surrounding computation, so
    the *semantics* match the in-register kernel epilogue exactly.
    """
    if epi is None:
        return y
    z = y.astype(jnp.float32)
    if epi.has_bias:
        z = z + bias.astype(jnp.float32)
    if epi.has_residual:
        z = z + residual.astype(jnp.float32)
    return apply_act(z, epi.act, epi.negative_slope).astype(y.dtype)
