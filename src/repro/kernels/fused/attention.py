"""One-pass fused graph attention: SDDMM → edge act → softmax → SpMM.

The unfused GAT layer runs three dispatches and materializes the
E-length edge-score vector twice (scores, then attention weights).  The
kernels here stream each live tile of the topology exactly once and keep
the softmax statistics (running row max ``m`` and exp-sum ``l``) plus
the output accumulator resident in VMEM — the edge scores never exist in
HBM at all:

  sweep over a row's live tiles:
      s   = act(q_tile @ kT_tile)          # SDDMM piece, in-register
      m'  = max(m, rowmax(s));  scale = exp(m - m')
      l   = l * scale + rowsum(exp(s - m'))
      acc = acc * scale + exp(s - m') @ V_tile
  flush: out = acc / max(l, eps)

This is the max/sum two-sweep online softmax in streaming form: the
first "sweep" (the running max) and the second (exp-sum + weighted
accumulation) advance together, with the ``scale`` factor retroactively
correcting earlier tiles — algebraically identical to two passes over
the row, matching ``models.gnn._segment_softmax`` to float tolerance.

Layouts:
  * Block-ELL — grid (nbr, W), W innermost; the structural mask comes
    from A's blocks (padding slots are all-zero and mask out).
  * SELL-C-σ — grid (T,) over live tiles, flush on row change; q is
    pre-gathered into packed row order, the epilogue gather un-permutes
    and re-inserts pruned (edge-less => zero) rows.
  * csr / dense — jnp reference compositions (element paths are
    E-granular by construction; they are the oracle, not the fused
    target).

Every layout's jnp reference here IS the two-sweep (explicit max pass,
then exp/sum/accumulate pass) so kernel-vs-reference parity also pins
the online-rescaling algebra.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import BlockELL, SellCS
from repro.kernels._compat import tpu_compiler_params
from repro.kernels.fused.epilogue import apply_act

NEG_INF = -1e30   # finite: masked - masked stays nan-free
EPS = 1e-12       # the _segment_softmax denominator guard


# ---------------------------------------------------------------------------
# Block-ELL fused attention
# ---------------------------------------------------------------------------


def _ell_attn_kernel(idx_ref, a_ref, q_ref, kt_ref, v_ref, o_ref,
                     acc_ref, m_ref, l_ref, *, n_slots: int, act: str,
                     slope: float):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    mask = a_ref[0, 0, :, :] != 0
    s = jax.lax.dot_general(
        q_ref[...], kt_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [bm, bn]
    s = jnp.where(mask, apply_act(s, act, slope), NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * scale + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * scale[:, None] + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(w == n_slots - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], EPS)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("act", "slope", "out_dtype", "interpret")
)
def fused_attn_blockell_kernel(
    indices,  # int32[nbr, W]
    blocks,  # dtype[nbr, W, bm, bn]  structural mask source
    q,  # dtype[nbr*bm, dk]
    kt,  # dtype[dk, Np]
    v,  # dtype[Np, D]
    *,
    act: str = "leaky_relu",
    slope: float = 0.2,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    nbr, w, bm, bn = blocks.shape
    mp, dk = q.shape
    n, d = v.shape
    assert mp == nbr * bm, (mp, nbr, bm)
    assert n % bn == 0, (n, bn)

    grid = (nbr, w)
    kernel = functools.partial(_ell_attn_kernel, n_slots=w, act=act,
                               slope=slope)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn),
                             lambda i, s, idx: (i, s, 0, 0)),
                pl.BlockSpec((bm, dk), lambda i, s, idx: (i, 0)),
                pl.BlockSpec((dk, bn), lambda i, s, idx: (0, idx[i, s])),
                pl.BlockSpec((bn, d), lambda i, s, idx: (idx[i, s], 0)),
            ],
            out_specs=pl.BlockSpec((bm, d), lambda i, s, idx: (i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bm, d), jnp.float32),
                pltpu.VMEM((bm,), jnp.float32),
                pltpu.VMEM((bm,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((mp, d), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="fused_graph_attention_blockell",
    )(indices, blocks, q, kt, v)


def fused_attn_blockell_ref(ell: BlockELL, q, kt, v, *,
                            act: str = "leaky_relu", slope: float = 0.2,
                            out_dtype=jnp.float32):
    """Blocked two-sweep jnp oracle (sweep 1: row max; sweep 2: exp/sum
    + accumulate).  Works tile-granularly — the only intermediates are
    blocked [nbr, W, bm, bn] score tiles, never an E-length vector."""
    nbr, w = ell.indices.shape
    bm, bn = ell.bm, ell.bn
    mp, np_ = ell.shape
    dk = q.shape[1]
    d = v.shape[1]
    qb = q.reshape(nbr, bm, dk).astype(jnp.float32)
    ktb = kt.reshape(dk, np_ // bn, bn).transpose(1, 0, 2)[ell.indices]
    vb = v.reshape(np_ // bn, bn, d)[ell.indices]  # [nbr, W, bn, d]
    s = jnp.einsum("imk,iwkn->iwmn", qb, ktb.astype(jnp.float32))
    mask = ell.blocks != 0
    s = jnp.where(mask, apply_act(s, act, slope), NEG_INF)
    mx = s.max(axis=(1, 3))                      # sweep 1: [nbr, bm]
    p = jnp.where(mask, jnp.exp(s - mx[:, None, :, None]), 0.0)
    den = p.sum(axis=(1, 3))                     # sweep 2 statistics
    y = jnp.einsum("iwmn,iwnd->imd", p, vb.astype(jnp.float32))
    y = y / jnp.maximum(den, EPS)[:, :, None]
    return y.reshape(mp, d).astype(out_dtype)


def fused_attn_blockell(ell: BlockELL, q, kt, v, *,
                        act: str = "leaky_relu", slope: float = 0.2,
                        out_dtype=None, use_kernel: bool = False,
                        interpret: bool = False):
    """Fused attention over a Block-ELL topology (padded output rows).

    ``q``: [M, dk] row scores, ``kt``: [dk, N], ``v``: [N, D] — logical
    shapes; padding to the block grid happens here, the caller trims the
    output to the logical row count.
    """
    out_dtype = out_dtype or jnp.result_type(q.dtype, v.dtype)
    mp, np_ = ell.shape
    dk = q.shape[1]
    d = v.shape[1]
    if q.shape[0] != mp:
        q = jnp.zeros((mp, dk), q.dtype).at[: q.shape[0]].set(q)
    if kt.shape[1] != np_:
        kt = jnp.zeros((dk, np_), kt.dtype).at[:, : kt.shape[1]].set(kt)
    if v.shape[0] != np_:
        v = jnp.zeros((np_, d), v.dtype).at[: v.shape[0]].set(v)
    if use_kernel or interpret:
        return fused_attn_blockell_kernel(
            ell.indices, ell.blocks, q, kt, v, act=act, slope=slope,
            out_dtype=out_dtype, interpret=interpret)
    return fused_attn_blockell_ref(ell, q, kt, v, act=act, slope=slope,
                                   out_dtype=out_dtype)


def fused_attn_blockcoo_ref(coo, q, kt, v, *, act: str = "leaky_relu",
                            slope: float = 0.2, out_dtype=jnp.float32):
    """Blocked two-sweep over Block-COO (the transposed-ELL layout).

    Same algebra as the ELL reference, with segment reductions over the
    block-row coordinate instead of a dense slot axis.  Inputs are
    already padded to the block grid.
    """
    nnzb, bm, bn = coo.blocks.shape
    mp, np_ = coo.shape
    nbr = mp // bm
    dk = q.shape[1]
    d = v.shape[1]
    qb = q.reshape(nbr, bm, dk).astype(jnp.float32)[coo.rows]
    ktb = kt.reshape(dk, np_ // bn, bn).transpose(1, 0, 2)[coo.cols]
    vb = v.reshape(np_ // bn, bn, d)[coo.cols]
    s = jnp.einsum("emk,ekn->emn", qb, ktb.astype(jnp.float32))
    mask = coo.blocks != 0
    s = jnp.where(mask, apply_act(s, act, slope), NEG_INF)
    mx = jax.ops.segment_max(s.max(axis=2), coo.rows,
                             num_segments=nbr)       # sweep 1
    p = jnp.where(mask, jnp.exp(s - mx[coo.rows][:, :, None]), 0.0)
    den = jax.ops.segment_sum(p.sum(axis=2), coo.rows, num_segments=nbr)
    y = jax.ops.segment_sum(
        jnp.einsum("emn,end->emd", p, vb.astype(jnp.float32)),
        coo.rows, num_segments=nbr)                  # sweep 2
    y = y / jnp.maximum(den, EPS)[:, :, None]
    return y.reshape(mp, d).astype(out_dtype)


# ---------------------------------------------------------------------------
# SELL-C-σ fused attention
# ---------------------------------------------------------------------------


def _sell_attn_kernel(rows_ref, cols_ref, mask_ref, q_ref, kt_ref, v_ref,
                      o_ref, acc_ref, m_ref, l_ref, *, n_tiles: int,
                      act: str, slope: float):
    t = pl.program_id(0)
    row = rows_ref[t]
    prev = rows_ref[jnp.maximum(t - 1, 0)]
    nxt = rows_ref[jnp.minimum(t + 1, n_tiles - 1)]

    @pl.when((t == 0) | (row != prev))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    mask = mask_ref[0, :, :] != 0
    s = jax.lax.dot_general(
        q_ref[...], kt_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = jnp.where(mask, apply_act(s, act, slope), NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    scale = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * scale + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * scale[:, None] + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when((t == n_tiles - 1) | (row != nxt))
    def _flush():
        l = jnp.maximum(l_ref[...], EPS)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_live_block_rows", "act", "slope", "out_dtype",
                     "interpret"),
)
def fused_attn_sell_kernel(
    tile_rows,  # int32[T]
    tile_cols,  # int32[T]
    mask_blocks,  # dtype[T, bm, bn]  0/1 structural pattern
    q_perm,  # dtype[n_live*bm, dk]  q gathered into packed row order
    kt,  # dtype[dk, Np]
    v,  # dtype[Np, D]
    *,
    n_live_block_rows: int,
    act: str = "leaky_relu",
    slope: float = 0.2,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    t_count, bm, bn = mask_blocks.shape
    mp, dk = q_perm.shape
    n, d = v.shape
    assert mp == n_live_block_rows * bm, (mp, n_live_block_rows, bm)
    assert n % bn == 0, (n, bn)

    grid = (t_count,)
    kernel = functools.partial(_sell_attn_kernel, n_tiles=t_count,
                               act=act, slope=slope)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bn),
                             lambda t, rows, cols: (t, 0, 0)),
                pl.BlockSpec((bm, dk), lambda t, rows, cols: (rows[t], 0)),
                pl.BlockSpec((dk, bn), lambda t, rows, cols: (0, cols[t])),
                pl.BlockSpec((bn, d), lambda t, rows, cols: (cols[t], 0)),
            ],
            out_specs=pl.BlockSpec(
                (bm, d), lambda t, rows, cols: (rows[t], 0)),
            scratch_shapes=[
                pltpu.VMEM((bm, d), jnp.float32),
                pltpu.VMEM((bm,), jnp.float32),
                pltpu.VMEM((bm,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((mp, d), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_graph_attention_sell",
    )(tile_rows, tile_cols, mask_blocks, q_perm, kt, v)


def fused_attn_sell(sell: SellCS, q, kt, v, *, act: str = "leaky_relu",
                    slope: float = 0.2, out_dtype=None,
                    use_kernel: bool = False, interpret: bool = False):
    """Fused attention over a SELL-packed topology (logical [M, D] out).

    The kernel walks live tiles only; rows in pruned slices have no
    edges, so their attention output is exactly zero and the epilogue
    gather's appended zero row restores them for free.
    """
    out_dtype = out_dtype or jnp.result_type(q.dtype, v.dtype)
    m, n = sell.shape
    dk = q.shape[1]
    d = v.shape[1]
    if not (use_kernel or interpret):
        return fused_attn_sell_slots_ref(sell, q, kt, v, act=act,
                                         slope=slope, out_dtype=out_dtype)
    if sell.n_tiles == 0:
        return jnp.zeros((m, d), out_dtype)

    from repro.kernels.spmm.sell import sell_tile_blocks

    bn = sell.bn
    n_pad = -(-n // bn) * bn
    q_ext = jnp.concatenate([q, jnp.zeros((1, dk), q.dtype)])
    q_perm = q_ext[sell.perm]  # [n_live*bm, dk]
    if kt.shape[1] != n_pad:
        kt = jnp.zeros((dk, n_pad), kt.dtype).at[:, :n].set(kt)
    if v.shape[0] != n_pad:
        v = jnp.zeros((n_pad, d), v.dtype).at[:n].set(v)
    mask = (sell_tile_blocks(sell) != 0).astype(jnp.float32)
    y = fused_attn_sell_kernel(
        sell.tile_rows, sell.tile_cols, mask, q_perm, kt, v,
        n_live_block_rows=sell.n_live_block_rows, act=act, slope=slope,
        out_dtype=out_dtype, interpret=interpret)
    y_ext = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])
    return y_ext[sell.tile_out_gather]


def fused_attn_sell_slots_ref(sell: SellCS, q, kt, v, *,
                              act: str = "leaky_relu", slope: float = 0.2,
                              out_dtype=jnp.float32):
    """Slot-granular reference over the packed slots.

    The slot triplet is an element layout (padding slots carry zero
    values and mask out against the structural pattern), so this is the
    element reference evaluated at the slot coordinates.
    """
    return fused_attn_elements(sell.slot_rows, sell.slot_cols,
                               sell.slot_vals, q, kt, v, sell.shape[0],
                               act=act, slope=slope, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Element (csr) and dense reference paths
# ---------------------------------------------------------------------------


def fused_attn_elements(row_ids, col_ids, values, q, kt, v, m: int, *,
                        act: str = "leaky_relu", slope: float = 0.2,
                        out_dtype=None):
    """The csr reference path (element-granular, E-length by nature)."""
    from repro.sparse.paths import sddmm_element_dots, spmm_elements

    out_dtype = out_dtype or jnp.result_type(q.dtype, v.dtype)
    dots = sddmm_element_dots(row_ids, col_ids, q, kt)
    mask = values != 0
    e = jnp.where(mask, apply_act(dots.astype(jnp.float32), act, slope),
                  NEG_INF)
    mx = jax.ops.segment_max(e, row_ids, num_segments=m)
    ex = jnp.where(mask, jnp.exp(e - mx[row_ids]), 0.0)
    den = jax.ops.segment_sum(ex, row_ids, num_segments=m)
    alpha = ex / jnp.maximum(den[row_ids], EPS)
    y = spmm_elements(row_ids, col_ids, alpha.astype(v.dtype), v, m)
    return y.astype(out_dtype)


def fused_attn_dense(a_dense, q, kt, v, *, act: str = "leaky_relu",
                     slope: float = 0.2, out_dtype=None):
    """Densified fallback: masked row softmax over the full product."""
    out_dtype = out_dtype or jnp.result_type(q.dtype, v.dtype)
    s = q.astype(jnp.float32) @ kt.astype(jnp.float32)
    mask = a_dense != 0
    e = jnp.where(mask, apply_act(s, act, slope), NEG_INF)
    mx = e.max(axis=1, keepdims=True)
    p = jnp.where(mask, jnp.exp(e - mx), 0.0)
    den = jnp.maximum(p.sum(axis=1, keepdims=True), EPS)
    return ((p / den) @ v.astype(jnp.float32)).astype(out_dtype)
