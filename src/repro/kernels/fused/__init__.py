"""Fused sparse pipelines: SpMM+epilogue and one-pass graph attention.

Kernel-level fusion of the repo's streaming sparse ops so intermediates
stay resident in VMEM instead of round-tripping HBM (the paper's
streamed-volume argument applied across op boundaries):

  * :mod:`repro.kernels.fused.epilogue` — the hashable ``Epilogue`` spec
    (act / bias / residual) and its jnp apply/grad helpers;
  * :mod:`repro.kernels.fused.spmm` — Block-ELL and SELL-C-σ SpMM with
    the epilogue applied to the VMEM accumulator at the output flush;
  * :mod:`repro.kernels.fused.attention` — SDDMM→edge-act→segment-
    softmax→SpMM in one pass over the topology's live tiles (max/sum
    online softmax; the E-length score vector never exists in HBM).

The differentiable front-ends live in ``repro.sparse.ops``
(``matmul(..., epilogue=...)`` and ``fused_graph_attention``).
"""
from repro.kernels.fused.epilogue import (Epilogue, act_grad_from_out,
                                          apply_act, apply_epilogue,
                                          normalize_epilogue)

__all__ = [
    "Epilogue", "act_grad_from_out", "apply_act", "apply_epilogue",
    "normalize_epilogue",
]
