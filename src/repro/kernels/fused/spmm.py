"""SpMM + epilogue fused Pallas TPU kernels (Block-ELL and SELL-C-σ).

Both kernels are the repo's streaming SpMM kernels with the elementwise
tail — ``act(y + bias + residual)`` — applied to the VMEM accumulator at
the single output flush, so the raw product never round-trips HBM just
to have a bias added and a relu applied (the paper's
intermediate-materialization tax, killed at the kernel level).

  * Block-ELL grid: (nbr, D/bd, W) exactly like ``kernels/spmm/kernel``;
    the epilogue runs inside the ``w == W-1`` flush.  Bias streams as a
    (1, bd) tile of the [1, D] vector, the residual as the output-shaped
    (bm, bd) tile — both only when the spec says they participate, so an
    epilogue-free call builds the identical pipeline as before.
  * SELL grid: (D/bd, T) over live tiles like ``kernels/spmm/sell``;
    the epilogue runs at every row-change flush.  The residual is
    pre-gathered into *packed* row order by the wrapper (``perm``), and
    rows living in pruned (all-zero) slices — which the kernel never
    touches — get their ``act(bias + residual)`` background re-inserted
    by the epilogue gather, keeping the semantics identical to the
    logical ``act(A @ H + bias + residual)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import SellCS
from repro.kernels._compat import tpu_compiler_params
from repro.kernels.fused.epilogue import Epilogue, apply_act, apply_epilogue


def _finish(acc, epi: Epilogue, bias_blk, res_blk):
    z = acc
    if epi.has_bias:
        z = z + bias_blk.astype(jnp.float32)
    if epi.has_residual:
        z = z + res_blk.astype(jnp.float32)
    return apply_act(z, epi.act, epi.negative_slope)


# ---------------------------------------------------------------------------
# Block-ELL SpMM + epilogue
# ---------------------------------------------------------------------------


def _ell_fused_kernel(idx_ref, a_ref, h_ref, *rest, n_slots: int,
                      epi: Epilogue):
    """o[i, j] = act(sum_k A[i, k] @ H[idx[i, k], j] + bias + res)."""
    refs = list(rest)
    bias_ref = refs.pop(0) if epi.has_bias else None
    res_ref = refs.pop(0) if epi.has_residual else None
    o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, 0, :, :],
        h_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_slots - 1)
    def _flush():
        bias_blk = bias_ref[0, :] if epi.has_bias else None
        res_blk = res_ref[...] if epi.has_residual else None
        o_ref[...] = _finish(acc_ref[...], epi, bias_blk,
                             res_blk).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("epi", "bd", "out_dtype", "interpret"),
)
def spmm_blockell_epilogue_kernel(
    indices,  # int32[nbr, W]
    blocks,  # dtype[nbr, W, bm, bn]
    h,  # dtype[N, D]
    bias,  # dtype[1, D] (zeros-shaped dummy never built: pass None-free)
    res,  # dtype[nbr*bm, D]
    *,
    epi: Epilogue,
    bd: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    nbr, w, bm, bn = blocks.shape
    n, d = h.shape
    assert d % bd == 0, (d, bd)
    assert n % bn == 0, (n, bn)

    grid = (nbr, d // bd, w)
    kernel = functools.partial(_ell_fused_kernel, n_slots=w, epi=epi)
    in_specs = [
        pl.BlockSpec((1, 1, bm, bn), lambda i, j, k, idx: (i, k, 0, 0)),
        pl.BlockSpec((bn, bd), lambda i, j, k, idx: (idx[i, k], j)),
    ]
    operands = [blocks, h]
    if epi.has_bias:
        in_specs.append(pl.BlockSpec((1, bd), lambda i, j, k, idx: (0, j)))
        operands.append(bias)
    if epi.has_residual:
        in_specs.append(pl.BlockSpec((bm, bd), lambda i, j, k, idx: (i, j)))
        operands.append(res)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bd), lambda i, j, k, idx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nbr * bm, d), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spmm_blockell_epilogue",
    )(indices, *operands)


def spmm_blockell_fused(ell, h, epi: Epilogue, bias=None, residual=None,
                        *, bd=None, out_dtype=None, use_kernel: bool = False,
                        interpret: bool = False):
    """Y = act(A @ H + bias + residual) with A in Block-ELL.

    ``h`` is already padded to ``ell.shape[1]`` rows (the SpMM-path
    contract); the output carries the padded ``nbr*bm`` rows — callers
    trim to the logical row count like the unfused path.  ``residual``
    carries *logical* rows and is zero-padded here.
    """
    from repro.kernels.spmm.ops import _pick_bd, spmm_blockell

    out_dtype = out_dtype or jnp.result_type(ell.blocks.dtype, h.dtype)
    if not (use_kernel or interpret):
        y = spmm_blockell(ell, h, bd=bd, out_dtype=out_dtype,
                          use_kernel=False)
        res = residual
        if res is not None and res.shape[0] != y.shape[0]:
            res = jnp.zeros((y.shape[0],) + res.shape[1:], res.dtype) \
                .at[: res.shape[0]].set(res)
        return apply_epilogue(y, epi, bias, res)
    d = h.shape[1]
    mp = ell.n_block_rows * ell.bm
    bias2d = None
    if epi.has_bias:
        bias2d = jnp.asarray(bias).reshape(1, d)
    res = None
    if epi.has_residual:
        res = residual
        if res.shape[0] != mp:
            res = jnp.zeros((mp, d), res.dtype).at[: res.shape[0]].set(res)
    return spmm_blockell_epilogue_kernel(
        ell.indices, ell.blocks, h, bias2d, res,
        epi=epi, bd=bd or _pick_bd(d), out_dtype=out_dtype,
        interpret=interpret)


# ---------------------------------------------------------------------------
# SELL-C-σ SpMM + epilogue
# ---------------------------------------------------------------------------


def _sell_fused_kernel(rows_ref, cols_ref, a_ref, h_ref, *rest,
                       n_tiles: int, epi: Epilogue):
    refs = list(rest)
    bias_ref = refs.pop(0) if epi.has_bias else None
    res_ref = refs.pop(0) if epi.has_residual else None
    o_ref, acc_ref = refs
    t = pl.program_id(1)
    row = rows_ref[t]
    prev = rows_ref[jnp.maximum(t - 1, 0)]
    nxt = rows_ref[jnp.minimum(t + 1, n_tiles - 1)]

    @pl.when((t == 0) | (row != prev))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, :, :],
        h_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when((t == n_tiles - 1) | (row != nxt))
    def _flush():
        bias_blk = bias_ref[0, :] if epi.has_bias else None
        res_blk = res_ref[...] if epi.has_residual else None
        o_ref[...] = _finish(acc_ref[...], epi, bias_blk,
                             res_blk).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("epi", "n_live_block_rows", "bd", "out_dtype",
                     "interpret"),
)
def spmm_sell_epilogue_kernel(
    tile_rows,  # int32[T]
    tile_cols,  # int32[T]
    tile_blocks,  # dtype[T, bm, bn]
    h,  # dtype[Np, D]
    bias,  # dtype[1, D] or None
    res_perm,  # dtype[n_live*bm, D] residual in packed row order, or None
    *,
    epi: Epilogue,
    n_live_block_rows: int,
    bd: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    t_count, bm, bn = tile_blocks.shape
    n, d = h.shape
    assert d % bd == 0, (d, bd)
    assert n % bn == 0, (n, bn)

    grid = (d // bd, t_count)
    kernel = functools.partial(_sell_fused_kernel, n_tiles=t_count, epi=epi)
    in_specs = [
        pl.BlockSpec((1, bm, bn), lambda j, t, rows, cols: (t, 0, 0)),
        pl.BlockSpec((bn, bd), lambda j, t, rows, cols: (cols[t], j)),
    ]
    operands = [tile_blocks, h]
    if epi.has_bias:
        in_specs.append(
            pl.BlockSpec((1, bd), lambda j, t, rows, cols: (0, j)))
        operands.append(bias)
    if epi.has_residual:
        in_specs.append(
            pl.BlockSpec((bm, bd), lambda j, t, rows, cols: (rows[t], j)))
        operands.append(res_perm)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (bm, bd), lambda j, t, rows, cols: (rows[t], j)),
            scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_live_block_rows * bm, d),
                                       out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spmm_sell_epilogue",
    )(tile_rows, tile_cols, *operands)


def spmm_sell_fused(sell: SellCS, h, epi: Epilogue, bias=None,
                    residual=None, *, bd=None, out_dtype=None,
                    use_kernel: bool = False, interpret: bool = False):
    """Y = act(A @ H + bias + residual) with A in SELL-C-σ.

    ``h`` carries the logical N rows.  Rows the tile-pruned kernel never
    computes (all-zero rows in pruned slices) still owe their epilogue
    background ``act(bias + residual)``, which the final gather
    re-inserts — with no bias/residual that background is exactly zero
    (every supported act fixes 0), so the cheap path is unchanged.
    """
    from repro.kernels.spmm.ops import _pick_bd
    from repro.sparse.paths import spmm_sell_ref

    out_dtype = out_dtype or jnp.result_type(sell.slot_vals.dtype, h.dtype)
    m, n = sell.shape
    d = h.shape[1]
    if not (use_kernel or interpret):
        y = spmm_sell_ref(sell, h, out_dtype=out_dtype)
        return apply_epilogue(y, epi, bias, residual)
    if sell.n_live_block_rows == 0:
        y = jnp.zeros((m, d), out_dtype)
        return apply_epilogue(y, epi, bias, residual)

    from repro.kernels.spmm.sell import sell_tile_blocks

    bn = sell.bn
    n_pad = -(-n // bn) * bn
    if h.shape[0] != n_pad:
        h = jnp.zeros((n_pad, d), h.dtype).at[:n].set(h)
    bias2d = jnp.asarray(bias).reshape(1, d) if epi.has_bias else None
    res_perm = None
    if epi.has_residual:
        res_ext = jnp.concatenate(
            [residual, jnp.zeros((1, d), residual.dtype)])
        res_perm = res_ext[sell.perm]  # packed row order; pad rows zero
    y = spmm_sell_epilogue_kernel(
        sell.tile_rows, sell.tile_cols, sell_tile_blocks(sell), h,
        bias2d, res_perm, epi=epi,
        n_live_block_rows=sell.n_live_block_rows,
        bd=bd or _pick_bd(d), out_dtype=out_dtype, interpret=interpret)
    y_ext = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])
    out = y_ext[sell.tile_out_gather]
    if epi.has_bias or epi.has_residual:
        # pruned rows (A row all-zero): out = act(bias + residual[row])
        zero = jnp.zeros((m, d), jnp.float32)
        bg = apply_epilogue(zero, epi, bias, residual).astype(out.dtype)
        live = (sell.tile_out_gather < sell.n_live_block_rows * sell.bm)
        out = jnp.where(live[:, None], out, bg)
    return out
