"""Tile-pruned SELL-C-σ SDDMM Pallas TPU kernel.

Samples B @ C only at the live tiles of a SELL-packed operand: the grid
walks the flat live-tile descriptor (scalar-prefetched), streams the
(bm x bk) B tile and (bk x bn) C tile each live tile needs, contracts
over K with the accumulator resident in VMEM, and masks with the tile's
structural pattern at the flush — all-zero row slices were pruned at
pack time, so no grid step ever samples a dead tile.

Because SELL packs *permuted* rows, the caller passes B already gathered
into packed row order (``b[perm]`` — the row gather the descriptor
records); the slot extraction afterwards folds the tile output back to
slot (element) order.

Grid: (T, K/bk)   [K innermost => sequential accumulation]
  B_perm: [L*bm, K]     -> tile (bm, bk)    at (rows[t], k)
  C:      [K, Np]       -> tile (bk, bn)    at (k, cols[t])
  mask:   [T, bm, bn]   -> tile (1, bm, bn) at (t, 0, 0)
  Y:      [T, bm, bn]   -> tile (1, bm, bn) at (t, 0, 0), revisited in k
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import SellCS
from repro.kernels._compat import tpu_compiler_params


def _sell_sddmm_kernel(rows_ref, cols_ref, b_ref, c_ref, mask_ref, o_ref,
                       acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        b_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _sample():
        mask = mask_ref[0, :, :].astype(jnp.float32)
        o_ref[0, :, :] = (mask * acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "out_dtype", "interpret")
)
def sddmm_sell_kernel(
    tile_rows,  # int32[T] compact live block-row per tile
    tile_cols,  # int32[T] block-column per tile
    mask_blocks,  # dtype[T, bm, bn] structural 0/1 pattern of each tile
    b_perm,  # dtype[L*bm, K]  B gathered into packed row order
    c,  # dtype[K, Np]
    *,
    bk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    t_count, bm, bn = mask_blocks.shape
    m, k = b_perm.shape
    k2, n = c.shape
    assert k == k2 and k % bk == 0, (k, bk)

    grid = (t_count, k // bk)
    kernel = functools.partial(_sell_sddmm_kernel, n_k=k // bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (bm, bk), lambda t, kk, rows, cols: (rows[t], kk)
                ),
                pl.BlockSpec(
                    (bk, bn), lambda t, kk, rows, cols: (kk, cols[t])
                ),
                pl.BlockSpec(
                    (1, bm, bn), lambda t, kk, rows, cols: (t, 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, bm, bn), lambda t, kk, rows, cols: (t, 0, 0)
            ),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t_count, bm, bn), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="sddmm_sell",
    )(tile_rows, tile_cols, b_perm, c, mask_blocks)
    return out


def sddmm_sell_tiles_ref(tile_rows, tile_cols, mask_blocks, b_perm, c,
                         *, out_dtype=jnp.float32):
    """Pure-jnp oracle of the kernel's masked tile output."""
    t_count, bm, bn = mask_blocks.shape
    m, k = b_perm.shape
    _, n = c.shape
    b_tiles = b_perm.reshape(m // bm, bm, k)[tile_rows]
    c_tiles = c.reshape(k, n // bn, bn).transpose(1, 0, 2)[tile_cols]
    prod = jnp.einsum(
        "tmk,tkn->tmn",
        b_tiles.astype(jnp.float32),
        c_tiles.astype(jnp.float32),
    )
    return (mask_blocks.astype(jnp.float32) * prod).astype(out_dtype)


def sample_sell_blocked(sell: SellCS, b, c, *, bk: int | None = None,
                        interpret: bool = False):
    """Raw dots (B @ C) at the live structural slots, in slot order.

    ``b``: [M, K] logical rows; ``c``: [K, N] logical columns.  Output:
    float32[n_slots] — padding slots read the appended zero cell.
    """
    from repro.kernels.sddmm.ops import _pick_bk

    m, n = sell.shape
    k = b.shape[1]
    n_slots = sell.n_slots
    if sell.n_tiles == 0:
        return jnp.zeros((n_slots,), jnp.float32)
    bn = sell.bn
    n_pad = -(-n // bn) * bn
    b_ext = jnp.concatenate([b, jnp.zeros((1, k), b.dtype)])
    b_perm = b_ext[sell.perm]  # [n_live*bm, K]; padding rows are zero
    if c.shape[1] != n_pad:
        c = jnp.zeros((k, n_pad), c.dtype).at[:, :n].set(c)
    mask = (sell.tile_slot_map < n_slots).astype(b.dtype)
    tiles = sddmm_sell_kernel(
        sell.tile_rows, sell.tile_cols, mask, b_perm, c,
        bk=bk or _pick_bk(k), out_dtype=jnp.float32, interpret=interpret)
    flat = jnp.concatenate([tiles.reshape(-1), jnp.zeros((1,), tiles.dtype)])
    return flat[sell.slot_tile_pos]
