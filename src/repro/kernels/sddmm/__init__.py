from repro.kernels.sddmm.ops import sddmm_blockcoo
from repro.kernels.sddmm.ref import sddmm_blockcoo_ref

__all__ = ["sddmm_blockcoo", "sddmm_blockcoo_ref"]
