"""Block-COO SDDMM Pallas TPU kernel:  Y_blk = A_blk ⊙ (B_row · C_col).

CS-3 -> TPU adaptation (DESIGN.md §2): the paper keeps the nonzero tile of A
stationary on each worker PE and streams columns of B / rows of C through
the grid.  On TPU the nonzero-block list is scalar-prefetched, and the
pipeline streams the (bm x bk) B tile and (bk x bn) C tile each block needs
from HBM; the contraction over K happens across the innermost grid dim with
the accumulator resident in VMEM (the stationary-output dataflow).

Grid: (nnzb, K/bk)   [K innermost => sequential accumulation]
  B:      [M, K]           -> tile (bm, bk)     at (rows[e], k)
  C:      [K, N]           -> tile (bk, bn)     at (k, cols[e])
  A mask: [nnzb, bm, bn]   -> tile (1, bm, bn)  at (e, 0, 0)
  Y:      [nnzb, bm, bn]   -> tile (1, bm, bn)  at (e, 0, 0), revisited in k
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _sddmm_kernel(rows_ref, cols_ref, b_ref, c_ref, a_ref, o_ref, acc_ref,
                  *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        b_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _sample():
        mask = a_ref[0, :, :].astype(jnp.float32)
        o_ref[0, :, :] = (mask * acc_ref[...]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bk", "out_dtype", "interpret")
)
def sddmm_blockcoo_kernel(
    rows,  # int32[nnzb]
    cols,  # int32[nnzb]
    mask_blocks,  # dtype[nnzb, bm, bn]
    b,  # dtype[M, K]
    c,  # dtype[K, N]
    *,
    bk: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    nnzb, bm, bn = mask_blocks.shape
    m, k = b.shape
    k2, n = c.shape
    assert k == k2 and k % bk == 0, (k, bk)

    grid = (nnzb, k // bk)
    kernel = functools.partial(_sddmm_kernel, n_k=k // bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda e, kk, rows, cols: (rows[e], kk)),
                pl.BlockSpec((bk, bn), lambda e, kk, rows, cols: (kk, cols[e])),
                pl.BlockSpec((1, bm, bn), lambda e, kk, rows, cols: (e, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, bm, bn), lambda e, kk, rows, cols: (e, 0, 0)
            ),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnzb, bm, bn), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="sddmm_blockcoo",
    )(rows, cols, b, c, mask_blocks)
    return out
