"""Pure-jnp oracle for Block-COO SDDMM: Y = A ⊙ (B @ C)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import BlockCOO


def sddmm_blockcoo_ref(coo: BlockCOO, b, c, *, out_dtype=None):
    """Reference SDDMM.

    coo.blocks are the sampling values of A (for a 0/1 mask this returns the
    sampled product; for weighted A it returns A ⊙ (B@C)).
    b: [M, K]; c: [K, N].  Output: BlockCOO with the same coordinates.
    Padded entries carry zero mask blocks so their output is zero.
    """
    bm, bn = coo.bm, coo.bn
    m, k = b.shape
    k2, n = c.shape
    assert k == k2, (b.shape, c.shape)
    b_blocks = b.reshape(m // bm, bm, k)[coo.rows]  # [nnzb, bm, K]
    c_blocks = c.reshape(k, n // bn, bn).transpose(1, 0, 2)[coo.cols]
    prod = jnp.einsum(
        "emk,ekn->emn",
        b_blocks.astype(jnp.float32),
        c_blocks.astype(jnp.float32),
    )
    out_dtype = out_dtype or jnp.result_type(coo.blocks.dtype, b.dtype)
    out_blocks = (coo.blocks.astype(jnp.float32) * prod).astype(out_dtype)
    return BlockCOO(
        rows=coo.rows, cols=coo.cols, blocks=out_blocks, shape=coo.shape
    )
