"""Public jit'd wrapper for Block-COO SDDMM."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.formats import BlockCOO
from repro.kernels.sddmm.kernel import sddmm_blockcoo_kernel
from repro.kernels.sddmm.ref import sddmm_blockcoo_ref


def _pick_bk(k: int) -> int:
    for cand in (512, 256, 128):
        if k % cand == 0:
            return cand
    return k  # tiny contraction dim (paper uses d=2 for GAT scores)


def sddmm_blockcoo(
    coo: BlockCOO,
    b,
    c,
    *,
    bk: int | None = None,
    out_dtype=None,
    use_kernel: bool = True,
    interpret: bool = False,
) -> BlockCOO:
    """Y = A ⊙ (B @ C), computed only at A's nonzero blocks."""
    out_dtype = out_dtype or jnp.result_type(coo.blocks.dtype, b.dtype)
    if not use_kernel:
        return sddmm_blockcoo_ref(coo, b, c, out_dtype=out_dtype)
    k = b.shape[1]
    bk = bk or _pick_bk(k)
    if k % bk != 0:
        raise ValueError(f"K={k} not divisible by bk={bk}")
    out_blocks = sddmm_blockcoo_kernel(
        coo.rows, coo.cols, coo.blocks, b, c,
        bk=bk, out_dtype=out_dtype, interpret=interpret,
    )
    return BlockCOO(
        rows=coo.rows, cols=coo.cols, blocks=out_blocks, shape=coo.shape
    )
