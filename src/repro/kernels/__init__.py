"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel package contains:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (tile selection, padding, dtype policy)
  ref.py    — pure-jnp oracle used by tests and as the CPU execution path
"""
