"""Block-ELL SpMM Pallas TPU kernel.

Design (CS-3 -> TPU adaptation, see DESIGN.md §2):

  * The paper's router PEs pre-filter the stream of (col_idx, value) pairs so
    each worker row only sees nonzeros in its column range.  Here that
    filtering is done once at format-construction time (Block-ELL), and the
    *scalar-prefetched* block-column indices drive the Pallas pipeline's
    `index_map`, so the HBM->VMEM DMA engine fetches exactly the H tile each
    A block needs — the dataflow "router" realized as prefetch-driven DMA.

  * The paper pads every stream to equal length (NULL wavelets) so I/O
    channels stay uniform.  Here every block-row is padded to the same ELL
    width W, so the grid is static and each step does identical work; padded
    slots carry zero blocks and clipped indices and contribute exactly 0.

  * The paper's north->south partial-sum folding maps to output-block
    revisiting: the innermost grid dimension walks the W nonzero slots while
    the output tile stays resident in VMEM and accumulates.

Grid: (num_block_rows, D/bd, W)   [W innermost => sequential accumulation]
  A blocks: [nbr, W, bm, bn] -> tile (1, 1, bm, bn) at (i, k, 0, 0)
  H:        [N, D]           -> tile (bn, bd)       at (idx[i, k], j)
  Y:        [M, D]           -> tile (bm, bd)       at (i, j), revisited in k
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _spmm_kernel(idx_ref, a_ref, h_ref, o_ref, acc_ref, *, n_slots: int):
    """One grid step: o[i, j] += A[i, k] @ H[idx[i, k], j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_blk = a_ref[0, 0, :, :]
    h_blk = h_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a_blk,
        h_blk,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_slots - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bd", "out_dtype", "interpret"),
)
def spmm_blockell_kernel(
    indices,  # int32[nbr, W]
    blocks,  # dtype[nbr, W, bm, bn]
    h,  # dtype[N, D]
    *,
    bd: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    nbr, w, bm, bn = blocks.shape
    n, d = h.shape
    assert d % bd == 0, (d, bd)
    assert n % bn == 0, (n, bn)

    grid = (nbr, d // bd, w)

    kernel = functools.partial(_spmm_kernel, n_slots=w)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bm, bn), lambda i, j, k, idx: (i, k, 0, 0)
                ),
                pl.BlockSpec((bn, bd), lambda i, j, k, idx: (idx[i, k], j)),
            ],
            out_specs=pl.BlockSpec((bm, bd), lambda i, j, k, idx: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nbr * bm, d), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spmm_blockell",
    )(indices, blocks, h)
    return out
