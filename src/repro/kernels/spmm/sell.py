"""Tile-pruned SELL-C-σ SpMM Pallas TPU kernel.

The Block-ELL kernel pads every block-row to one global width W, so past
~99 % sparsity nearly all of its grid steps multiply zero padding — the
paper's hyper-sparsity cliff.  This kernel iterates a *flat list of live
tiles* instead (the SELL slice descriptor, scalar-prefetched):

  * the grid's sequential axis walks only tiles that exist — all-zero
    row slices were pruned at pack time and are never launched;
  * tiles are ordered block-row-major, so the output tile stays resident
    in VMEM while consecutive grid steps accumulate into it; the flush
    happens when the scalar-prefetched ``tile_rows`` descriptor changes
    (width-adaptive: each block-row owns exactly as many steps as it has
    live tiles);
  * the output is *compacted* — only live block-rows are written — and
    the caller's epilogue gather applies the inverse row permutation,
    re-inserts pruned (all-zero) rows, and trims padding in one pass.

Grid: (D/bd, T)   [T innermost => sequential accumulate/flush]
  A tiles: [T, bm, bn] -> tile (1, bm, bn)  at (t, 0, 0)
  H:       [Np, D]     -> tile (bn, bd)     at (cols[t], j)
  Y:       [L*bm, D]   -> tile (bm, bd)     at (rows[t], j), revisited
                          while rows[t] stays constant
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import SellCS
from repro.kernels._compat import tpu_compiler_params


def _sell_spmm_kernel(rows_ref, cols_ref, a_ref, h_ref, o_ref, acc_ref,
                      *, n_tiles: int):
    """One live tile: acc += A_tile @ H[cols[t]]; flush on row change."""
    t = pl.program_id(1)
    row = rows_ref[t]
    prev = rows_ref[jnp.maximum(t - 1, 0)]
    nxt = rows_ref[jnp.minimum(t + 1, n_tiles - 1)]

    @pl.when((t == 0) | (row != prev))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0, :, :],
        h_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when((t == n_tiles - 1) | (row != nxt))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_live_block_rows", "bd", "out_dtype", "interpret"),
)
def spmm_sell_kernel(
    tile_rows,  # int32[T]  compact live block-row per tile (ascending)
    tile_cols,  # int32[T]  block-column per tile
    tile_blocks,  # dtype[T, bm, bn]  live tile data
    h,  # dtype[Np, D]  (rows padded to the block-column grid)
    *,
    n_live_block_rows: int,
    bd: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
):
    """Compact Y for the live block-rows only: [n_live*bm, D]."""
    t_count, bm, bn = tile_blocks.shape
    n, d = h.shape
    assert d % bd == 0, (d, bd)
    assert n % bn == 0, (n, bn)

    grid = (d // bd, t_count)
    kernel = functools.partial(_sell_spmm_kernel, n_tiles=t_count)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, bm, bn), lambda j, t, rows, cols: (t, 0, 0)
                ),
                pl.BlockSpec(
                    (bn, bd), lambda j, t, rows, cols: (cols[t], j)
                ),
            ],
            out_specs=pl.BlockSpec(
                (bm, bd), lambda j, t, rows, cols: (rows[t], j)
            ),
            scratch_shapes=[pltpu.VMEM((bm, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_live_block_rows * bm, d),
                                       out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="spmm_sell",
    )(tile_rows, tile_cols, tile_blocks, h)
    return out


def spmm_sell_tiles_ref(tile_rows, tile_cols, tile_blocks, h,
                        *, n_live_block_rows: int, out_dtype=jnp.float32):
    """Pure-jnp oracle of the kernel's compact output (tile granular)."""
    t_count, bm, bn = tile_blocks.shape
    n, d = h.shape
    h_blocks = h.reshape(n // bn, bn, d)
    prods = jnp.einsum(
        "tmn,tnd->tmd",
        tile_blocks.astype(jnp.float32),
        h_blocks[tile_cols].astype(jnp.float32),
    )
    out = jax.ops.segment_sum(prods, tile_rows,
                              num_segments=n_live_block_rows)
    return out.reshape(n_live_block_rows * bm, d).astype(out_dtype)


def sell_tile_blocks(sell: SellCS):
    """Gather the live-tile data from the slot values (trace-safe).

    Values live exactly once (``slot_vals``); dead tile cells map to the
    appended zero slot.
    """
    vals_ext = jnp.concatenate(
        [sell.slot_vals, jnp.zeros((1,), sell.slot_vals.dtype)])
    return vals_ext[sell.tile_slot_map]


def spmm_sell_blocked(sell: SellCS, h, *, bd: int | None = None,
                      out_dtype=None, interpret: bool = False):
    """Y = A @ H through the tile-pruned kernel, epilogue applied.

    ``h`` carries the logical N rows; it is padded to the block-column
    grid here.  The epilogue gather un-permutes rows, re-inserts the
    pruned all-zero rows, and trims to the logical row count.
    """
    from repro.kernels.spmm.ops import _pick_bd

    out_dtype = out_dtype or jnp.result_type(sell.slot_vals.dtype, h.dtype)
    m, n = sell.shape
    d = h.shape[1]
    if sell.n_live_block_rows == 0:
        return jnp.zeros((m, d), out_dtype)
    bn = sell.bn
    n_pad = -(-n // bn) * bn
    if h.shape[0] != n_pad:
        h = jnp.zeros((n_pad, d), h.dtype).at[:n].set(h)
    y = spmm_sell_kernel(
        sell.tile_rows, sell.tile_cols, sell_tile_blocks(sell), h,
        n_live_block_rows=sell.n_live_block_rows,
        bd=bd or _pick_bd(d), out_dtype=out_dtype, interpret=interpret)
    y_ext = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])
    return y_ext[sell.tile_out_gather]
