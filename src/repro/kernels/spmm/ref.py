"""Pure-jnp oracle for Block-ELL SpMM: Y = A @ H."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import BlockELL


def spmm_blockell_ref(ell: BlockELL, h, *, out_dtype=None):
    """Reference Y = A @ H with A in Block-ELL.

    ell.blocks: [nbr, W, bm, bn]; ell.indices: [nbr, W]; h: [N, D].
    Padded ELL slots carry zero blocks, so gathering an arbitrary (valid)
    H tile for them is harmless — same contract as the Pallas kernel.
    """
    nbr, w, bm, bn = ell.blocks.shape
    n, d = h.shape
    assert n == ell.shape[1], (n, ell.shape)
    h_blocks = h.reshape(n // bn, bn, d)
    gathered = h_blocks[ell.indices]  # [nbr, W, bn, D]
    acc = jnp.einsum(
        "rwmn,rwnd->rmd",
        ell.blocks.astype(jnp.float32),
        gathered.astype(jnp.float32),
    )
    out_dtype = out_dtype or ell.blocks.dtype
    return acc.reshape(nbr * bm, d).astype(out_dtype)
