"""Public jit'd wrapper for Block-ELL SpMM."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import BlockELL
from repro.kernels.spmm.kernel import spmm_blockell_kernel
from repro.kernels.spmm.ref import spmm_blockell_ref


def _pick_bd(d: int) -> int:
    """Largest MXU-friendly tile of the D axis that divides D (<=512)."""
    for cand in (512, 256, 128):
        if d % cand == 0:
            return cand
    return d  # small D (e.g. GAT scores with d=2): single tile


def spmm_blockell(
    ell: BlockELL,
    h,
    *,
    bd: int | None = None,
    out_dtype=None,
    use_kernel: bool = True,
    interpret: bool = False,
):
    """Y = A @ H with A in Block-ELL format.

    ``use_kernel=False`` (or a non-TPU-friendly shape) falls back to the
    pure-jnp reference, which XLA fuses well on CPU; the Pallas kernel is the
    TPU execution path and is validated against the reference in interpret
    mode by tests/test_kernels_spmm.py.
    """
    out_dtype = out_dtype or jnp.result_type(ell.blocks.dtype, h.dtype)
    n, d = h.shape
    if not use_kernel:
        return spmm_blockell_ref(ell, h, out_dtype=out_dtype)
    bd = bd or _pick_bd(d)
    if d % bd != 0:
        raise ValueError(f"D={d} not divisible by bd={bd}")
    return spmm_blockell_kernel(
        ell.indices,
        ell.blocks,
        h,
        bd=bd,
        out_dtype=out_dtype,
        interpret=interpret,
    )
