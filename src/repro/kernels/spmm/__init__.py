from repro.kernels.spmm.ops import spmm_blockell
from repro.kernels.spmm.ref import spmm_blockell_ref

__all__ = ["spmm_blockell", "spmm_blockell_ref"]
