"""Deterministic synthetic data pipeline.

Per-host sharding: each host materializes only its slice of the global
batch (``host_index``/``host_count``), and batches are pure functions of
(seed, step) so a restarted or re-elected host regenerates identical data
— deterministic recovery is a fault-tolerance requirement, not a nicety.

Modality frontends are STUBS per the assignment: ``vision_embeds`` /
``enc_embeds`` are pseudo-random patch/frame embeddings of the right shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    # synthetic LM stream: a noisy long-range copy task so losses can
    # actually decrease (pure uniform noise has no learnable signal)
    structure: str = "ngram"  # ngram | uniform


def _host_slice(global_batch: int, dcfg: DataConfig):
    per = global_batch // dcfg.host_count
    return per


def make_lm_batch(cfg: ModelConfig, seq_len: int, global_batch: int,
                  step: int, dcfg: DataConfig) -> Dict[str, jnp.ndarray]:
    b = _host_slice(global_batch, dcfg)
    rng = np.random.default_rng(
        (dcfg.seed * 1_000_003 + step) * 65_537 + dcfg.host_index)
    n_text = seq_len - cfg.vision_tokens
    if dcfg.structure == "ngram":
        # Markov-ish stream: next token = (3 * prev + noise) mod V
        toks = np.empty((b, n_text + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, b)
        noise = rng.integers(0, 7, (b, n_text))
        for t in range(n_text):
            toks[:, t + 1] = (3 * toks[:, t] + noise[:, t]) % cfg.vocab_size
    else:
        toks = rng.integers(0, cfg.vocab_size, (b, n_text + 1),
                            dtype=np.int32)
    batch = {
        "tokens": jnp.asarray(toks[:, :-1]),
        "targets": jnp.asarray(toks[:, 1:]),
        "mask": jnp.ones((b, n_text), jnp.float32),
    }
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model))
            .astype(np.float32))
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model))
            .astype(np.float32))
    return batch


def lm_data_iter(cfg: ModelConfig, shape: ShapeConfig,
                 dcfg: Optional[DataConfig] = None,
                 start_step: int = 0) -> Iterator[Dict]:
    dcfg = dcfg or DataConfig()
    step = start_step
    while True:
        yield make_lm_batch(cfg, shape.seq_len, shape.global_batch, step,
                            dcfg)
        step += 1


# ---------------------------------------------------------------------------
# Synthetic sparse matrices / graphs (the paper's evaluation §4.1)
# ---------------------------------------------------------------------------


def random_sparse_dense(n: int, density: float, seed: int = 0,
                        m: Optional[int] = None) -> np.ndarray:
    """Random N x N (or M x N) matrix with the given density — the paper's
    synthetic workload ("random sparse and dense matrices, K=N")."""
    m = m or n
    rng = np.random.default_rng(seed)
    mask = rng.random((m, n)) < density
    vals = rng.normal(size=(m, n)).astype(np.float32)
    return np.where(mask, vals, 0.0).astype(np.float32)


def random_graph(n_nodes: int, avg_degree: float, seed: int = 0,
                 clustered: bool = True) -> np.ndarray:
    """Synthetic adjacency with power-law-ish degree skew (GNN-like)."""
    rng = np.random.default_rng(seed)
    if not clustered:
        density = avg_degree / n_nodes
        return (rng.random((n_nodes, n_nodes)) < density).astype(np.float32)
    # preferential-attachment-ish skewed degrees
    w = rng.pareto(2.0, n_nodes) + 1.0
    w /= w.sum()
    nnz = int(avg_degree * n_nodes)
    rows = rng.choice(n_nodes, size=nnz, p=w)
    cols = rng.integers(0, n_nodes, size=nnz)
    a = np.zeros((n_nodes, n_nodes), np.float32)
    a[rows, cols] = 1.0
    return a
