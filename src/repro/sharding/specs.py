"""Parameter / cache / batch PartitionSpec policies.

FSDP+TP ("2D") scheme in MaxText style:
  * every weight matrix shards its input-ish dim over `data` (FSDP) and its
    output-ish dim over `model` (TP); optimizer moments inherit => ZeRO.
  * experts shard over `model` (EP), their inner dims over `data`.
  * the `pod` axis is pure DP/2.5D-replication: parameters are replicated
    across pods, gradients cross pods once per step.

Axes are applied only when they divide the dim (``_fit``): vocab sizes like
51865 or 92553 simply fall back to replication for that dim instead of
relying on XLA's uneven-sharding padding — keeps memory accounting exact.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


# ---------------------------------------------------------------------------
# Version-guarded mesh construction
# ---------------------------------------------------------------------------
#
# ``jax.sharding.AxisType`` only exists from jax 0.5 onward; the pinned
# jax 0.4.37 builds meshes without explicit axis types (every axis is
# "auto" there anyway).  All mesh construction in the repo goes through
# ``make_mesh`` so the guard lives in exactly one place.


def axis_types_kw(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh`` if this jax supports it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes, *, devices=None) -> Mesh:
    """``jax.make_mesh`` with auto axis types on jax versions that have them."""
    kw = axis_types_kw(len(axes))
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


# key -> (logical spec per trailing dims of the UNSTACKED param)
_PARAM_RULES = {
    # projections [in, out]
    "wq": ("fsdp", "tensor"), "wk": ("fsdp", "tensor"),
    "wv": ("fsdp", "tensor"), "wi": ("fsdp", "tensor"),
    "wg": ("fsdp", "tensor"), "wx": ("fsdp", "tensor"),
    "in_proj": ("fsdp", "tensor"),
    "vision_proj": ("fsdp", "tensor"),
    "lm_head": ("fsdp", "tensor"),
    "router": ("fsdp", None),
    # output projections [in, d]
    "wo": ("tensor", "fsdp"), "out": ("tensor", "fsdp"),
    "out_proj": ("tensor", "fsdp"),
    # embedding [V, d]
    "embed": ("tensor", "fsdp"),
    # experts
    "w_in": ("expert", "fsdp", None), "w_gate": ("expert", "fsdp", None),
    "w_out": ("expert", None, "fsdp"),
    # biases / vectors
    "bq": ("tensor",), "bk": ("tensor",), "bv": ("tensor",),
    "conv_w": (None, "tensor"), "conv_b": ("tensor",),
    "A_log": ("tensor",), "D": ("tensor",), "dt_bias": ("tensor",),
    "lam": ("tensor",), "ga_b": ("tensor",), "gi_b": ("tensor",),
    "ga_w": ("tensor", None), "gi_w": ("tensor", None),
    "norm": ("tensor",),
    # norms (replicated)
    "ln1": (None,), "ln2": (None,), "lnx": (None,), "final_ln": (None,),
    "ba": ("tensor",), "bi": ("tensor",),
    "wa": ("tensor", None),
}

_LOGICAL = {
    "fsdp": ("data",),
    "tensor": ("model",),
    "expert": ("model",),
    "dp": ("pod", "data"),
}


def _fit(dim: int, axes: Optional[Tuple[str, ...]], mesh: Mesh):
    """Return axes (possibly trimmed) only if their product divides dim."""
    if axes is None:
        return None
    names = [a for a in axes if a in mesh.axis_names]
    while names:
        prod = math.prod(mesh.shape[a] for a in names)
        if dim % prod == 0:
            return tuple(names) if len(names) > 1 else names[0]
        names = names[:-1]
    return None


def _resolve(logical: Optional[str], mesh: Mesh):
    if logical is None:
        return None
    return _LOGICAL.get(logical, (logical,))


def param_spec(path, leaf, mesh: Mesh) -> P:
    keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    key = keys[-1] if keys else None
    rule = _PARAM_RULES.get(key)
    if rule is None:
        return P()
    # ZeRO-1 across pods: optimizer moments (under opt/m, opt/v) addition-
    # ally shard their fsdp dim over `pod` — parameters stay pod-replicated
    # (cheap to read every step), moments are touched once per step so the
    # cross-pod gather/scatter is amortizable.  Needed for 400B-class
    # models whose f32 moments alone exceed a pod's HBM.
    zero1 = any(k in ("m", "v") for k in keys[:-1]) or key in ("m", "v")
    fsdp_axes = ("pod", "data") if zero1 else ("data",)
    ndim = getattr(leaf, "ndim", len(leaf.shape))
    shape = leaf.shape
    pad = ndim - len(rule)
    entries = [None] * pad
    for i, logical in enumerate(rule):
        axes = _resolve(logical, mesh)
        if logical == "fsdp":
            axes = fsdp_axes
        entries.append(_fit(shape[pad + i], axes, mesh))
    return P(*entries)


def param_sharding_tree(params, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, param_spec(path, leaf, mesh))
         for path, leaf in flat])


# ---------------------------------------------------------------------------
# Batch / cache shardings per shape kind
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def kv_seq_axes(mesh: Mesh, shape: ShapeConfig) -> Optional[Tuple[str, ...]]:
    if shape.name == "long_500k":
        # batch=1: spread the 500k cache over every axis available
        return tuple(mesh.axis_names)
    return ("model",)


def batch_spec(mesh: Mesh, ndim: int, *, batch_divisible=True) -> P:
    ax = batch_axes(mesh)
    first = ax if batch_divisible else None
    return P(first, *([None] * (ndim - 1)))


def data_sharding_tree(batch, mesh: Mesh, global_batch: int):
    ax = batch_axes(mesh)
    n = math.prod(mesh.shape[a] for a in ax)
    ok = global_batch % n == 0 and global_batch >= n

    def spec(leaf):
        nd = getattr(leaf, "ndim", len(leaf.shape))
        return NamedSharding(mesh, batch_spec(mesh, nd, batch_divisible=ok))

    return jax.tree_util.tree_map(spec, batch)


def cache_spec(path, leaf, mesh: Mesh, cfg: ModelConfig,
               shape: ShapeConfig) -> P:
    """Sharding for KV / state caches (decode cells)."""
    key = None
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            key = p.key
            break
    nd = getattr(leaf, "ndim", len(leaf.shape))
    bax = batch_axes(mesh)
    nb = math.prod(mesh.shape[a] for a in bax)
    b_ok = shape.global_batch % nb == 0 and shape.global_batch >= nb
    b_entry = bax if b_ok else None
    stacked = nd >= 1 and any(
        isinstance(p, jax.tree_util.DictKey) and p.key == "periods"
        for p in path)
    pad = (None,) if stacked else ()

    kvax = kv_seq_axes(mesh, shape)

    if key in ("k", "v"):  # [B, S, hkv, hd]
        s_dim = leaf.shape[-3]
        return P(*pad, b_entry, _fit(s_dim, kvax, mesh), None, None)
    if key == "kpos":  # [B, S]
        s_dim = leaf.shape[-1]
        return P(*pad, b_entry, _fit(s_dim, kvax, mesh))
    if key in ("enc_k", "enc_v"):  # [B, Se, hkv, hd]
        return P(*pad, b_entry, None, None, None)
    if key == "state":  # [B, nh, hd, ds]
        return P(*pad, b_entry, _fit(leaf.shape[-3], ("model",), mesh),
                 None, None)
    if key == "conv":  # [B, cw-1, C]
        return P(*pad, b_entry, None,
                 _fit(leaf.shape[-1], ("model",), mesh))
    if key == "h":  # [B, w]
        return P(*pad, b_entry, _fit(leaf.shape[-1], ("model",), mesh))
    if key == "pos":
        return P()
    return P()


def cache_sharding_tree(cache, mesh: Mesh, cfg: ModelConfig,
                        shape: ShapeConfig):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, cache_spec(path, leaf, mesh, cfg, shape))
         for path, leaf in flat])
