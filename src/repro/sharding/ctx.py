"""Logical-axis sharding context (MaxText-style logical axis rules).

Model code annotates activations with *logical* axes (``shard_hint(x,
"batch", "seq", "embed")``); the launcher installs a mesh plus a
logical->mesh translation table.  On a bare CPU run (unit tests, smoke
tests) no mesh is installed and hints are no-ops, so models stay mesh
agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Default logical->mesh translation. "dp" axes join pod+data for batch;
# "fsdp" = data; "tensor" = model.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "seq": None,
    "kv_seq": None,
    "stage": None,
}


def set_mesh(mesh: Mesh, rules: Optional[dict] = None):
    _state.mesh = mesh
    base = dict(DEFAULT_RULES)
    if rules:
        base.update(rules)
    # Drop rules referencing axes the mesh doesn't have (e.g. single-pod).
    names = set(mesh.axis_names)

    def _filter(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        vv = tuple(a for a in v if a in names)
        return vv if vv else None

    _state.rules = {k: _filter(v) for k, v in base.items()}


def clear_mesh():
    _state.mesh = None
    _state.rules = None


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def axis_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def logical_to_spec(logical: Tuple[Optional[str], ...]) -> P:
    """Translate logical axes to a PartitionSpec, dropping duplicate mesh
    axes (first occurrence wins) — a spec may not reuse a mesh axis."""
    rules = axis_rules() or {}
    used = set()
    entries = []
    for ax in logical:
        v = rules.get(ax) if ax else None
        axes = (v,) if isinstance(v, str) else tuple(v or ())
        if any(a in used for a in axes):
            v = None
            axes = ()
        used.update(axes)
        entries.append(v)
    return P(*entries)


def shard_hint(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
