from repro.sharding.ctx import (
    axis_rules, clear_mesh, current_mesh, set_mesh, shard_hint)

__all__ = ["axis_rules", "clear_mesh", "current_mesh", "set_mesh",
           "shard_hint"]
