"""Retry policy (exponential backoff + jitter) and retry budget.

The policy decides *how long* to wait between attempts; the budget
decides *whether* a retry may run at all.  The budget is a token bucket
shared by an engine: under a fault storm it drains and further failures
fail fast as :class:`~repro.resilience.errors.TransientExecutorError`
instead of amplifying load with synchronized retries.  Both are
deterministic given their seed.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro import obs
from repro.resilience.errors import POISON, TRANSIENT, classify


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with decorrelating jitter.

    ``max_attempts`` counts *executions* (first try included): 3 means
    one try plus up to two retries.
    """

    max_attempts: int = 3
    base_ms: float = 1.0
    max_ms: float = 50.0
    multiplier: float = 2.0
    jitter: float = 0.5      # fraction of the backoff randomized away

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        """Sleep before attempt ``attempt`` (attempt 2 = first retry)."""
        raw = self.base_ms * self.multiplier ** max(attempt - 2, 0)
        raw = min(raw, self.max_ms)
        if self.jitter > 0.0:
            raw *= 1.0 - self.jitter * float(rng.random())
        return raw / 1e3

    def allows(self, attempt: int) -> bool:
        return attempt <= self.max_attempts


class RetryBudget:
    """Token bucket bounding total retries an engine may run.

    Starts full at ``capacity``; each retry spends one token; tokens
    refill at ``refill_per_s``.  An exhausted budget makes ``spend()``
    return False — the caller fails fast instead of retrying.
    """

    def __init__(self, capacity: int = 64, refill_per_s: float = 8.0):
        self.capacity = float(capacity)
        self.refill_per_s = float(refill_per_s)
        self._tokens = float(capacity)
        self._t_last = time.monotonic()
        self._lock = threading.Lock()

    def _refill(self) -> None:
        now = time.monotonic()
        self._tokens = min(
            self.capacity,
            self._tokens + (now - self._t_last) * self.refill_per_s)
        self._t_last = now

    def spend(self, n: int = 1) -> bool:
        with self._lock:
            self._refill()
            if self._tokens < n:
                return False
            self._tokens -= n
            return True

    def remaining(self) -> float:
        with self._lock:
            self._refill()
            return self._tokens


def call_with_retry(fn: Callable, *, policy: Optional[RetryPolicy] = None,
                    budget: Optional[RetryBudget] = None,
                    rng: Optional[np.random.Generator] = None,
                    site: str = "call",
                    sleep: Callable[[float], None] = time.sleep):
    """Run ``fn()`` with classified retries.

    Poison and fatal errors propagate immediately; transient errors are
    retried (with backoff) while the policy and budget allow.  Every
    retry bumps ``resilience_retries_total{site,kind}``.
    """
    policy = policy or RetryPolicy()
    rng = rng if rng is not None else np.random.default_rng(0)
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn()
        except BaseException as exc:  # noqa: BLE001 — classified below
            kind = classify(exc)
            if kind != TRANSIENT or not policy.allows(attempt + 1):
                raise
            if budget is not None and not budget.spend():
                raise
            obs.counter("resilience_retries_total",
                        site=site, kind=kind).inc()
            sleep(policy.backoff_s(attempt + 1, rng))


__all__ = ["POISON", "RetryBudget", "RetryPolicy", "TRANSIENT",
           "call_with_retry"]
