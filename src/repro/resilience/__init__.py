"""repro.resilience — fault injection, retry, shedding, recovery.

The layer that turns a fast demo into a system that stays up:

* :mod:`repro.resilience.chaos` — deterministic, seed-driven
  :class:`FaultPlan` injected at named sites across the executor, both
  serving engines, the train loop, the checkpointer, and the DeltaGraph
  repack thread.  Zero overhead when disarmed.
* :mod:`repro.resilience.errors` — the structured error taxonomy
  (poison vs transient vs shed vs deadline vs closed) every engine
  speaks, plus :func:`classify` for the retry decision.
* :mod:`repro.resilience.retry` — exponential backoff with jitter and
  a token-bucket :class:`RetryBudget` so fault storms fail fast instead
  of amplifying load.
* :mod:`repro.resilience.supervisor` — bounded worker-thread restarts
  for the serving loops.

Recovery actions are visible in ``obs.snapshot()`` via
``resilience_retries_total{site,kind}``, ``resilience_shed_total``,
``resilience_quarantined_total{kind}``, ``resilience_degraded_total``,
``resilience_worker_restarts_total{worker}`` and
``resilience_recoveries_total{site}``; injected faults count in
``chaos_faults_total{site,kind}``.
"""
from repro.resilience import chaos
from repro.resilience.chaos import (FaultPlan, FaultSpec,
                                    ProcessKillRequested,
                                    WorkerHangRequested, WorkerKilled)
from repro.resilience.errors import (DeadlineExceededError,
                                     EngineClosedError, NaNOutputError,
                                     PoisonRequestError, RequestShedError,
                                     ResilienceError,
                                     TransientExecutorError, WorkerLostError,
                                     classify)
from repro.resilience.retry import RetryBudget, RetryPolicy, call_with_retry
from repro.resilience.supervisor import WorkerSupervisor

__all__ = [
    "DeadlineExceededError", "EngineClosedError", "FaultPlan", "FaultSpec",
    "NaNOutputError", "PoisonRequestError", "ProcessKillRequested",
    "RequestShedError", "ResilienceError", "RetryBudget", "RetryPolicy",
    "TransientExecutorError", "WorkerHangRequested", "WorkerKilled",
    "WorkerLostError", "WorkerSupervisor", "call_with_retry", "chaos",
    "classify",
]
