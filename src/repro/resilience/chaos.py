"""Deterministic fault injection for the serve + train paths.

A :class:`FaultPlan` is a seed-driven schedule of faults at named
**sites** — fixed hook points threaded through the executor, the
serving engines, the train loop, the checkpointer, and the DeltaGraph
repack thread:

========================  ====================================================
site                      where it fires
========================  ====================================================
``executor.compile``      inside the traced executor body (= compile time)
``executor.execute``      before a bucketed-executor group execution
``executor.output``       on a group's output array (``corrupt`` site)
``serve.worker``          top of ``BatchServingEngine._serve_loop``
``serve.flush``           before a micro-batch flush (ctx carries ``tags``)
``continuous.worker``     top of ``ContinuousBatchEngine._step_loop``
``continuous.execute``    before a lane-step execution (ctx carries ``tags``)
``continuous.output``     on a lane-step output array (``corrupt`` site)
``train.step``            before each training step (ctx carries ``step``)
``checkpoint.write``      between the temp-dir write and the atomic rename
``delta.repack``          inside the background repack build
``fleet.worker``          fleet dispatch / monitor tick (ctx: ``worker``,
                          ``phase``) — ``kill_proc``/``hang`` act here
``fleet.heartbeat``       parent-side heartbeat intake (ctx: ``worker``)
``fleet.rpc``             fleet send/recv boundary (ctx: ``worker``,
                          ``phase``)
========================  ====================================================

Faults trigger on exact hit counts (``at``/``times``) or with a
seed-driven probability (``p``) — either way the schedule is a pure
function of the plan's seed and the sequence of hook calls, so a chaos
run replays bit-identically.  A ``match`` dict restricts a fault to
hook calls whose context carries a value (e.g. a poison request's tag),
which is how tests mark one request of a co-batched lane as the culprit.

When no plan is installed every hook is a cheap module-global ``None``
check — zero overhead on the production path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.resilience.errors import (PoisonRequestError,
                                     TransientExecutorError)

#: fault kinds a spec can carry
RAISE = "raise"      # raise TransientExecutorError (or the payload exc)
POISON = "poison"    # raise PoisonRequestError
DELAY = "delay"      # sleep payload seconds (latency spike)
DIE = "die"          # raise WorkerKilled — kills a worker thread
NAN = "nan"          # corrupt an output array with NaN (corrupt sites)
KILL_PROC = "kill_proc"  # raise ProcessKillRequested — the fleet layer
#                          catches it and SIGKILLs the worker process
HANG = "hang"        # raise WorkerHangRequested — the fleet layer catches
#                      it and freezes the worker's loop (heartbeats stop)

KINDS = (RAISE, POISON, DELAY, DIE, NAN, KILL_PROC, HANG)


class WorkerKilled(TransientExecutorError):
    """Injected worker-thread death (``kind="die"``)."""


class ProcessKillRequested(Exception):
    """Control signal of ``kind="kill_proc"``: the hook site (a
    ``fleet.*`` site) must hard-kill the worker process it names.  Not
    an error surface — only the fleet layer catches it."""


class WorkerHangRequested(Exception):
    """Control signal of ``kind="hang"``: the hook site must wedge the
    worker's loop (payload = seconds, ``None`` = until killed), so its
    heartbeats stop and the fleet's missed-heartbeat detection fires."""

    def __init__(self, msg: str, payload: Any = None):
        super().__init__(msg)
        self.payload = payload


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.

    ``at`` is the 1-based hit count of the site at which the fault
    starts firing; it fires for ``times`` consecutive matching hits
    (``None`` = forever).  ``p`` (0..1) makes it probabilistic instead,
    drawn from the plan's seeded rng.  ``match`` filters on the hook's
    context: each key must equal the context value, or be contained in
    it when the context value is a sequence (how a poison *tag* matches
    a lane whose occupant list carries it).
    """

    site: str
    kind: str = RAISE
    at: int = 1
    times: Optional[int] = 1
    p: float = 0.0
    payload: Any = None
    match: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")

    def matches_ctx(self, ctx: Dict[str, Any]) -> bool:
        if not self.match:
            return True
        for k, want in self.match.items():
            got = ctx.get(k)
            if got == want:
                continue
            if isinstance(got, (list, tuple, set, frozenset)) and want in got:
                continue
            return False
        return True


class FaultPlan:
    """A deterministic, seed-driven schedule of injected faults."""

    def __init__(self, faults: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.seed = seed
        self.faults = list(faults)
        self.rng = np.random.default_rng(seed)
        self.events: List[Tuple[str, str, int]] = []  # (site, kind, hit)
        self._hits: Dict[int, int] = {}  # per-spec matching-hit counters
        self._lock = threading.Lock()

    # -- scheduling ---------------------------------------------------------

    def _armed(self, site: str, ctx: Dict[str, Any]) -> List[FaultSpec]:
        """The specs firing on this hook call (advances hit counters)."""
        out = []
        with self._lock:
            for idx, spec in enumerate(self.faults):
                if spec.site != site or not spec.matches_ctx(ctx):
                    continue
                hit = self._hits.get(idx, 0) + 1
                self._hits[idx] = hit
                if spec.p > 0.0:
                    fire = bool(self.rng.random() < spec.p)
                else:
                    fire = hit >= spec.at and (
                        spec.times is None or hit < spec.at + spec.times)
                if fire:
                    self.events.append((site, spec.kind, hit))
                    obs.counter("chaos_faults_total",
                                site=site, kind=spec.kind).inc()
                    out.append(spec)
        return out

    # -- firing -------------------------------------------------------------

    @staticmethod
    def _act(site: str, spec: FaultSpec) -> None:
        if spec.kind == DELAY:
            time.sleep(float(spec.payload) if spec.payload else 0.05)
        elif spec.kind == DIE:
            raise WorkerKilled(f"chaos: worker killed at {site}")
        elif spec.kind == KILL_PROC:
            raise ProcessKillRequested(f"chaos: kill_proc at {site}")
        elif spec.kind == HANG:
            raise WorkerHangRequested(f"chaos: hang at {site}",
                                      payload=spec.payload)
        elif spec.kind == POISON:
            raise PoisonRequestError(f"chaos: poison at {site}")
        elif spec.kind == RAISE:
            if isinstance(spec.payload, BaseException):
                raise spec.payload
            raise TransientExecutorError(f"chaos: fault at {site}")
        # NAN specs only act at corrupt() sites

    def fire(self, site: str, ctx: Dict[str, Any]) -> None:
        """Run this hook call's scheduled faults (may raise / sleep)."""
        for spec in self._armed(site, ctx):
            self._act(site, spec)

    def corrupt_value(self, site: str, value, ctx: Dict[str, Any]):
        """Apply NaN-corruption faults scheduled at this site (other
        kinds also work here — a corrupt site is a hook site too)."""
        for spec in self._armed(site, ctx):
            if spec.kind != NAN:
                self._act(site, spec)
                continue
            idx = spec.payload if spec.payload is not None else (0, 0)
            if idx == "all":
                value = value * np.nan
            else:
                try:
                    value = value.at[tuple(idx)].set(np.nan)
                except AttributeError:  # plain numpy
                    value = np.array(value, copy=True)
                    value[tuple(idx)] = np.nan
        return value


# ---------------------------------------------------------------------------
# Global arm/disarm (the hooks below are the only production touch points)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> None:
    """Arm a plan process-wide (one at a time)."""
    global _ACTIVE
    _ACTIVE = plan


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def active(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Arm ``plan`` for the duration of the block."""
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


def hook(site: str, **ctx) -> None:
    """Fault-injection point: no-op (one ``None`` check) when disarmed."""
    plan = _ACTIVE
    if plan is not None:
        plan.fire(site, ctx)


def corrupt(site: str, value, **ctx):
    """Output-corruption point: returns ``value`` unchanged when
    disarmed, else with any scheduled NaN faults applied."""
    plan = _ACTIVE
    if plan is not None:
        return plan.corrupt_value(site, value, ctx)
    return value


__all__ = [
    "DELAY", "DIE", "FaultPlan", "FaultSpec", "HANG", "KILL_PROC", "KINDS",
    "NAN", "POISON", "ProcessKillRequested", "RAISE", "WorkerHangRequested",
    "WorkerKilled", "active", "active_plan", "corrupt", "hook",
    "install", "uninstall",
]
