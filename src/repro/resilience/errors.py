"""Structured error taxonomy for the serving and training paths.

Every failure an engine can surface is one of these classes, so callers
(and the retry machinery) can tell *what kind* of failure happened and
therefore what to do about it:

* :class:`PoisonRequestError` — the request itself is the cause
  (malformed structure, non-finite output).  Retrying it anywhere would
  fail again; the request is quarantined and its co-batched neighbors
  are re-admitted.
* :class:`TransientExecutorError` — the infrastructure hiccuped (an
  executor exception, a latency blip, a dead thread).  The request is
  innocent; it is retried with backoff up to its retry budget.
* :class:`RequestShedError` — load shedding dropped the request before
  execution (queue over capacity, deadline already hopeless).
* :class:`DeadlineExceededError` — the request's deadline (or an
  ``infer(timeout=...)``) expired.  Subclasses :class:`TimeoutError` so
  plain ``except TimeoutError`` works.
* :class:`EngineClosedError` — the engine shut down; subclasses
  :class:`RuntimeError` for compatibility with pre-taxonomy callers.

``classify()`` maps an arbitrary exception onto the retry decision.
"""
from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class of every structured serving/training failure."""


class PoisonRequestError(ResilienceError):
    """The request itself is the deterministic cause of the failure.

    Not retryable: the request is quarantined (its future fails with
    this error) and any innocent co-batched requests are re-admitted.
    """


class NaNOutputError(PoisonRequestError):
    """The request's output contained NaN/Inf; the result is withheld
    (quarantined) instead of returned as garbage."""


class TransientExecutorError(ResilienceError):
    """Infrastructure failure independent of any one request; the work
    is retryable (with backoff, up to the retry budget)."""


class WorkerLostError(TransientExecutorError):
    """A fleet worker died with this request in flight and no survivor
    (or restart) could take it over within the failover budget.  The
    request itself is innocent — resubmitting it is safe."""


class RequestShedError(ResilienceError):
    """Load shedding dropped this request before execution."""


class DeadlineExceededError(TimeoutError, ResilienceError):
    """The request's deadline (or an ``infer`` timeout) expired."""


class EngineClosedError(ResilienceError):
    """The engine was closed; the request cannot be (or was not) run."""


#: classification tags returned by :func:`classify`
POISON = "poison"
TRANSIENT = "transient"
FATAL = "fatal"  # do not retry, do not blame the request (closed, ...)


def classify(exc: BaseException) -> str:
    """Retry decision for an exception raised during request execution.

    Unknown exceptions classify as *transient*: an executor blowing up
    under a co-batched workload is an infrastructure event until
    bisection pins it on a single request (which re-raises it wrapped
    in :class:`PoisonRequestError`).
    """
    if isinstance(exc, PoisonRequestError):
        return POISON
    if isinstance(exc, (EngineClosedError, DeadlineExceededError,
                        RequestShedError)):
        return FATAL
    if isinstance(exc, (KeyboardInterrupt, SystemExit)):
        return FATAL
    if isinstance(exc, (ValueError, TypeError)):
        # malformed request data (shape mismatch, bad dtype, ...) is
        # deterministic — retrying would fail identically, so the
        # request is quarantined with its original exception
        return POISON
    return TRANSIENT


__all__ = [
    "DeadlineExceededError", "EngineClosedError", "FATAL", "NaNOutputError",
    "POISON", "PoisonRequestError", "RequestShedError", "ResilienceError",
    "TRANSIENT", "TransientExecutorError", "WorkerLostError", "classify",
]
