"""Worker-thread supervision: detect dead loops, restart them bounded.

Both serving engines run their work off a single daemon thread (the
micro-batching ``_serve_loop``, the continuous ``_step_loop``).  Before
this layer, any exception escaping that loop left a silently dead
engine: the queue kept accepting work that nothing would ever run.

:class:`WorkerSupervisor` wraps the thread: ``ensure()`` (called from
the engine's submit/drain paths — the places a dead worker actually
hurts) restarts a dead loop up to ``max_restarts`` times, counting each
restart in ``resilience_worker_restarts_total{worker}`` and
``resilience_recoveries_total{site="worker"}``.  Past the budget the
engine falls back to its fail-the-backlog behavior.

Concurrency contract: the observe-dead → charge-budget → respawn
sequence is atomic under one lock, so two threads hitting ``ensure()``
on the same dead worker can never double-restart or double-charge the
budget (the second observer sees the already-respawned thread and
returns).  Each spawn bumps ``generation``; a caller that observed a
death *before* taking the lock can pass its observed generation and
becomes a no-op if another thread already handled that death — the
guard the fleet's process-level supervisor relies on, where a respawn
is seconds long and must happen outside the lock
(:class:`repro.serve.fleet.FleetSupervisor`).
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro import obs


class WorkerSupervisor:
    """Restartable daemon thread with a bounded restart budget."""

    def __init__(self, name: str, target: Callable[[], None], *,
                 max_restarts: int = 3):
        self.name = name
        self.target = target
        self.max_restarts = int(max_restarts)
        self.restarts = 0
        self.generation = 0
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def _spawn(self) -> None:
        self.generation += 1
        self._thread = threading.Thread(
            target=self.target, name=f"{self.name}-g{self.generation}",
            daemon=True)
        self._thread.start()

    def start(self) -> None:
        with self._lock:
            if self._thread is None:
                self._spawn()

    def alive(self) -> bool:
        with self._lock:
            t = self._thread
        return t is not None and t.is_alive()

    def ensure(self, observed_generation: Optional[int] = None) -> bool:
        """Restart the worker if it died.  Returns True while a live
        worker exists (possibly just restarted); False once the restart
        budget is exhausted and the loop is dead.

        ``observed_generation`` makes a deferred death report safe: a
        caller that saw generation *g* dead, then raced another caller
        to this lock, only restarts if the generation is still *g* —
        otherwise the death was already handled (possibly by a restart
        that has itself since died, which the next plain ``ensure()``
        will observe against the *new* generation).
        """
        with self._lock:
            if self._thread is None:
                return False  # never started (foreground mode)
            if observed_generation is not None \
                    and observed_generation != self.generation:
                return self._thread.is_alive() \
                    or self.restarts < self.max_restarts
            if self._thread.is_alive():
                return True
            if self.restarts >= self.max_restarts:
                return False
            self.restarts += 1
            obs.counter("resilience_worker_restarts_total",
                        worker=self.name).inc()
            obs.counter("resilience_recoveries_total", site="worker").inc()
            self._spawn()
            return True

    def join(self, timeout: Optional[float] = None) -> None:
        with self._lock:
            t = self._thread
        if t is not None:
            t.join(timeout=timeout)


__all__ = ["WorkerSupervisor"]
