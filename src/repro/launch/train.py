"""Training launcher:  python -m repro.launch.train --arch <id> [...]

On this CPU container it runs the reduced (smoke) config by default; on a
real TPU slice the same entry point takes --full and the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, lm_data_iter
from repro.ft.checkpoint import Checkpointer
from repro.ft.health import StragglerDetector
from repro.models.transformer import init_lm
from repro.sharding import ctx as shard_ctx
from repro.sharding.specs import param_sharding_tree
from repro.train.loop import (TrainConfig, init_train_state, make_train_step,
                              train_loop)
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--full", action="store_true",
                    help="use the full card config (TPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=("none", "int8", "topk_ef"))
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else dataclasses.replace(
        get_smoke_config(args.arch), dtype="float32")
    base = SHAPES[args.shape]
    shape = ShapeConfig("train",
                        args.seq or (base.seq_len if args.full else 128),
                        args.batch or (base.global_batch if args.full
                                       else 8), "train")
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps),
        microbatches=args.microbatches, compression=args.compression)

    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and args.resume and ck.latest_step() is not None:
        restored = ck.restore({"params": params, "state": state})
        params, state = restored["params"], restored["state"]
        start = ck.latest_step()
        print(f"resumed from step {start}")

    n_dev = len(jax.devices())
    if n_dev > 1:
        from repro.sharding.specs import make_mesh
        mesh = make_mesh((n_dev // 2, 2), ("data", "model"))
        shard_ctx.set_mesh(mesh)
        sh = param_sharding_tree(params, mesh)
        params = jax.device_put(params, sh)
        state = jax.device_put(state, param_sharding_tree(state, mesh))

    step = make_train_step(cfg, tcfg)
    data = lm_data_iter(cfg, shape, DataConfig(seed=0), start_step=start)
    det = StragglerDetector()

    def cb(i, params, state, metrics):
        if i % 10 == 0:
            print(f"step {start + i:5d}  loss {float(metrics['loss']):.4f}"
                  f"  lr {float(metrics['lr']):.2e}")

    out = train_loop(params, state, step, data, args.steps,
                     checkpointer=ck, ckpt_every=args.ckpt_every,
                     health=det, callback=cb)
    if ck:
        ck.wait()
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}; "
          f"median step {det.median:.3f}s; stragglers {det.flags}")


if __name__ == "__main__":
    main()
