import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-touching import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lower + compile the cell's step function (train_step / prefill_step /
     serve_step) against ShapeDtypeStruct inputs with explicit
     in/out_shardings — the production scan-over-layers form; print
     memory_analysis() (proves it fits) and cost_analysis(),
  3. recompile 1-period and 2-period model variants with every loop
     unrolled (repro.runtime.cost_mode) and extrapolate exact per-device
     FLOPs / bytes / collective bytes (XLA cost analysis counts loop
     bodies once — see launch/roofline.py),
  4. write a JSON artifact to experiments/dryrun/ for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod
"""
import argparse
import dataclasses
import gc
import json
import math
import time
import traceback

import jax

from repro import runtime
from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import costs_of, extrapolate, terms_from
from repro.serve.engine import make_prefill_step, make_serve_step
from repro.sharding import ctx as shard_ctx
from repro.sharding.specs import (batch_axes, cache_sharding_tree,
                                  data_sharding_tree, param_sharding_tree)
from repro.train.loop import TrainConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "experiments", "dryrun")


def cell_is_skipped(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return ("skipped: pure full-attention arch; long_500k requires "
                "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return ""


def shape_rules(mesh, shape, *, seq_parallel: bool = True):
    """Logical-axis rule overrides per shape (activation sharding)."""
    rules = {}
    nb = math.prod(mesh.shape[a] for a in batch_axes(mesh))
    if shape.global_batch % nb != 0 or shape.global_batch < nb:
        rules["batch"] = None
    if shape.name == "long_500k":
        rules["kv_seq"] = tuple(mesh.axis_names)
    if seq_parallel and shape.kind in ("train", "prefill") \
            and shape.seq_len % mesh.shape["model"] == 0:
        # Megatron-style sequence parallelism: the residual stream (and the
        # activations the backward pass saves) is sharded over `model`
        # between blocks; XLA inserts the all-gather/reduce-scatter pair
        # around attention/MLP.  Without this the per-device saved
        # activations of a 4k x 256 batch do not fit HBM.
        rules["seq"] = "model"
    return rules


def compile_cell(cfg, shape, mesh, tcfg: TrainConfig):
    """Lower + compile one cell on `mesh`; returns the compiled executable."""
    specs = input_specs(cfg, shape, tcfg)
    shard_ctx.set_mesh(mesh, shape_rules(mesh, shape))
    try:
        if shape.kind == "train":
            step = make_train_step(cfg, tcfg)
            params, state, batch = (specs["params"], specs["state"],
                                    specs["batch"])
            p_sh = param_sharding_tree(params, mesh)
            s_sh = param_sharding_tree(state, mesh)  # m/v keys mirror params
            b_sh = data_sharding_tree(batch, mesh, shape.global_batch)
            fn = jax.jit(step, in_shardings=(p_sh, s_sh, b_sh),
                         out_shardings=(p_sh, s_sh, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, max_len=shape.seq_len)
            params, batch = specs["params"], specs["batch"]
            batch.pop("targets", None)
            batch.pop("mask", None)
            p_sh = param_sharding_tree(params, mesh)
            b_sh = data_sharding_tree(batch, mesh, shape.global_batch)
            from repro.launch.inputs import abstract_cache
            c_sh = cache_sharding_tree(abstract_cache(cfg, shape), mesh,
                                       cfg, shape)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(None, c_sh))
            lowered = fn.lower(params, batch)
        else:  # decode
            step = make_serve_step(cfg)
            params, token, cache = (specs["params"], specs["token"],
                                    specs["cache"])
            p_sh = param_sharding_tree(params, mesh)
            t_sh = data_sharding_tree(token, mesh, shape.global_batch)
            c_sh = cache_sharding_tree(cache, mesh, cfg, shape)
            fn = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
            lowered = fn.lower(params, token, cache)
        return lowered.compile()
    finally:
        shard_ctx.clear_mesh()


def _cost_variant(cfg, n_periods: int):
    r = len(cfg.remainder_kinds)
    return dataclasses.replace(cfg,
                               n_layers=n_periods * cfg.period + r)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               tcfg: TrainConfig = None, verbose: bool = True,
               causal_skip=None, skip_costs: bool = False,
               cfg_overrides: dict = None, tcfg_overrides: dict = None):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = cell_is_skipped(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    if tcfg is None:
        # Memory compile runs the production microbatched (grad-accum)
        # step so saved activations fit HBM; cost compiles use
        # microbatches=1 (totals are microbatch-invariant, and XLA counts
        # loop bodies once — see roofline.py).
        micro = 8 if shape.kind == "train" and shape.global_batch % 8 == 0 \
            else 1
        tcfg = TrainConfig(microbatches=micro, **(tcfg_overrides or {}))
    if causal_skip is None:
        # production prefill skips masked blocks (forward-only); train uses
        # the masked scan -> cost model matches each path's real FLOPs
        causal_skip = shape.kind == "prefill"

    t0 = time.time()
    compiled = compile_cell(cfg, shape, mesh, tcfg)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()
    raw = costs_of(compiled)
    del compiled
    gc.collect()

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "compile_s": round(t_full, 1),
        "causal_skip": bool(causal_skip),
        "memory": {
            "argument_bytes_per_device": getattr(
                mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(
                mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(
                mem, "alias_size_in_bytes", None),
        },
        "raw_scan_costs": raw,  # loop bodies counted once — NOT roofline
    }

    if not skip_costs:
        # Unrolled-attention block count dominates SPMD-partitioner time on
        # this 1-core container: cap the cost-model chunk count at ~8 per
        # layer (FLOP totals are chunk-size invariant; causal-skip
        # granularity coarsens accordingly — noted in EXPERIMENTS.md).
        attn_chunk = max(2048, shape.seq_len // 8) \
            if shape.kind != "decode" else None
        cost_tcfg = dataclasses.replace(tcfg, microbatches=1)
        with runtime.cost_mode(causal_skip=causal_skip,
                               attn_chunk=attn_chunk):
            c1 = costs_of(compile_cell(_cost_variant(cfg, 1), shape, mesh,
                                       cost_tcfg))
            gc.collect()
            c2 = costs_of(compile_cell(_cost_variant(cfg, 2), shape, mesh,
                                       cost_tcfg))
            gc.collect()
        costs = extrapolate(c1, c2, cfg.n_periods)
        terms = terms_from(costs, cfg, shape, chips)
        result["roofline"] = terms.summary()
        result["cost_1p"] = c1
        result["cost_2p"] = c2
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] "
                  f"full-compile {t_full:.0f}s")
            print("  memory_analysis:", mem)
            print("  terms: compute=%.4fs memory=%.4fs collective=%.4fs "
                  "-> %s (roofline frac %.3f)"
                  % (terms.t_compute, terms.t_memory, terms.t_collective,
                     terms.bottleneck, terms.roofline_fraction))
    elif verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled "
              f"{t_full:.0f}s; memory:", mem)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (LM archs only)")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-costs", action="store_true",
                    help="memory/compile check only (no cost variants)")
    ap.add_argument("--causal-skip", default=None,
                    choices=(None, "on", "off"),
                    help="override static causal block skipping in the "
                         "cost model")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--cast-params", action="store_true",
                    help="§Perf: cast f32 params to bf16 once per step")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=("nothing", "dots"))
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="§Perf: override mamba2 SSD chunk length")
    ap.add_argument("--ssm-bf16", action="store_true",
                    help="§Perf: bf16 intra-chunk SSD quadratic")
    args = ap.parse_args()
    cfg_overrides = {}
    if args.ssm_chunk:
        cfg_overrides["ssm_chunk"] = args.ssm_chunk
    if args.ssm_bf16:
        cfg_overrides["ssm_bf16_intra"] = True
    tcfg_overrides = {}
    if args.cast_params:
        tcfg_overrides["cast_params_once"] = True
    if args.remat_policy != "nothing":
        tcfg_overrides["remat_policy"] = args.remat_policy

    archs = [a for a in ARCHS if a != "paper-gnn"] \
        if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    causal_skip = None if args.causal_skip is None \
        else args.causal_skip == "on"

    failures = 0
    for arch in archs:
        for shape in shapes:
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            tag = f"{arch}_{shape}_{mesh_name}{args.tag}"
            path = os.path.join(args.out, tag + ".json")
            try:
                res = lower_cell(arch, shape, args.multi_pod,
                                 causal_skip=causal_skip,
                                 skip_costs=args.skip_costs,
                                 cfg_overrides=cfg_overrides or None,
                                 tcfg_overrides=tcfg_overrides or None)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
                failures += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            print(f"wrote {path} ({res['status']})", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells FAILED")


if __name__ == "__main__":
    main()
