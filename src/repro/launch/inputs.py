"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract inputs the corresponding
step function is lowered with:
  train_*   -> (params f32, train-state, batch{tokens,targets,mask,...})
  prefill_* -> (params bf16, batch{tokens,...})
  decode_*  -> (params bf16, token, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_cache, init_lm
from repro.train.loop import TrainConfig, init_train_state

SDS = jax.ShapeDtypeStruct


def abstract_params(cfg: ModelConfig, dtype=None):
    tree = jax.eval_shape(
        functools.partial(init_lm, cfg=cfg), jax.random.PRNGKey(0))
    if dtype is not None:
        tree = jax.tree_util.tree_map(
            lambda s: SDS(s.shape, dtype) if jnp.issubdtype(
                s.dtype, jnp.floating) else s, tree)
    return tree


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig):
    params = abstract_params(cfg)
    return params, jax.eval_shape(
        functools.partial(init_train_state, tcfg=tcfg), params)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                train: bool = True) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    n_text = s - cfg.vision_tokens
    out: Dict[str, Any] = {"tokens": SDS((b, n_text), jnp.int32)}
    if train:
        out["targets"] = SDS((b, n_text), jnp.int32)
        out["mask"] = SDS((b, n_text), jnp.float32)
    if cfg.vision_tokens:
        out["vision_embeds"] = SDS((b, cfg.vision_tokens, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.encoder_layers:
        out["enc_embeds"] = SDS((b, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    return out


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch,
                          shape.seq_len, dtype))


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple:
    token = SDS((shape.global_batch, 1), jnp.int32)
    return token, abstract_cache(cfg, shape)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                tcfg: TrainConfig = None) -> Dict[str, Any]:
    """All abstract inputs for the cell, keyed by role."""
    tcfg = tcfg or TrainConfig()
    if shape.kind == "train":
        params, state = abstract_train_state(cfg, tcfg)
        return {"params": params, "state": state,
                "batch": batch_specs(cfg, shape, train=True)}
    if shape.kind == "prefill":
        return {"params": abstract_params(cfg, jnp.bfloat16),
                "batch": batch_specs(cfg, shape, train=False)}
    token, cache = decode_specs(cfg, shape)
    return {"params": abstract_params(cfg, jnp.bfloat16),
            "token": token, "cache": cache}
