"""Serving launcher:  python -m repro.launch.serve --arch <id> [...]

Loads (or inits) a model, prefills a batch of synthetic prompts and
decodes continuations with the batched engine.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.transformer import init_lm
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else dataclasses.replace(
        get_smoke_config(args.arch), dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(
        max_len=args.prompt_len + args.new_tokens,
        temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size,
        (args.batch, args.prompt_len - cfg.vision_tokens)).astype(np.int32)
    kw = {}
    if cfg.vision_tokens:
        import jax.numpy as jnp
        kw["vision_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.encoder_layers:
        import jax.numpy as jnp
        kw["enc_embeds"] = jnp.asarray(rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    t0 = time.time()
    out = eng.generate(prompts, args.new_tokens, **kw)
    dt = time.time() - t0
    print(f"{args.batch}x{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", out[0][:16])


if __name__ == "__main__":
    main()
