"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_chip / link_bw      (~50 GB/s/link ICI)

``compiled.cost_analysis()`` reports *per-device* flops / bytes accessed
(verified: a matmul sharded 8 ways reports total/8).  Collective bytes are
not in cost_analysis, so we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum result-shape bytes of every collective
op; all-reduce is weighted 2x (ring reduce-scatter+all-gather traffic).

XLA counts while-loop bodies ONCE (verified), so scan-over-layers would
under-report every term.  The dry-run therefore extracts costs from fully
unrolled 1-period / 2-period model variants (repro.runtime.cost_mode) and
extrapolates linearly:  cost(n) = c1 + (n-1) * (c2 - c1).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OPS = "all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"
_LINE_RE = re.compile(
    rf"=\s*(?P<shapes>.+?)\s+(?P<op>{_OPS})(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-type result bytes of collectives in optimized HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _LINE_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group("shapes"))
        if m.group("start"):
            b //= 2  # async start carries (operands, results) tuple
        out[m.group("op")] = out.get(m.group("op"), 0) + b
    return out


def weighted_collective_bytes(per_op: Dict[str, float]) -> float:
    w = {"all-reduce": 2.0}
    return sum(b * w.get(op, 1.0) for op, b in per_op.items())


def costs_of(compiled) -> Dict:
    """Raw per-device cost terms of one compiled executable."""
    ca = compiled.cost_analysis() or {}
    per_op = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in per_op.items()},
    }


def extrapolate(c1: Dict, c2: Dict, n_periods: int) -> Dict:
    """cost(n) = c1 + (n-1)*(c2-c1), per term (c1/c2 = 1/2-period costs)."""
    k = n_periods - 1
    ops = set(c1["coll"]) | set(c2["coll"])
    return {
        "flops": c1["flops"] + k * (c2["flops"] - c1["flops"]),
        "bytes": c1["bytes"] + k * (c2["bytes"] - c1["bytes"]),
        "coll": {op: c1["coll"].get(op, 0.0)
                 + k * (c2["coll"].get(op, 0.0) - c1["coll"].get(op, 0.0))
                 for op in ops},
    }


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    per_op_collectives: Dict[str, float]
    chips: int
    model_flops: float  # 6·N_active·tokens (train) etc.

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / total HLO flops (catches remat/redundancy waste)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound step time: how close the cell runs
        to the machine roofline if perfectly overlapped."""
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        t = self.roofline_time
        return t_useful / t if t else 0.0

    def summary(self) -> Dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "per_op_collectives": self.per_op_collectives,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N_active per train token, 2·N_active per inference
    token (decode processes global_batch tokens per step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch


def terms_from(costs: Dict, cfg, shape, chips: int) -> RooflineTerms:
    return RooflineTerms(
        flops_per_chip=costs["flops"],
        bytes_per_chip=costs["bytes"],
        collective_bytes_per_chip=weighted_collective_bytes(costs["coll"]),
        per_op_collectives=costs["coll"],
        chips=chips,
        model_flops=model_flops_for(cfg, shape),
    )


def analyze(compiled, cfg, shape, chips: int) -> RooflineTerms:
    """Single-compile analysis (no trip-count correction) — used for quick
    looks; the dry-run uses costs_of + extrapolate instead."""
    return terms_from(costs_of(compiled), cfg, shape, chips)
