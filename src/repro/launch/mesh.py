"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

from repro.sharding.specs import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model).

    Uses the first `n` devices so a 512-placeholder-device dry-run process
    can build the single-pod mesh too."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_local_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh over however many devices exist (tests/smoke)."""
    n = len(jax.devices())
    if shape[0] * shape[1] > n:
        shape = (1, 1)
    return make_mesh(shape, axes)
