"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
Writes markdown to stdout.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES

ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), "experiments", "dryrun")


def load(dirname):
    cells = {}
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        name = os.path.basename(path)[:-5]
        with open(path) as f:
            d = json.load(f)
        key = (d.get("arch"), d.get("shape"), d.get("mesh"))
        tag = name.split(d.get("mesh") or "", 1)[-1] if d.get("mesh") else ""
        if tag:  # tagged experiment variants don't overwrite the baseline
            cells.setdefault("variants", {})[name] = d
            continue
        cells[key] = d
    return cells


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b / 2**30:.2f}"


def dryrun_table(cells, mesh):
    rows = ["| arch | shape | status | args GiB/dev | temps GiB/dev | "
            "compile s |",
            "|---|---|---|---|---|---|"]
    for arch in [a for a in ARCHS if a != "paper-gnn"]:
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None:
                rows.append(f"| {arch} | {shape} | MISSING | | | |")
                continue
            if d["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | skipped¹ | — | — | — |")
                continue
            mem = d.get("memory", {})
            rows.append(
                f"| {arch} | {shape} | {d['status']} | "
                f"{fmt_bytes(mem.get('argument_bytes_per_device'))} | "
                f"{fmt_bytes(mem.get('temp_bytes_per_device'))} | "
                f"{d.get('compile_s', '—')} |")
    return "\n".join(rows)


def roofline_table(cells, mesh="16x16"):
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
            "MODEL_FLOPS | useful frac | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in [a for a in ARCHS if a != "paper-gnn"]:
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None or d.get("status") != "ok" or "roofline" not in d:
                continue
            r = d["roofline"]
            rows.append(
                f"| {arch} | {shape} | {r['t_compute_s']:.4f} | "
                f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
                f"**{r['bottleneck']}** | {r['model_flops']:.2e} | "
                f"{r['useful_flop_fraction']:.3f} | "
                f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def collectives_table(cells, mesh="16x16"):
    rows = ["| arch | shape | all-reduce GiB | all-gather GiB | "
            "reduce-scatter GiB | all-to-all GiB | permute GiB |",
            "|---|---|---|---|---|---|---|"]
    for arch in [a for a in ARCHS if a != "paper-gnn"]:
        for shape in SHAPES:
            d = cells.get((arch, shape, mesh))
            if d is None or d.get("status") != "ok" or "roofline" not in d:
                continue
            c = d["roofline"]["per_op_collectives"]
            g = lambda k: c.get(k, 0) / 2**30  # noqa: E731
            rows.append(
                f"| {arch} | {shape} | {g('all-reduce'):.2f} | "
                f"{g('all-gather'):.2f} | {g('reduce-scatter'):.2f} | "
                f"{g('all-to-all'):.2f} | {g('collective-permute'):.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    args = ap.parse_args()
    cells = load(args.dir)
    print("### Dry-run — single-pod 16x16 (256 chips)\n")
    print(dryrun_table(cells, "16x16"))
    print("\n### Dry-run — multi-pod 2x16x16 (512 chips)\n")
    print(dryrun_table(cells, "2x16x16"))
    print("\n### Roofline — single-pod, per chip\n")
    print(roofline_table(cells))
    print("\n### Collective breakdown (bytes/chip/step)\n")
    print(collectives_table(cells))


if __name__ == "__main__":
    main()
