"""GCN / GAT on the SpMM + SDDMM substrate — the paper's driving app.

GCN layer:   H' = act( Â (H W) )           — one SpMM per layer (paper §2.1)
GAT layer:   e = SDDMM(A, B, C) with d=2   — per paper §4.4, B/C hold source
             /destination attention scores; then segment-softmax over each
             row's edges and SpMM with the attention-weighted adjacency.

The adjacency is carried in both Block-ELL (MXU path) and expanded-CSR
(element path) forms; GCN uses Block-ELL SpMM, GAT's edge-granular
softmax uses the CSR arrays (row_ids/col_ids/values).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gnn import GNNConfig
from repro.core.formats import CSR, BlockELL
from repro.core.sddmm import sddmm_coo
from repro.core.spmm import csr_to_device_arrays, spmm_csr
from repro.dispatch.dispatcher import plan_spmm, record_plan
from repro.dispatch.stats import MatrixStats
from repro.kernels.spmm.ref import spmm_blockell_ref
from repro.models.layers import _he


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Device-side graph: normalized adjacency in two sparse forms.

    ``stats`` is static aux metadata (plain Python numbers), so the
    dispatch layer can plan the SpMM path at jit trace time even though
    the adjacency arrays themselves are tracers.
    """
    ell: BlockELL
    row_ids: Any
    col_ids: Any
    values: Any
    n_nodes: int
    stats: Any = None  # Optional[MatrixStats]

    def tree_flatten(self):
        return (self.ell, self.row_ids, self.col_ids, self.values), \
            (self.n_nodes, self.stats)

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_nodes, stats = aux if isinstance(aux, tuple) else (aux, None)
        return cls(*children, n_nodes=n_nodes, stats=stats)


def build_graph(adj_dense: np.ndarray, cfg: GNNConfig,
                normalize: bool = True) -> Graph:
    """adj_dense: [N, N] 0/1.  GCN normalization Â = D^-1/2 (A+I) D^-1/2."""
    n = adj_dense.shape[0]
    a = adj_dense.astype(np.float32)
    if normalize:
        a = a + np.eye(n, dtype=np.float32)
        deg = a.sum(1)
        dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
        a = a * dinv[:, None] * dinv[None, :]
    csr = CSR.from_dense(a)
    row_ids, col_ids, values = csr_to_device_arrays(csr)
    ell = BlockELL.from_dense(a, bm=cfg.block_m, bn=cfg.block_n)
    stats = MatrixStats.from_blockell(ell, nnz=csr.nnz)
    return Graph(ell=ell, row_ids=row_ids, col_ids=col_ids, values=values,
                 n_nodes=n, stats=stats)


def graph_spmm(graph: Graph, h, *, policy: str = "auto"):
    """One message-passing step A @ H, routed by the dispatch layer.

    The Graph carries the adjacency in Block-ELL and expanded-CSR forms,
    so those are the candidate paths; the plan is made from the static
    ``graph.stats`` and is therefore jit-trace safe.
    """
    if graph.stats is None:
        raise ValueError(
            "graph_spmm: Graph has no sparsity stats; construct it with "
            "build_graph() (or attach MatrixStats) to use policy routing")
    plan = plan_spmm(graph.stats, h.shape[-1], policy=policy,
                     candidates=("ell", "csr"))
    record_plan(plan)
    if plan.path == "ell":
        return spmm_blockell_ref(graph.ell, h)[: graph.n_nodes]
    return spmm_csr(graph.row_ids, graph.col_ids, graph.values, h,
                    graph.n_nodes)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------


def init_gcn(key, cfg: GNNConfig) -> Dict:
    dims = [cfg.in_features] + [cfg.hidden] * (cfg.n_layers - 1) \
        + [cfg.n_classes]
    ks = jax.random.split(key, cfg.n_layers)
    return {"w": [_he(ks[i], (dims[i], dims[i + 1]))
                  for i in range(cfg.n_layers)]}


def gcn_forward(params, graph: Graph, x, *, use_blockell: bool = True,
                policy: str | None = None):
    """GCN forward pass.

    ``policy`` (when given) routes each layer's aggregation through the
    sparsity-adaptive dispatcher ("auto"/"ell"/"csr"); the legacy
    ``use_blockell`` flag applies otherwise.
    """
    h = x
    for i, w in enumerate(params["w"]):
        h = h @ w
        if policy is not None:
            h = graph_spmm(graph, h, policy=policy)
        elif use_blockell:
            h = spmm_blockell_ref(graph.ell, h)[: graph.n_nodes]
        else:
            h = spmm_csr(graph.row_ids, graph.col_ids, graph.values, h,
                         graph.n_nodes)
        if i < len(params["w"]) - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# GAT (single head; attention scores via SDDMM with d=2, per the paper)
# ---------------------------------------------------------------------------


def init_gat(key, cfg: GNNConfig) -> Dict:
    dims = [cfg.in_features] + [cfg.hidden] * (cfg.n_layers - 1) \
        + [cfg.n_classes]
    ks = jax.random.split(key, 3 * cfg.n_layers)
    return {
        "w": [_he(ks[3 * i], (dims[i], dims[i + 1]))
              for i in range(cfg.n_layers)],
        "a_src": [_he(ks[3 * i + 1], (dims[i + 1], 1))
                  for i in range(cfg.n_layers)],
        "a_dst": [_he(ks[3 * i + 2], (dims[i + 1], 1))
                  for i in range(cfg.n_layers)],
    }


def _segment_softmax(scores, row_ids, n_rows):
    mx = jax.ops.segment_max(scores, row_ids, num_segments=n_rows)
    ex = jnp.exp(scores - mx[row_ids])
    den = jax.ops.segment_sum(ex, row_ids, num_segments=n_rows)
    return ex / jnp.maximum(den[row_ids], 1e-12)


def gat_forward(params, graph: Graph, x):
    h = x
    n = graph.n_nodes
    for i, w in enumerate(params["w"]):
        h = h @ w
        s_src = (h @ params["a_src"][i])[:, 0]  # [N]
        s_dst = (h @ params["a_dst"][i])[:, 0]
        # SDDMM with K=2 (paper §4.4): B=[s_src, 1], C=[[1],[s_dst]]
        b = jnp.stack([s_src, jnp.ones_like(s_src)], axis=1)  # [N,2]
        c = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=0)  # [2,N]
        e = sddmm_coo(graph.row_ids, graph.col_ids, b, c)  # [nnz]
        e = jax.nn.leaky_relu(e, 0.2)
        alpha = _segment_softmax(e, graph.row_ids, n)
        h = spmm_csr(graph.row_ids, graph.col_ids, alpha, h, n)
        if i < len(params["w"]) - 1:
            h = jax.nn.elu(h)
    return h
