"""GCN / GAT on the SpMM + SDDMM substrate — the paper's driving app.

GCN layer:   H' = act( Â (H W) )           — one SpMM per layer (paper §2.1);
             with ``fuse=True`` (default) the bias+act tail rides the
             SpMM's fused epilogue instead of a separate full pass.
GAT layer:   e = SDDMM(A, B, C) with d=2   — per paper §4.4, B/C hold source
             /destination attention scores; then segment-softmax over each
             row's edges and SpMM with the attention-weighted adjacency.
             With ``fuse=True`` (default) the whole chain runs as ONE
             ``fused_graph_attention`` dispatch (no E-length score vector
             materialized on the blocked paths).

The adjacency is one ``repro.sparse.SparseMatrix`` carrying both the
Block-ELL (MXU path) and element (scalar path) forms, so the dispatch
layer can route either path at jit trace time from the static stats the
matrix carries.  Both products run through the unified differentiable
front-end: training gradients flow through the custom_vjp rules where
SpMM's backward is SDDMM and vice versa.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gnn import GNNConfig
from repro.models.layers import _he
from repro.sparse import (SparseMatrix, fused_graph_attention, matmul,
                          sample)

# adjacency paths a Graph can execute (it carries ell + csr forms; the
# densified fallback is deliberately excluded from auto planning)
GRAPH_PATHS = ("ell", "sell", "csr")


def graph_candidates(adj: "SparseMatrix"):
    """Paths an adjacency's carried forms can execute (a bucketed batch
    pads only the planned form, so candidates must follow the forms)."""
    return tuple(p for p in GRAPH_PATHS
                 if (p == "csr" and adj.has_form("csr"))
                 or (p == "sell" and adj.has_form("sell"))
                 or (p == "ell" and (adj.has_form("ell")
                                     or adj.has_form("coo"))))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Graph:
    """Device-side graph: normalized adjacency as one ``SparseMatrix``.

    The matrix's ``stats`` are static aux metadata (plain Python
    numbers), so the dispatch layer can plan the SpMM path at jit trace
    time even though the adjacency arrays themselves are tracers.
    """
    adj: SparseMatrix
    n_nodes: int

    def tree_flatten(self):
        return (self.adj,), (self.n_nodes,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (adj,) = children
        return cls(adj=adj, n_nodes=aux[0])

    # -- legacy accessors (pre-SparseMatrix callers) ------------------------

    @property
    def ell(self):
        return self.adj.form("ell")

    @property
    def stats(self):
        return self.adj.stats if self.adj is not None else None

    @property
    def row_ids(self):
        return self.adj.form("csr")[0]

    @property
    def col_ids(self):
        return self.adj.form("csr")[1]

    @property
    def values(self):
        return self.adj.form("csr")[2]


def build_graph(adj_dense: np.ndarray, cfg: GNNConfig,
                normalize: bool = True) -> Graph:
    """adj_dense: [N, N] 0/1.  GCN normalization Â = D^-1/2 (A+I) D^-1/2."""
    n = adj_dense.shape[0]
    a = adj_dense.astype(np.float32)
    if normalize:
        a = a + np.eye(n, dtype=np.float32)
        deg = a.sum(1)
        dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
        a = a * dinv[:, None] * dinv[None, :]
    formats = ("ell", "csr")
    adj = SparseMatrix.from_dense(a, formats=formats,
                                  block=(cfg.block_m, cfg.block_n))
    if adj.stats is not None and adj.stats.sparsity >= 0.99:
        # hyper-sparse adjacency: also pack SELL-C-σ so dispatch can
        # route around the Block-ELL padding cliff
        adj = adj.with_form("sell")
    return Graph(adj=adj, n_nodes=n)


def graph_spmm(graph: Graph, h, *, policy: str = "auto", epilogue=None,
               bias=None, residual=None):
    """One message-passing step A @ H, routed by the dispatch layer.

    The adjacency carries Block-ELL and element forms, so those are the
    candidate paths; the plan is made from the matrix's static stats and
    is therefore jit-trace safe (and memoized per graph instance).
    ``epilogue``/``bias``/``residual`` fuse the layer's elementwise tail
    into the aggregation (see ``repro.sparse.ops.matmul``).
    """
    if graph.adj is None or graph.adj.stats is None:
        raise ValueError(
            "graph_spmm: Graph adjacency has no sparsity stats; construct "
            "it with build_graph() (or SparseMatrix.from_dense) to use "
            "policy routing")
    cand = graph_candidates(graph.adj)
    return matmul(graph.adj, h, policy=policy,
                  candidates=cand or GRAPH_PATHS, epilogue=epilogue,
                  bias=bias, residual=residual)


# ---------------------------------------------------------------------------
# GCN
# ---------------------------------------------------------------------------


def init_gcn(key, cfg: GNNConfig, *, bias: bool = False) -> Dict:
    dims = [cfg.in_features] + [cfg.hidden] * (cfg.n_layers - 1) \
        + [cfg.n_classes]
    ks = jax.random.split(key, cfg.n_layers)
    params = {"w": [_he(ks[i], (dims[i], dims[i + 1]))
                    for i in range(cfg.n_layers)]}
    if bias:
        params["b"] = [jnp.zeros((dims[i + 1],), jnp.float32)
                       for i in range(cfg.n_layers)]
    return params


def gcn_forward(params, graph: Graph, x, *, use_blockell: bool = True,
                policy: Optional[str] = None, fuse: bool = True):
    """GCN forward pass.

    ``policy`` (when given) routes each layer's aggregation through the
    sparsity-adaptive dispatcher ("auto"/"ell"/"csr"); the legacy
    ``use_blockell`` flag forces the corresponding path otherwise.

    ``fuse=True`` (default) folds each layer's elementwise tail —
    per-layer bias (when the params carry ``"b"``) and the inter-layer
    relu — into the aggregation SpMM's epilogue, so the raw product
    never pays a separate full pass.  ``fuse=False`` keeps the unfused
    composition as the oracle.
    """
    if policy is None:
        policy = "ell" if use_blockell else "csr"
    biases = params.get("b")
    h = x
    n_layers = len(params["w"])
    for i, w in enumerate(params["w"]):
        h = h @ w
        b = biases[i] if biases is not None else None
        inner = i < n_layers - 1
        if fuse:
            h = graph_spmm(graph, h, policy=policy,
                           epilogue="relu" if inner else None, bias=b)
        else:
            h = graph_spmm(graph, h, policy=policy)
            if b is not None:
                h = h + b
            if inner:
                h = jax.nn.relu(h)
    return h


def batch_graphs(graphs) -> "Any":
    """Compose many Graphs' adjacencies block-diagonally.

    Returns a :class:`repro.batch.BatchedSparseMatrix`; wrap its
    ``.matrix`` in a Graph (or call :func:`gcn_forward_batched`) to run
    the whole batch through one planned aggregation per layer.
    """
    from repro.batch import BatchedSparseMatrix

    return BatchedSparseMatrix.from_matrices([g.adj for g in graphs])


def gcn_forward_batched(params, batch, hs, *, policy: str = "auto"):
    """GCN over N graphs at once via the block-diagonal composition.

    GCN weights are node-independent, so ``diag(A_1..A_N) @ (H W)``
    computes every graph's aggregation in one SpMM per layer.
    ``hs`` holds per-graph features [n_i, in_features]; returns the
    per-graph logits list.
    """
    h = batch.batch_features(hs)
    g = Graph(adj=batch.matrix, n_nodes=batch.matrix.shape[0])
    out = gcn_forward(params, g, h, policy=policy)
    return batch.unbatch(out)


# ---------------------------------------------------------------------------
# GAT (single head; attention scores via SDDMM with d=2, per the paper)
# ---------------------------------------------------------------------------


def init_gat(key, cfg: GNNConfig) -> Dict:
    dims = [cfg.in_features] + [cfg.hidden] * (cfg.n_layers - 1) \
        + [cfg.n_classes]
    ks = jax.random.split(key, 3 * cfg.n_layers)
    return {
        "w": [_he(ks[3 * i], (dims[i], dims[i + 1]))
              for i in range(cfg.n_layers)],
        "a_src": [_he(ks[3 * i + 1], (dims[i + 1], 1))
                  for i in range(cfg.n_layers)],
        "a_dst": [_he(ks[3 * i + 2], (dims[i + 1], 1))
                  for i in range(cfg.n_layers)],
    }


def _segment_softmax(scores, row_ids, n_rows):
    mx = jax.ops.segment_max(scores, row_ids, num_segments=n_rows)
    ex = jnp.exp(scores - mx[row_ids])
    den = jax.ops.segment_sum(ex, row_ids, num_segments=n_rows)
    return ex / jnp.maximum(den[row_ids], 1e-12)


def gat_forward(params, graph: Graph, x, *, policy: Optional[str] = None,
                fuse: bool = True):
    """GAT forward pass (single head, d=2 SDDMM scores per the paper).

    ``fuse=True`` (default) runs each layer's whole attention
    aggregation — SDDMM scores, leaky-relu, segment softmax, SpMM — as
    ONE planned ``fused_graph_attention`` dispatch over the adjacency's
    carried forms: a single plan per layer in the dispatch log, and no
    E-length score vector materialized on the blocked paths.

    ``fuse=False`` keeps the unfused three-dispatch composition as the
    oracle; it too now routes through the sparsity-adaptive dispatcher
    (``policy``, default "auto") instead of hand-forcing the csr path.
    """
    policy = "auto" if policy is None else policy
    h = x
    n = graph.n_nodes
    cand = graph_candidates(graph.adj) if fuse else None
    # 0/1 edge pattern in element form: the SDDMM sampling operand (the
    # attention scores ignore the normalized adjacency weights)
    patt = None if fuse else graph.adj.to("csr").pattern()
    for i, w in enumerate(params["w"]):
        h = h @ w
        s_src = (h @ params["a_src"][i])[:, 0]  # [N]
        s_dst = (h @ params["a_dst"][i])[:, 0]
        # score factors with K=2 (paper §4.4): q=[s_src, 1], k=[1, s_dst]
        # so (q kᵀ)[i, j] = s_src[i] + s_dst[j]
        q = jnp.stack([s_src, jnp.ones_like(s_src)], axis=1)  # [N,2]
        if fuse:
            k = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=1)  # [N,2]
            h = fused_graph_attention(graph.adj, q, k, h,
                                      edge_act="leaky_relu",
                                      negative_slope=0.2, policy=policy,
                                      candidates=cand or None)
        else:
            c = jnp.stack([jnp.ones_like(s_dst), s_dst], axis=0)  # [2,N]
            e = sample(patt, q, c, policy=policy).data  # [nnz]
            e = jax.nn.leaky_relu(e, 0.2)
            alpha = _segment_softmax(e, graph.row_ids, n)
            h = matmul(patt.with_data(alpha), h, policy=policy)
        if i < len(params["w"]) - 1:
            h = jax.nn.elu(h)
    return h
