"""Config-driven LM: dense / MoE / SSM / hybrid / enc-dec / VLM.

Layer stacking: the layer pattern (cfg.layer_pattern) repeats down the
stack; whole periods are stacked and applied under ``lax.scan`` so compiled
HLO is O(period), not O(n_layers); a partial trailing period ("remainder")
is applied unrolled.  Every block kind threads an optional cache entry so
the same code path serves train (no cache), prefill (build cache) and
decode (consume + update cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import (init_rglru, init_rglru_cache,
                                rglru_decode_step, rglru_forward)
from repro.models.ssm import (init_ssm, init_ssm_cache, ssm_decode_step,
                              ssm_forward)
from repro.sharding import shard_hint

ATTN_KINDS = ("attn", "local", "moe")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32)}
    if kind in ATTN_KINDS:
        p["attn"] = L.init_attn(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        if kind == "moe":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg)
    elif kind == "rglru":
        p["rec"] = init_rglru(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), jnp.float32)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["lnx"] = jnp.zeros((d,), jnp.float32)
        p["xattn"] = L.init_attn(ks[2], cfg, cross=True)
    return p


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _unstacked_periods(periods) -> bool:
    """True when ``params["periods"]`` is a tuple of per-period block
    tuples rather than scan-stacked leaves.  Sparse-weight params (see
    ``models.pruning.sparsify_lm``) are shipped this way: a pruned
    weight is a host-planned ``SparseMatrix`` whose topology differs
    per layer, so periods cannot be stacked or scanned and are applied
    with a python loop instead."""
    return bool(periods) and isinstance(periods[0], tuple)


def init_lm(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + 8)
    cross = cfg.encoder_layers > 0
    period_params = []
    for i in range(cfg.n_periods):
        blocks = tuple(
            _init_block(keys[i * cfg.period + j], kind, cfg, cross=cross)
            for j, kind in enumerate(cfg.layer_pattern))
        period_params.append(blocks)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32)
        / np.sqrt(cfg.d_model),
        "periods": _stack(period_params) if period_params else (),
        "remainder": tuple(
            _init_block(keys[cfg.n_periods * cfg.period + j], kind, cfg,
                        cross=cross)
            for j, kind in enumerate(cfg.remainder_kinds)),
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._he(keys[-2], (cfg.d_model, cfg.vocab_size))
    if cfg.vision_tokens:
        params["vision_proj"] = L._he(keys[-3], (cfg.d_model, cfg.d_model))
    if cfg.encoder_layers:
        enc_blocks = tuple(
            _init_block(keys[-4 - j], "attn", cfg) for j in
            range(cfg.encoder_layers))
        params["encoder"] = {
            "blocks": _stack(enc_blocks),
            "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# Block application (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _apply_block(p, x, kind: str, cfg: ModelConfig, *, positions,
                 enc_out=None, mode: str = "train",
                 max_len: Optional[int] = None):
    """Returns (x, cache_entry_or_None, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        if mode == "prefill":
            y, cache_entry = _prefill_self_attention(
                p["attn"], h, cfg, kind=kind, positions=positions,
                max_len=max_len)
        else:
            y = L.self_attention(p["attn"], h, cfg, kind=kind,
                                 positions=positions)
        x = x + y
        if enc_out is not None:
            hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
            enc_kv = L.encode_cross_kv(p["xattn"], enc_out, cfg)
            x = x + L.cross_attention(p["xattn"], hx, enc_kv, cfg)
            if mode == "prefill":
                cache_entry = {"self": cache_entry, "enc_k": enc_kv[0],
                               "enc_v": enc_kv[1]}
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y2, aux = moe_ffn(p["moe"], h2, cfg)
        else:
            y2 = L.mlp(p["mlp"], h2, cfg)
        x = x + y2
    elif kind == "ssm":
        if mode == "prefill":
            y, cache_entry = ssm_forward(p["ssm"], h, cfg, return_state=True)
        else:
            y = ssm_forward(p["ssm"], h, cfg)
        x = x + y
    elif kind == "rglru":
        if mode == "prefill":
            y, cache_entry = rglru_forward(p["rec"], h, cfg,
                                           return_state=True)
        else:
            y = rglru_forward(p["rec"], h, cfg)
        x = x + y
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg)
    x = shard_hint(x, "batch", "seq", "embed")
    return x, cache_entry, aux


def _prefill_self_attention(p, x, cfg: ModelConfig, *, kind: str, positions,
                            max_len: int):
    """Full-sequence attention that also materializes the decode cache."""
    b, s, _ = x.shape
    q, k, v = L._qkv(p, x, x, cfg)
    if cfg.family != "audio":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    blk = min(cfg.attn_block, s)
    from repro.core import attention as attn_lib
    lblk = min(blk, cfg.window) if cfg.window else blk
    if kind == "local" and s > cfg.window and s % lblk == 0 \
            and cfg.window % lblk == 0:
        out = attn_lib.local_block_attention(
            q, k, v, window=cfg.window, block=lblk)
    elif s % blk == 0 and s > max(blk, 2048):
        # prefill is forward-only: dynamic causal block skipping is legal
        out = attn_lib.flash_attention(q, k, v, causal=True, q_chunk=blk,
                                       kv_chunk=blk, skip_masked_blocks=True)
    else:
        window = cfg.window if kind == "local" else None
        out = attn_lib.mha_reference(q, k, v, causal=True, window=window)
    y = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)

    size = min(max_len, cfg.window) if kind == "local" else max_len
    take = min(s, size)
    k_tail, v_tail = k[:, -take:], v[:, -take:]
    pos_tail = positions[:, -take:]
    slots = pos_tail[0] % size if kind == "local" else pos_tail[0]
    kc = jnp.zeros((b, size) + k.shape[2:], k.dtype).at[:, slots].set(k_tail)
    vc = jnp.zeros((b, size) + v.shape[2:], v.dtype).at[:, slots].set(v_tail)
    kpos = jnp.full((b, size), -1, jnp.int32).at[:, slots].set(pos_tail)
    return y, {"k": kc, "v": vc, "kpos": kpos}


# ---------------------------------------------------------------------------
# Decode block application
# ---------------------------------------------------------------------------


def _decode_block(p, x, cache_entry, kind: str, cfg: ModelConfig, *, pos):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        self_cache = cache_entry["self"] if "enc_k" in cache_entry \
            else cache_entry
        y, new_self = L.decode_self_attention(p["attn"], h, self_cache, cfg,
                                              kind=kind, pos=pos)
        x = x + y
        if "enc_k" in cache_entry:
            hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
            x = x + L.cross_attention(
                p["xattn"], hx, (cache_entry["enc_k"], cache_entry["enc_v"]),
                cfg)
            new_cache = dict(cache_entry)
            new_cache["self"] = new_self
        else:
            new_cache = new_self
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            y2, _ = moe_ffn(p["moe"], h2, cfg)
        else:
            y2 = L.mlp(p["mlp"], h2, cfg)
        x = x + y2
    elif kind == "ssm":
        y, new_cache = ssm_decode_step(p["ssm"], h, cache_entry, cfg)
        x = x + y
    elif kind == "rglru":
        y, new_cache = rglru_decode_step(p["rec"], h, cache_entry, cfg)
        x = x + y
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg)
    return x, new_cache


# ---------------------------------------------------------------------------
# Full forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens, vision_embeds=None,
                  dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    if cfg.vision_tokens and vision_embeds is not None:
        vproj = vision_embeds.astype(dtype) @ params["vision_proj"].astype(
            dtype)
        x = jnp.concatenate([vproj, x], axis=1)
    if cfg.family == "audio":
        x = x + L.sinusoid_positions(x.shape[1], cfg.d_model).astype(dtype)
    return shard_hint(x, "batch", "seq", "embed")


def _run_encoder(params, cfg: ModelConfig, enc_embeds):
    """Whisper encoder over (stubbed) frame embeddings [B, Se, d]."""
    dtype = jnp.dtype(cfg.dtype)
    x = enc_embeds.astype(dtype)
    x = x + L.sinusoid_positions(x.shape[1], cfg.d_model).astype(dtype)
    b, se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(se)[None], (b, se))

    def body(x, blk_p):
        h = L.rms_norm(x, blk_p["ln1"], cfg.norm_eps)
        y = L.self_attention(blk_p["attn"], h, cfg, kind="attn",
                             positions=positions, causal=False)
        x = x + y
        h2 = L.rms_norm(x, blk_p["ln2"], cfg.norm_eps)
        x = x + L.mlp(blk_p["mlp"], h2, cfg)
        return x, None

    body_r = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)
    if runtime.unrolled():
        for i in range(cfg.encoder_layers):
            blk_p = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                           params["encoder"]["blocks"])
            x, _ = body_r(x, blk_p)
    else:
        x, _ = jax.lax.scan(body_r, x, params["encoder"]["blocks"])
    return L.rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, tokens, *, vision_embeds=None,
                   enc_embeds=None, mode: str = "train",
                   max_len: Optional[int] = None, remat: bool = True,
                   remat_policy: str = "nothing"):
    """Returns (hidden [B,S,d], cache_or_None, aux_loss)."""
    x = _embed_inputs(params, cfg, tokens, vision_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    enc_out = None
    if cfg.encoder_layers and enc_embeds is not None:
        enc_out = _run_encoder(params, cfg, enc_embeds)

    def period_body(carry, period_p):
        x, aux = carry
        caches = []
        for j, kind in enumerate(cfg.layer_pattern):
            x, ce, a = _apply_block(period_p[j], x, kind, cfg,
                                    positions=positions, enc_out=enc_out,
                                    mode=mode, max_len=max_len)
            aux = aux + a
            caches.append(ce)
        return (x, aux), tuple(caches)

    body = period_body
    if remat and mode == "train":
        policy = (jax.checkpoint_policies.dots_saveable
                  if remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(period_body, policy=policy)

    aux0 = jnp.zeros((), jnp.float32)
    unstacked = _unstacked_periods(params["periods"])
    if cfg.n_periods and (unstacked or runtime.unrolled()):
        carry = (x, aux0)
        pcs = []
        for i in range(cfg.n_periods):
            period_p = params["periods"][i] if unstacked else \
                jax.tree_util.tree_map(lambda a, i=i: a[i],
                                       params["periods"])
            carry, pc = body(carry, period_p)
            pcs.append(pc)
        (x, aux) = carry
        period_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *pcs) if pcs and mode == "prefill" \
            else ()
    elif cfg.n_periods:
        (x, aux), period_caches = jax.lax.scan(
            body, (x, aux0), params["periods"])
    else:
        aux, period_caches = aux0, ()

    rem_caches = []
    for j, kind in enumerate(cfg.remainder_kinds):
        x, ce, a = _apply_block(params["remainder"][j], x, kind, cfg,
                                positions=positions, enc_out=enc_out,
                                mode=mode, max_len=max_len)
        aux = aux + a
        rem_caches.append(ce)

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    cache = None
    if mode == "prefill":
        cache = {"periods": period_caches, "remainder": tuple(rem_caches),
                 "pos": jnp.asarray(s, jnp.int32)}
    return x, cache, aux


def _lm_head(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_ce_loss(hidden, head_w, targets, mask, *, chunk: int = 1024):
    """Cross-entropy computed per sequence chunk so [B,S,V] logits are
    never materialized (V can be 262k).  hidden: [B,S,d]."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def one(args):
        h, t, m = args
        logits = (h.astype(jnp.float32)
                  @ head_w.astype(jnp.float32))  # [B,chunk,V]
        logits = shard_hint(logits, "batch", None, "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return nll.sum(), m.sum()

    if runtime.unrolled():
        parts = [jax.checkpoint(one)(
            (hc[i], tc[i], mc[i])) for i in range(nc)]
        nll = sum(p[0] for p in parts)
        cnt = sum(p[1] for p in parts)
        return nll / jnp.maximum(cnt, 1.0)
    nll, cnt = jax.lax.map(jax.checkpoint(one), (hc, tc, mc))
    return nll.sum() / jnp.maximum(cnt.sum(), 1.0)


def lm_loss(params, cfg: ModelConfig, batch, *, aux_weight: float = 0.01,
            remat: bool = True, remat_policy: str = "nothing"):
    """batch: dict(tokens[B,S], targets[B,S], mask[B,S], vision_embeds?,
    enc_embeds?)."""
    hidden, _, aux = forward_hidden(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        enc_embeds=batch.get("enc_embeds"), mode="train", remat=remat,
        remat_policy=remat_policy)
    if cfg.vision_tokens:
        hidden = hidden[:, cfg.vision_tokens:]
    loss = chunked_ce_loss(hidden, _lm_head(params, cfg), batch["targets"],
                           batch["mask"].astype(jnp.float32))
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving entry points
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)

    def entry(kind):
        if kind in ATTN_KINDS:
            c = L.init_attn_cache(cfg, batch, max_len, kind, dtype)
            if cfg.encoder_layers:
                hkv, hd = cfg.n_kv_heads, cfg.head_dim
                c = {"self": c,
                     "enc_k": jnp.zeros((batch, cfg.encoder_seq, hkv, hd),
                                        dtype),
                     "enc_v": jnp.zeros((batch, cfg.encoder_seq, hkv, hd),
                                        dtype)}
            return c
        if kind == "ssm":
            return init_ssm_cache(cfg, batch, dtype)
        if kind == "rglru":
            return init_rglru_cache(cfg, batch, dtype)
        raise ValueError(kind)

    period = tuple(entry(k) for k in cfg.layer_pattern)
    periods = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), period) \
        if cfg.n_periods else ()
    remainder = tuple(entry(k) for k in cfg.remainder_kinds)
    return {"periods": periods, "remainder": remainder,
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            vision_embeds=None, enc_embeds=None):
    """Returns (last-token logits [B,V], cache)."""
    hidden, cache, _ = forward_hidden(
        params, cfg, tokens, vision_embeds=vision_embeds,
        enc_embeds=enc_embeds, mode="prefill", max_len=max_len, remat=False)
    last = hidden[:, -1]
    logits = last.astype(jnp.float32) @ _lm_head(params, cfg).astype(
        jnp.float32)

    # stack per-period caches gathered from the scan's ys
    def fix(c):
        return c

    cache = jax.tree_util.tree_map(fix, cache)
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: [B,1] int32.  Returns (logits [B,V], new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dtype)[token]
    pos = cache["pos"]
    if cfg.family == "audio":
        half = np.arange(0, cfg.d_model, 2) / cfg.d_model
        ang = pos.astype(jnp.float32) / (10000.0 ** jnp.asarray(half,
                                                                jnp.float32))
        pe = jnp.zeros((cfg.d_model,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
        x = x + pe.astype(dtype)

    def period_body(x, scanned):
        period_p, period_c = scanned
        new_caches = []
        for j, kind in enumerate(cfg.layer_pattern):
            x, nc = _decode_block(period_p[j], x, period_c[j], kind, cfg,
                                  pos=pos)
            new_caches.append(nc)
        return x, tuple(new_caches)

    unstacked = _unstacked_periods(params["periods"])
    if cfg.n_periods and (unstacked or runtime.unrolled()):
        pcs = []
        for i in range(cfg.n_periods):
            if unstacked:
                scanned = (params["periods"][i],
                           jax.tree_util.tree_map(lambda a, i=i: a[i],
                                                  cache["periods"]))
            else:
                scanned = jax.tree_util.tree_map(
                    lambda a, i=i: a[i],
                    (params["periods"], cache["periods"]))
            x, pc = period_body(x, scanned)
            pcs.append(pc)
        new_periods = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pcs)
    elif cfg.n_periods:
        x, new_periods = jax.lax.scan(
            period_body, x, (params["periods"], cache["periods"]))
    else:
        new_periods = ()

    new_rem = []
    for j, kind in enumerate(cfg.remainder_kinds):
        x, nc = _decode_block(params["remainder"][j], x,
                              cache["remainder"][j], kind, cfg, pos=pos)
        new_rem.append(nc)

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x[:, 0].astype(jnp.float32) @ _lm_head(params, cfg).astype(
        jnp.float32)
    logits = shard_hint(logits, "batch", "vocab")
    new_cache = {"periods": new_periods, "remainder": tuple(new_rem),
                 "pos": pos + 1}
    return logits, new_cache
