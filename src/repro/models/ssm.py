"""Mamba-2 SSD (state-space duality) layer.

Chunked SSD algorithm (Dao & Gu 2024): split the sequence into chunks;
within a chunk the recurrence is materialized as a decay-masked
attention-like quadratic form (MXU-friendly), across chunks a scan carries
the [heads, head_dim, state] SSM state.  Decode is the O(1)/token
recurrence — why the mamba2 cell RUNS the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import _he, rms_norm
from repro.sharding import shard_hint


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ds
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32)
                 * (np.log(0.1) - np.log(0.001)) + np.log(0.001))
    return {
        "in_proj": _he(ks[0], (d, 2 * di + 2 * ds + nh)),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_dim),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),  # inverse-softplus init
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": _he(ks[3], (di, d)),
    }


def _split_proj(p, x, cfg: ModelConfig):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * ds]
    dt_raw = zxbcdt[..., 2 * di + 2 * ds:]
    return z, xbc, dt_raw


def _causal_conv(xbc, conv_w, conv_b, *, tail=None, act: str = "silu"):
    """Depthwise causal conv over time. xbc: [B,S,C]; conv_w: [W,C]."""
    w = conv_w.shape[0]
    if tail is None:
        pad = jnp.zeros(xbc.shape[:1] + (w - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+W-1, C]
    out = sum(xp[:, i: i + xbc.shape[1]] * conv_w[i].astype(xbc.dtype)
              for i in range(w))
    out = out + conv_b.astype(xbc.dtype)
    if act == "silu":
        out = jax.nn.silu(out)
    return out, xp[:, -(w - 1):] if w > 1 else None


def ssd_chunked(xh, dt, a_log, b_mat, c_mat, *, chunk: int, init_state=None,
                intra_dtype=jnp.float32):
    """Chunked SSD scan.

    xh: [B,S,H,P] inputs (head-split), dt: [B,S,H] (post-softplus),
    b_mat/c_mat: [B,S,N] (ngroups=1 shared over heads).
    Returns y: [B,S,H,P] and final state [B,H,P,N].

    ``intra_dtype``: dtype of the intra-chunk quadratic operands (decay /
    scores / dt-weighted inputs).  The recurrence statistics (cum, carry
    state) stay f32 regardless; bf16 here halves the dominant memory
    term (§Perf P8) at ~1e-2 relative output error.
    """
    bsz, s, h, pdim = xh.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log)  # [H], negative
    # log-decay per step
    dta = dt * a  # [B,S,H]
    xdt = xh * dt[..., None]  # dt-weighted input

    xc = xdt.reshape(bsz, nc, chunk, h, pdim)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)
    dtac = dta.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(dtac, axis=2)  # [B,nc,Q,H]

    # intra-chunk quadratic (the "duality" matmul form).  The contraction
    # order is forced: (scores ⊙ decay) first, then one matmul over k —
    # a free-form 3-operand einsum let XLA pick paths that materialize a
    # [B,nc,Q,K,H,P]-shaped intermediate at some chunk sizes (§Perf P6,
    # first attempt: memory term *rose* 4.5x at chunk 64).
    cd = intra_dtype
    scores = jnp.einsum("bcqn,bckn->bcqk", cc.astype(cd), bc.astype(cd),
                        preferred_element_type=cd)  # [B,nc,Q,Q]
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # cum_q - cum_k
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel),
                      0.0).astype(cd)
    w = scores[..., None] * decay  # [B,nc,Q,K,H]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", w, xc.astype(cd),
                        preferred_element_type=jnp.float32)

    # chunk states: sum_k exp(cum_last - cum_k) B_k x_k^T
    seg = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc.astype(cd),
                        seg.astype(cd), xc.astype(cd),
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence.  The scan carry and its per-chunk inputs must
    # carry the SAME sharding (heads over `model`) or SPMD reshards
    # state-sized tensors at every chunk step (§Perf P6/P7: ~170 MB/step
    # against a 5 MB carry; full replication (P7) killed the resharding
    # but paid gathers + a worse memory term — consistent H-sharding of
    # both sides (P7b) keeps every step local AND sharded).
    states = shard_hint(states, "batch", None, "heads", None, None)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]
    if init_state is None:
        init_state = jnp.zeros((bsz, h, pdim, n), jnp.float32)
    init_state = shard_hint(init_state, "batch", "heads", None, None)

    def scan_body(carry, inp):
        st = carry
        new_st, dec = inp
        out_prev = st
        st = st * dec[:, :, None, None] + new_st
        return st, out_prev

    states_t = states.astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    decay_t = chunk_decay.astype(jnp.float32).transpose(1, 0, 2)
    from repro import runtime
    if runtime.unrolled():
        st = init_state
        prevs = []
        for c in range(nc):
            st, prev = scan_body(st, (states_t[c], decay_t[c]))
            prevs.append(prev)
        final_state = st
        prev_states = jnp.stack(prevs, axis=1)  # [B,nc,H,P,N]
    else:
        final_state, prev_states = jax.lax.scan(
            scan_body, init_state, (states_t, decay_t))
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # inter-chunk contribution: C_q · (decayed carry-in state)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc.astype(cd),
                       jnp.exp(cum).astype(cd), prev_states.astype(cd),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(bsz, s, h, pdim)
    return y, final_state


def ssm_forward(p, x, cfg: ModelConfig, *, init_state=None, conv_tail=None,
                return_state: bool = False):
    """Full-sequence SSD block. x: [B,S,d] -> [B,S,d]."""
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    bsz, s, _ = x.shape
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc, tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], tail=conv_tail)
    xh = xbc[..., :di].reshape(bsz, s, nh, hd)
    b_mat = xbc[..., di: di + ds]
    c_mat = xbc[..., di + ds:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])  # [B,S,H]
    xh = shard_hint(xh, "batch", "seq", "heads", None)
    chunk = min(cfg.ssm_chunk, s)
    while s % chunk:  # largest divisor of s not exceeding the target
        chunk -= 1
    y, state = ssd_chunked(
        xh.astype(jnp.float32), dt, p["A_log"],
        b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
        chunk=chunk, init_state=init_state,
        intra_dtype=jnp.bfloat16 if cfg.ssm_bf16_intra else jnp.float32)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, {"state": state, "conv": tail}
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, ds = cfg.d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, ds),
                           jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ds), dtype),
    }


def ssm_decode_step(p, x, cache, cfg: ModelConfig):
    """One-token recurrence. x: [B,1,d]."""
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_head_dim
    bsz = x.shape[0]
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    # conv over [tail, current]
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xbc], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    xh = xbc1[..., :di].reshape(bsz, nh, hd).astype(jnp.float32)
    b_mat = xbc1[:, 0, di: di + ds].astype(jnp.float32)
    c_mat = xbc1[:, 0, di + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)  # [B,H]
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, b_mat)
    y = jnp.einsum("bhpn,bn->bhp", state, c_mat)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_cache = {"state": state,
                 "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache
