"""Shared model building blocks (pure-functional, params as pytrees)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import attention as attn_lib


def _he(key, shape, scale_dim=None):
    scale_dim = scale_dim if scale_dim is not None else shape[0]
    return jax.random.normal(key, shape, jnp.float32) / np.sqrt(scale_dim)


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w)).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def rope(x, positions, theta: float):
    """Rotary embedding. x: [B, S, H, D]; positions: [B, S]."""
    b, s, h, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(
        jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoid_positions(s: int, d: int):
    pos = np.arange(s)[:, None]
    dim = np.arange(0, d, 2)[None, :] / d
    ang = pos / (10000.0 ** dim)
    out = np.zeros((s, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d: Optional[int] = None,
             f: Optional[int] = None):
    d = d or cfg.d_model
    f = f or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": _he(ks[0], (d, f)), "wo": _he(ks[1], (f, d))}
    if cfg.gated_mlp:
        p["wg"] = _he(ks[2], (d, f))
    return p


def _wmat(x, w):
    """x @ w where w may be a pruned ``SparseMatrix`` weight.

    Sparse weights (see ``models.pruning``) go through the planned
    sparse front-end via ``__rmatmul__`` — [B, S, d] collapses to one
    [B*S, d] operand so the whole batch rides a single dispatch plan —
    and come back in x's compute dtype like a dense weight would.
    """
    from repro.sparse.matrix import SparseMatrix

    if isinstance(w, SparseMatrix):
        lead = x.shape[:-1]
        y = x.reshape(-1, x.shape[-1]) @ w
        return y.reshape(lead + (w.shape[1],)).astype(x.dtype)
    return x @ w.astype(x.dtype)


def mlp(p, x, cfg: ModelConfig):
    h = _wmat(x, p["wi"])
    h = activation(h, cfg.act)
    if cfg.gated_mlp:
        h = h * _wmat(x, p["wg"])
    return _wmat(h, p["wo"])


# ---------------------------------------------------------------------------
# Attention (self + cross), GQA, RoPE, cached decode
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d, hq * hd)),
        "wk": _he(ks[1], (d, hkv * hd)),
        "wv": _he(ks[2], (d, hkv * hd)),
        "wo": _he(ks[3], (hq * hd, d), scale_dim=hq * hd),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def _qkv(p, x, kv_src, cfg: ModelConfig):
    b, s, _ = x.shape
    skv = kv_src.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = kv_src @ p["wk"].astype(x.dtype)
    v = kv_src @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(b, s, hq, hd), k.reshape(b, skv, hkv, hd),
            v.reshape(b, skv, hkv, hd))


def self_attention(p, x, cfg: ModelConfig, *, kind: str, positions,
                   causal: bool = True, dynamic_skip: bool = False):
    """Full-sequence self attention (train / prefill).

    ``dynamic_skip``: skip fully-masked causal kv blocks via a dynamic
    trip-count loop — forward-only (not reverse-differentiable), used by
    prefill; training uses the masked scan.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, x, cfg)
    if cfg.family != "audio":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    blk = min(cfg.attn_block, s)
    lblk = min(blk, cfg.window) if cfg.window else blk
    if kind == "local" and s > cfg.window and s % lblk == 0 \
            and cfg.window % lblk == 0:
        out = attn_lib.local_block_attention(
            q, k, v, window=cfg.window, block=lblk)
    elif s % blk == 0 and s > max(blk, 2048):
        # flash chunking only where the S^2 buffer actually threatens HBM;
        # short sequences take the loop-free dense path (cheaper to
        # partition, transient O(S^2) tile fits comfortably)
        out = attn_lib.flash_attention(
            q, k, v, causal=causal, q_chunk=blk, kv_chunk=blk,
            skip_masked_blocks=dynamic_skip)
    else:
        window = cfg.window if kind == "local" else None
        out = attn_lib.mha_reference(q, k, v, causal=causal, window=window)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """Decoder->encoder attention; enc_kv = (k, v) precomputed [B,Se,Hkv,D].

    Chunked over decoder positions (lax.map + checkpoint) so the
    [B, S_dec, S_enc] score tensor never fully materializes.
    """
    from repro import runtime
    b, s, _ = x.shape
    hq, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, hq, hd)
    k, v = enc_kv
    n_kv = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, s, n_kv, hq // n_kv, hd)

    def one(q_blk):  # [B, c, Hkv, G, D]
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        pr = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v.astype(jnp.float32))
        return o.reshape(o.shape[:2] + (hq * hd,)).astype(x.dtype)

    chunk = min(cfg.attn_block, s)
    if s % chunk or s == chunk:
        out = one(qg)
    else:
        # python-unrolled chunks: nested lax loops inside the scanned
        # period body explode SPMD-partitioner time at high device counts
        nc = s // chunk
        qc = qg.reshape(b, nc, chunk, n_kv, hq // n_kv, hd)
        out = jnp.concatenate(
            [jax.checkpoint(one)(qc[:, i]) for i in range(nc)], axis=1)
    return out @ p["wo"].astype(x.dtype)


def encode_cross_kv(p, enc_states, cfg: ModelConfig):
    b, se, _ = enc_states.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_states @ p["wk"].astype(enc_states.dtype)).reshape(b, se, hkv, hd)
    v = (enc_states @ p["wv"].astype(enc_states.dtype)).reshape(b, se, hkv, hd)
    return (k, v)


# -- cached decode -----------------------------------------------------------


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str,
                    dtype):
    """KV cache for one attention layer.

    Local layers keep a ring buffer of ``window`` entries (the 500k-decode
    memory win from the paper's technique: cache ∝ window, not seq).
    """
    size = min(max_len, cfg.window) if kind == "local" else max_len
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, size, hkv, hd), dtype),
        "v": jnp.zeros((batch, size, hkv, hd), dtype),
        "kpos": jnp.full((batch, size), -1, jnp.int32),
    }


def decode_self_attention(p, x, cache, cfg: ModelConfig, *, kind: str, pos):
    """One-token decode with cache update. x: [B,1,d]; pos: scalar int32."""
    b = x.shape[0]
    q, k, v = _qkv(p, x, x, cfg)
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.family != "audio":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    size = cache["k"].shape[1]
    slot = (pos % size).astype(jnp.int32) if kind == "local" else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kpos = jax.lax.dynamic_update_slice_in_dim(
        cache["kpos"], jnp.full((b, 1), pos, jnp.int32), slot, axis=1)
    # validity mask from stored absolute positions
    valid = kpos >= 0
    if kind == "local":
        valid &= kpos > pos - cfg.window
    scale = 1.0 / np.sqrt(cfg.head_dim)
    n_kv = k_cache.shape[2]
    hq = cfg.n_heads
    qg = q.reshape(b, n_kv, hq // n_kv, cfg.head_dim).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg,
                        k_cache.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, None, :], logits, attn_lib.NEG_INF)
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", pr, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, hq * cfg.head_dim).astype(x.dtype)
    y = out @ p["wo"].astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache, "kpos": kpos}
