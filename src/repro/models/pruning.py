"""Sparse-weight transformer inference: magnitude pruning + conversion.

The paper's DLMC motivation in model form: transformer MLP weights are
magnitude-pruned at block granularity (the ``block_pruned`` corpus
family is exactly this structure) and shipped as planned
:class:`~repro.sparse.matrix.SparseMatrix` operands, so every MLP
matmul in ``models.transformer`` runs through the sparsity-adaptive
dispatch front-end instead of a dense matmul over mostly-zero weights.

``sparsify_lm`` rewrites an ``init_lm`` params tree in place of the
dense one:

  * period blocks are *unstacked* (scan-stacked leaves indexed back out
    into per-period tuples) because each pruned weight carries its own
    host topology and cannot ride ``lax.scan``;
  * every MLP ``wi``/``wg``/``wo`` becomes a ``SparseMatrix`` built
    from the pruned dense weight (structure measured, plan memoized on
    first use);
  * everything else (embeddings, attention, norms) stays dense.

The transformer forward detects the unstacked layout and python-loops
the periods (see ``transformer._unstacked_periods``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# MLP weight leaves that get pruned + converted
_MLP_KEYS = ("wi", "wg", "wo")


def magnitude_prune(w, sparsity: float, block: Tuple[int, int] = (1, 1)
                    ) -> np.ndarray:
    """Zero the smallest-magnitude blocks of a [d_in, d_out] weight.

    ``block = (1, 1)`` is unstructured pruning; larger blocks score each
    tile by its L2 norm and drop whole tiles — the DLMC structured
    pattern the blocked kernels are built for.  Keeps the
    ceil((1-sparsity) * n_blocks) highest-scoring blocks, so realized
    sparsity is within one block of the request.
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
    w = np.asarray(w, np.float32)
    m, n = w.shape
    bm, bn = block
    if m % bm or n % bn:
        raise ValueError(
            f"weight shape {w.shape} not divisible by prune block {block}")
    gm, gn = m // bm, n // bn
    tiles = w.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)
    score = np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=(2, 3)))
    keep = int(np.ceil((1.0 - sparsity) * gm * gn))
    if keep >= gm * gn:
        return w
    # stable cutoff: keep the `keep` largest tile norms
    flat = score.reshape(-1)
    order = np.argsort(-flat, kind="stable")
    mask = np.zeros(gm * gn, bool)
    mask[order[:keep]] = True
    tiles = tiles * mask.reshape(gm, gn, 1, 1)
    return tiles.transpose(0, 2, 1, 3).reshape(m, n).astype(np.float32)


def _to_sparse(w, *, sparsity: float, prune_block: Tuple[int, int],
               formats: Optional[Tuple[str, ...]], format: str,
               block: Tuple[int, int]):
    from repro.sparse.matrix import SparseMatrix

    pruned = magnitude_prune(w, sparsity, prune_block)
    return SparseMatrix.from_dense(pruned, formats=formats, format=format,
                                   block=block)


def _sparsify_block(blk: Dict[str, Any], **kw) -> Dict[str, Any]:
    out = dict(blk)
    if "mlp" in blk:
        out["mlp"] = {
            k: (_to_sparse(v, **kw) if k in _MLP_KEYS else v)
            for k, v in blk["mlp"].items()
        }
    return out


def sparsify_lm(params: Dict[str, Any], cfg: ModelConfig, *,
                sparsity: float = 0.9,
                prune_block: Tuple[int, int] = (8, 8),
                formats: Optional[Tuple[str, ...]] = ("ell", "csr"),
                format: str = "auto",
                block: Tuple[int, int] = (64, 64)) -> Dict[str, Any]:
    """Prune every MLP weight of an ``init_lm`` params tree to
    ``SparseMatrix`` form; returns a new params dict with unstacked
    periods (safe to feed straight to ``forward_hidden`` /
    ``decode_step`` / ``lm_loss``).

    ``prune_block`` is the pruning granule (tile-norm magnitude
    pruning); ``block`` the Block-ELL storage tile of the converted
    operand; ``formats``/``format`` pass through to
    ``SparseMatrix.from_dense``.
    """
    kw = dict(sparsity=sparsity, prune_block=prune_block, formats=formats,
              format=format, block=block)
    out = dict(params)
    if cfg.n_periods and params["periods"]:
        unstacked = []
        for i in range(cfg.n_periods):
            period = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                            params["periods"])
            unstacked.append(tuple(_sparsify_block(b, **kw)
                                   for b in period))
        out["periods"] = tuple(unstacked)
    out["remainder"] = tuple(_sparsify_block(b, **kw)
                             for b in params["remainder"])
    return out


def weight_sparsity_report(params: Dict[str, Any]) -> Dict[str, float]:
    """Measured structure of the sparse weights in a params tree.

    Returns aggregate counts over every ``SparseMatrix`` leaf:
    ``n_sparse`` operands, true ``nnz`` vs logical ``elements``, and
    the realized global ``sparsity``.
    """
    from repro.sparse.matrix import SparseMatrix

    n_sparse, nnz, elements = 0, 0, 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, SparseMatrix)):
        if isinstance(leaf, SparseMatrix):
            n_sparse += 1
            nnz += leaf.stats.nnz
            elements += leaf.stats.dense_elements
    return {
        "n_sparse": n_sparse,
        "nnz": nnz,
        "elements": elements,
        "sparsity": 1.0 - nnz / elements if elements else 0.0,
    }


def dense_reference(params: Dict[str, Any]) -> Dict[str, Any]:
    """Densify every ``SparseMatrix`` weight back to a jnp array —
    the numerical oracle for sparse-vs-dense parity tests."""
    from repro.sparse.matrix import SparseMatrix

    return jax.tree_util.tree_map(
        lambda x: jnp.asarray(x.to("dense")) if isinstance(x, SparseMatrix)
        else x,
        params, is_leaf=lambda x: isinstance(x, SparseMatrix))
