"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Real-Gated Linear Recurrent Unit:
    r_t = σ(W_a u_t + b_a)            (recurrence gate)
    i_t = σ(W_i u_t + b_i)            (input gate)
    a_t = exp(c · r_t · log σ(Λ))     (gated decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

Training runs the recurrence as an associative scan (O(log S) depth);
decode is one multiply-add per token — sub-quadratic, so the
recurrentgemma cell RUNS the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import _he
from repro.models.ssm import _causal_conv

C_FACTOR = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    # Λ such that a^c = σ(Λ)^c is uniform in [0.9, 0.999] (Griffin init)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    a0 = u ** (1.0 / C_FACTOR)
    lam = jnp.log(a0 / (1.0 - a0))
    return {
        "wx": _he(ks[0], (d, w)),
        "wg": _he(ks[1], (d, w)),
        "conv_w": jax.random.normal(ks[5], (cfg.conv_width, w),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "ga_w": _he(ks[2], (w, w)),
        "ga_b": jnp.zeros((w,), jnp.float32),
        "gi_w": _he(ks[3], (w, w)),
        "gi_b": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": _he(ks[0], (w, d)),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["ga_w"].astype(u.dtype)
                       + p["ga_b"].astype(u.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["gi_w"].astype(u.dtype)
                       + p["gi_b"].astype(u.dtype)).astype(jnp.float32)
    log_a = C_FACTOR * r * (-jax.nn.softplus(-p["lam"]))  # c·r·logσ(Λ) ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = beta * (i * u.astype(jnp.float32))
    return a, b


def rglru_forward(p, x, cfg: ModelConfig, *, h0=None, conv_tail=None,
                  return_state: bool = False):
    """x: [B,S,d] -> [B,S,d]."""
    u = x @ p["wx"].astype(x.dtype)
    u, tail = _causal_conv(u, p["conv_w"], p["conv_b"], tail=conv_tail,
                           act="none")
    a, b = _gates(p, u)
    if h0 is not None:
        # fold carry-in state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return (al * ar, bl * ar + br)

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    g = jax.nn.gelu(x @ p["wg"].astype(x.dtype)).astype(jnp.float32)
    y = (h * g).astype(x.dtype) @ p["out"].astype(x.dtype)
    if return_state:
        return y, {"h": h[:, -1], "conv": tail}
    return y


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode_step(p, x, cache, cfg: ModelConfig):
    """x: [B,1,d] -> [B,1,d] with O(1) state update."""
    u = x @ p["wx"].astype(x.dtype)  # [B,1,w]
    window = jnp.concatenate([cache["conv"].astype(x.dtype), u], axis=1)
    u1 = (jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"])
          + p["conv_b"])[:, None, :].astype(x.dtype)
    a, b = _gates(p, u1)
    h = a[:, 0] * cache["h"] + b[:, 0]
    g = jax.nn.gelu(x @ p["wg"].astype(x.dtype)).astype(jnp.float32)
    y = (h[:, None] * g).astype(x.dtype) @ p["out"].astype(x.dtype)
    return y, {"h": h, "conv": window[:, 1:].astype(cache["conv"].dtype)}
