"""Mixture-of-Experts FFN with sort-based capacity dispatch.

DESIGN.md §4: top-1 routing *is* the paper's SpMM with a one-nonzero-per-
row dispatch matrix A (tokens x expert-slots) — the hyper-sparse regime
where the paper measures the CS-3 losing to CPU because data movement
dominates useful FLOPs.  The communication-optimal realization of that
SpMM on a TPU mesh is therefore NOT a masked dense matmul (which would
stream the full zero-padded A, the paper's Fig. 8 worst case) but a
sort-based dispatch: group tokens by expert (the sort plays the role of
the paper's router re-bucketing), truncate to capacity, and run one
batched matmul per local expert.

Expert parallelism: experts shard over `model`; activations entering the
block are replicated across the TP group (the Megatron-SP gather point),
so each model-rank locally selects the tokens routed to ITS experts and
the partial outputs fold with the same psum a TP FFN needs — dispatch
costs zero extra collectives.  Crucially the dispatch sort/scatter runs
*inside shard_map*, per device: a global (pjit-level) sort of the token
stream would lower to a cross-chip sort network — measured at 269s of
collective time for llama4-scout train_4k before this restructure
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _he, activation, init_mlp, mlp
from repro.sharding import ctx as shard_ctx


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _he(ks[0], (d, e)),
        "w_in": _he(ks[1], (e, d, f), scale_dim=d),
        "w_out": _he(ks[2], (e, f, d), scale_dim=f),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _he(ks[3], (e, d, f), scale_dim=d)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, f=cfg.d_ff * cfg.n_shared_experts)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for layout friendliness


def _dispatch_compute(p_router, w_in, w_gate, w_out, xf, cfg: ModelConfig,
                      e_offset, e_local: int, cap: int):
    """Local sort-based dispatch over xf [T,d] for experts
    [e_offset, e_offset + e_local).  Returns (y [T,d] f32, aux scalar)."""
    t, d = xf.shape
    e = cfg.n_experts
    router_logits = (xf.astype(jnp.float32)
                     @ p_router.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_all, eid_all = jax.lax.top_k(probs, cfg.top_k)
    one_hot = jax.nn.one_hot(eid_all[:, 0], e, dtype=jnp.float32)
    aux = e * e * jnp.mean(one_hot.mean(0) * probs.mean(0))

    y = jnp.zeros((t, d), jnp.float32)
    for slot in range(cfg.top_k):
        eid = eid_all[:, slot] - e_offset  # local expert id (may be OOR)
        gate = gate_all[:, slot]
        mine = (eid >= 0) & (eid < e_local)
        eid_c = jnp.where(mine, eid, e_local)  # OOR -> overflow bin
        # --- local sort-based grouping (the paper's router re-bucketing) --
        order = jnp.argsort(eid_c * t + jnp.arange(t))
        eid_s = eid_c[order]
        counts = jnp.bincount(eid_c, length=e_local + 1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(t) - starts[eid_s]
        keep = (eid_s < e_local) & (rank < cap)
        dest = jnp.where(keep, eid_s * cap + rank, e_local * cap)
        buf = jnp.zeros((e_local * cap + 1, d), xf.dtype)
        buf = buf.at[dest].set(xf[order])
        buf = buf[: e_local * cap].reshape(e_local, cap, d)
        # --- expert compute (batched over local experts) -------------------
        h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(buf.dtype))
        h = activation(h, cfg.act)
        if w_gate is not None:
            h = h * jnp.einsum("ecd,edf->ecf", buf,
                               w_gate.astype(buf.dtype))
        out = jnp.einsum("ecf,efd->ecd", h, w_out.astype(buf.dtype))
        # --- combine (inverse scatter, gate-weighted) -----------------------
        flat = out.reshape(e_local * cap, d)
        src = jnp.where(keep, eid_s * cap + rank, 0)
        ys = jnp.where(keep[:, None], flat[src], 0).astype(jnp.float32)
        inv = jnp.zeros((t,), jnp.int32).at[order].set(
            jnp.arange(t, dtype=jnp.int32))
        y = y + (ys * gate[order][:, None])[inv]
    return y, aux


def moe_ffn(p, x, cfg: ModelConfig):
    """x: [B, S, d] -> ([B, S, d], aux load-balance loss)."""
    b, s, d = x.shape
    mesh = shard_ctx.current_mesh()
    ep_ok = (mesh is not None and "model" in mesh.axis_names
             and cfg.n_experts % mesh.shape["model"] == 0)

    if not ep_ok:
        xf = x.reshape(-1, d)
        cap = _capacity(xf.shape[0], cfg)
        y, aux = _dispatch_compute(
            p["router"], p["w_in"], p.get("w_gate"), p["w_out"], xf, cfg,
            e_offset=jnp.zeros((), jnp.int32), e_local=cfg.n_experts,
            cap=cap)
    else:
        tp = mesh.shape["model"]
        e_local = cfg.n_experts // tp
        batch_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        nb = int(np.prod([mesh.shape[a] for a in batch_ax])) if batch_ax \
            else 1
        b_ok = b % nb == 0 and b >= nb
        bspec = batch_ax if b_ok else None
        t_local = (b // nb if b_ok else b) * s
        cap = _capacity(t_local, cfg)

        has_gate = "w_gate" in p
        # experts stacked on a leading grouped axis for the model shards
        ws = [p["w_in"].reshape(tp, e_local, d, cfg.d_ff),
              p["w_out"].reshape(tp, e_local, cfg.d_ff, d)]
        if has_gate:
            ws.append(p["w_gate"].reshape(tp, e_local, d, cfg.d_ff))

        def local_fn(router, x_local, *ws_local):
            w_in = ws_local[0][0]
            w_out = ws_local[1][0]
            w_gate = ws_local[2][0] if has_gate else None
            rank = jax.lax.axis_index("model")
            xf = x_local.reshape(-1, d)
            yl, aux = _dispatch_compute(
                router, w_in, w_gate, w_out, xf, cfg,
                e_offset=rank * e_local, e_local=e_local, cap=cap)
            # fold partial expert outputs.  bf16 on the wire is ~lossless
            # here: with top-1 routing each token has exactly ONE nonzero
            # contribution across ranks, so the sum incurs a single
            # rounding — and halves the EP psum bytes (§Perf P3).
            # NB: the result must STAY bf16 downstream — an immediate
            # f32 upcast lets XLA's simplifier elide the convert pair and
            # run the all-reduce in f32 (P3 first attempt, refuted).
            yl = jax.lax.psum(yl.astype(x_local.dtype), "model")
            aux = jax.lax.pmean(aux, mesh.axis_names)
            return yl.reshape(x_local.shape[0], s, d), aux

        fn = shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(), P(bspec, None, None))
            + tuple(P("model") for _ in ws),
            out_specs=(P(bspec, None, None), P()),
            check_rep=False,
        )
        y3, aux = fn(p["router"], x, *ws)
        y = y3.reshape(-1, d)

    y = y.astype(x.dtype)  # (already x.dtype on the EP path — stays bf16)
    if "shared" in p:
        y = y + mlp(p["shared"], x.reshape(-1, d), cfg)
    return y.reshape(b, s, d), aux
