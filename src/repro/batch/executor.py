"""Bucketed batch executor: O(#buckets) compiles for arbitrary traffic.

``BucketedExecutor`` is the layer between "a kernel that wins on one
matrix" and "an engine that sustains traffic": it takes a micro-batch of
(graph, features) requests with arbitrary shapes, groups them by
:func:`bucket_for`, pads every graph of a group into its bucket, fills
the group to a quantized batch size with all-zero dummies, composes the
group block-diagonally, and runs **one** jitted executor per
(bucket, batch-size) key.  Executors live in an LRU cache; a trace
counter distinguishes compiles from cache hits, and a
:class:`PaddingWaste` ledger accounts the streamed-but-dead volume.

The execution path is planned once per bucket from the bucket's
canonical stats through the regular cost model (or forced by policy),
so the batched engine inherits the paper's sparsity-adaptive routing.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
from repro.dispatch.dispatcher import plan_spmm
from repro.dispatch.policy import PATH_CSR, PATH_ELL
from repro.resilience import chaos
from repro.resilience.errors import TRANSIENT, classify
from repro.sparse import paths
from repro.sparse.matrix import SparseMatrix
from repro.batch.block_diag import BatchedSparseMatrix
from repro.batch.bucketing import (Bucket, BucketingConfig,
                                   DEFAULT_BUCKETING, PaddingWaste,
                                   bucket_for, canonical_stats,
                                   empty_in_bucket, pad_to_bucket)

Array = Any

# fn(batched_matrix, stacked_features) -> stacked outputs [rows, d_out];
# with a `context` configured, fn(context, batched_matrix, features)
ExecutorFn = Callable[..., Array]


def _quantize_batch(n: int, max_batch: int) -> int:
    """Next power of two >= n, capped at max_batch."""
    b = 1
    while b < n and b < max_batch:
        b *= 2
    return min(b, max_batch)


@dataclasses.dataclass(frozen=True)
class ExecutorKey:
    bucket: Bucket
    batch: int
    d: int
    form: str

    @property
    def label(self) -> str:
        """Stable per-cell name; ``BucketedExecutor.lane_label`` prefixes
        it with the owning executor's id to form the sentry lane."""
        return f"{self.bucket.label}/b{self.batch}/d{self.d}/{self.form}"


_EXECUTOR_IDS = itertools.count()


class BucketedExecutor:
    """Shape-bucketed compilation cache over block-diagonal batches.

    ``fn(matrix, h)`` is the traced program (default: the planned SpMM
    ``matrix @ h`` forced to the bucket's cost-model path).  One jitted
    instance is kept per (bucket, quantized batch, d, form) key in an
    LRU of ``max_executors``.

    ``context`` (a pytree, e.g. model weights) is passed to ``fn`` as a
    leading argument *through* jit — as a traced input, not a closure
    constant — so many cached executors share one copy of the weights
    instead of each baking them in as XLA constants.
    """

    def __init__(self, fn: Optional[ExecutorFn] = None, *,
                 context: Any = None,
                 form: str = "auto",
                 policy: str = "auto",
                 max_batch: int = 32,
                 max_executors: int = 64,
                 bucketing: BucketingConfig = DEFAULT_BUCKETING,
                 cost_model: CostModel = DEFAULT_COST_MODEL,
                 ladder: Any = None,
                 jit: bool = True,
                 degrade_after: int = 3):
        if form not in ("auto", "csr", "ell"):
            raise ValueError(
                f"form must be 'auto', 'csr' or 'ell'; got {form!r}")
        if fn is None and context is not None:
            raise ValueError("context without fn has nothing to consume it")
        self._fn = fn
        self.context = context
        self.form = form
        self.policy = policy
        self.max_batch = int(max_batch)
        self.max_executors = int(max_executors)
        self.bucketing = bucketing
        self.cost_model = cost_model
        # opt-in traffic-fitted bucket grid (an AdaptiveBucketLadder,
        # see repro.serve.runtime.ladder); None = the fixed geometric
        # grid, which needs no warm-up and stays the default
        self.ladder = ladder
        self.jit = jit
        self._executors: "collections.OrderedDict[ExecutorKey, Callable]" \
            = collections.OrderedDict()
        # sentry lanes are namespaced per executor instance: each
        # instance holds its own jit cache, so two engines compiling the
        # same (bucket, batch, d, form) cell are two first compiles, not
        # a retrace
        self.uid = next(_EXECUTOR_IDS)
        self.compiles = 0       # executor traces (LRU misses + retraces)
        self.calls = 0          # batched dispatches
        self.requests = 0       # individual graphs served
        self.evictions = 0
        self.waste = PaddingWaste()
        # bucket plans made by choose_form, kept for the cost audit (the
        # serving-side predicted-vs-measured rows need the cost vector)
        self._bucket_plans: Dict[Tuple[Bucket, int], Any] = {}
        # degraded mode: a (bucket, d, form) cell that fails
        # `degrade_after` consecutive transient executions is excluded
        # from auto form selection until the process restarts — the
        # caller replans onto the surviving form (see note_failure)
        self.degrade_after = int(degrade_after)
        self._form_failures: Dict[Tuple[Bucket, int, str], int] = {}
        self._degraded: set = set()

    # -- planning -----------------------------------------------------------

    def bucket_of(self, stats) -> Bucket:
        """The compile-grid cell a request with these stats pads into
        (the learned ladder when one is configured, else the fixed
        geometric grid)."""
        if self.ladder is not None:
            self.ladder.observe(stats)
            return self.ladder.bucket_for(stats)
        return bucket_for(stats, self.bucketing)

    def choose_form(self, bucket: Bucket, d: int,
                    carried: Sequence[str]) -> Tuple[str, str]:
        """(form to pad, path to run) for one bucket."""
        if self.policy in ("csr", "ell"):
            if self.policy not in carried:
                raise ValueError(
                    f"policy {self.policy!r} forced but the group carries "
                    f"only {tuple(carried)}")
            return self.policy, self.policy
        if self.form in ("csr", "ell"):
            if self.form not in carried:
                raise ValueError(
                    f"form {self.form!r} requested but the group carries "
                    f"only {tuple(carried)}")
            form = self.form
        else:
            cand = tuple(p for p in (PATH_ELL, PATH_CSR) if p in carried)
            if not cand:
                raise ValueError(
                    f"group carries no bucketable form: {tuple(carried)}")
            # degraded mode: skip forms that kept failing in this cell,
            # unless that would leave no candidate at all
            healthy = tuple(p for p in cand
                            if (bucket, d, p) not in self._degraded)
            plan = plan_spmm(canonical_stats(bucket), d, policy=self.policy,
                             cost_model=self.cost_model,
                             candidates=healthy or cand)
            self._bucket_plans[(bucket, d)] = plan
            form = plan.path
        return form, form

    def note_failure(self, bucket: Bucket, d: int, form: str) -> bool:
        """Record one transient execution failure for a cell.  Returns
        True exactly when the cell's form newly crosses
        ``degrade_after`` consecutive failures and enters degraded mode
        (the caller should replan the traffic onto a surviving form)."""
        key = (bucket, d, form)
        if key in self._degraded:
            return False
        n = self._form_failures.get(key, 0) + 1
        self._form_failures[key] = n
        if n < self.degrade_after:
            return False
        self._degraded.add(key)
        obs.counter("resilience_degraded_total", form=form).inc()
        obs.counter("resilience_recoveries_total", site="degrade").inc()
        return True

    def note_success(self, bucket: Bucket, d: int, form: str) -> None:
        """A successful execution resets the consecutive-failure count
        (a degraded form stays degraded — re-probation would flap)."""
        self._form_failures.pop((bucket, d, form), None)

    def bucket_plan(self, bucket: Bucket, d: int):
        """The cost-model plan made for this (bucket, d) cell, when one
        was (forced forms/policies plan nothing)."""
        return self._bucket_plans.get((bucket, d))

    def lane_label(self, key: ExecutorKey) -> str:
        """The retrace-sentry lane for this cell in this executor's
        compile cache (see ``uid``)."""
        return f"x{self.uid}/{key.label}"

    def executor_for(self, key: ExecutorKey) -> Callable:
        """The jitted program serving one (bucket, batch, d, form) cell
        (LRU-cached; tracing bumps ``compiles``).  Public so runtimes
        that manage their own batch composition (the continuous engine)
        can share this compile cache."""
        return self._executor_for(key)

    def _executor_for(self, key: ExecutorKey) -> Callable:
        cached = self._executors.get(key)
        if cached is not None:
            self._executors.move_to_end(key)
            return cached

        path = key.form
        inner = self._fn

        def body(*args):
            if inner is not None:
                return inner(*args)
            mat, h = args
            from repro.sparse import ops

            return ops.matmul(mat, h, policy=path, candidates=(path,))

        lane = self.lane_label(key)
        if self.jit:
            def run(*args):
                # trace-time chaos first, so an injected compile failure
                # does not pollute the compile counters or the sentry
                chaos.hook("executor.compile", lane=lane)
                self.compiles += 1  # runs at trace time only
                obs.SENTRY.record_compile(lane)
                return body(*args)

            exe = jax.jit(run)
        else:
            self.compiles += 1  # eager mode: one "trace" per key
            obs.SENTRY.record_compile(lane)
            exe = body
        self._executors[key] = exe
        while len(self._executors) > self.max_executors:
            evicted, _ = self._executors.popitem(last=False)
            self.evictions += 1
            obs.counter("executor_evictions_total").inc()
            # an evicted lane legitimately recompiles on its next use
            obs.SENTRY.forget(self.lane_label(evicted))
        return exe

    # -- execution ----------------------------------------------------------

    def run(self, mats: Sequence[SparseMatrix], hs: Sequence[Array]
            ) -> List[np.ndarray]:
        """Serve one micro-batch of (graph, features) requests.

        Groups by bucket, pads, composes block-diagonally, executes one
        jitted program per group, and returns per-request outputs (rows
        trimmed back to each graph's logical node count) in input order.
        """
        if len(mats) != len(hs):
            raise ValueError(f"{len(mats)} graphs but {len(hs)} features")
        groups: Dict[Tuple[Bucket, int], List[int]] = {}
        hs = [jnp.asarray(h) for h in hs]
        with obs.span("serve.bucket", requests=len(mats),
                      grid="ladder" if self.ladder is not None else "fixed"):
            for i, (m, h) in enumerate(zip(mats, hs)):
                if m.stats is None:
                    raise ValueError(
                        "bucketed execution needs matrices with stats "
                        "(construct with SparseMatrix.from_dense/from_*)")
                if h.ndim != 2 or h.shape[0] != m.shape[1]:
                    raise ValueError(
                        f"request {i}: features {h.shape} do not match "
                        f"matrix {m.shape}")
                bucket = self.bucket_of(m.stats)
                groups.setdefault((bucket, int(h.shape[1])), []).append(i)
        out: List[Optional[np.ndarray]] = [None] * len(mats)
        for (bucket, d), idxs in groups.items():
            for chunk_start in range(0, len(idxs), self.max_batch):
                chunk = idxs[chunk_start:chunk_start + self.max_batch]
                self._run_group(bucket, d, chunk, mats, hs, out)
        return out  # type: ignore[return-value]

    def _run_group(self, bucket: Bucket, d: int, idxs: List[int],
                   mats, hs, out) -> None:
        carried = [f for f in ("ell", "csr")
                   if all(mats[i].has_form(f) for i in idxs)]
        form, path = self.choose_form(bucket, d, carried)
        bs = _quantize_batch(len(idxs), self.max_batch)
        dtype = hs[idxs[0]].dtype
        key = ExecutorKey(bucket=bucket, batch=bs, d=d, form=path)
        lane = self.lane_label(key)
        with obs.span("serve.compose", lane=lane, n=len(idxs)):
            padded = [pad_to_bucket(mats[i], bucket, form=form)
                      for i in idxs]
            feats = [paths.pad_rows(hs[i], bucket.cols) for i in idxs]
            while len(padded) < bs:
                padded.append(empty_in_bucket(bucket, form=form,
                                              dtype=dtype))
                feats.append(jnp.zeros((bucket.cols, d), dtype))
            B = BatchedSparseMatrix.from_matrices(padded, formats=(form,))
            h = jnp.concatenate(feats, axis=0)
        args = (B.matrix, h) if self.context is None \
            else (self.context, B.matrix, h)
        with obs.span("serve.execute", lane=lane):
            t0 = time.perf_counter()
            try:
                chaos.hook("executor.execute", lane=lane, form=path)
                y = self._executor_for(key)(*args)
                jax.block_until_ready(y)
            except Exception as exc:
                if classify(exc) == TRANSIENT:
                    self.note_failure(bucket, d, path)
                raise
            exec_ms = (time.perf_counter() - t0) * 1e3
        y = chaos.corrupt("executor.output", y, lane=lane)
        self.note_success(bucket, d, path)
        obs.SENTRY.record_call(lane)
        plan = self.bucket_plan(bucket, d)
        obs.AUDIT.record_raw(
            op="spmm", path=path, measured_ms=exec_ms, bucket=bucket.label,
            costs=plan.costs if plan is not None else None,
            policy=plan.policy if plan is not None else self.policy)
        self.calls += 1
        self.requests += len(idxs)
        real_nnz = sum(mats[i].stats.nnz for i in idxs)
        real_rows = sum(mats[i].shape[0] for i in idxs)
        self.waste.add(real_rows=real_rows, padded_rows=bs * bucket.rows,
                       real_nnz=real_nnz, padded_nnz=bs * bucket.nnz,
                       bucket=bucket)
        with obs.span("serve.complete", lane=lane, n=len(idxs)):
            for slot, i in enumerate(idxs):
                lo = slot * bucket.rows
                out[i] = np.asarray(y[lo:lo + mats[i].shape[0]])

    # -- reporting ----------------------------------------------------------

    def report(self) -> Dict[str, Any]:
        """Canonical keys (see DESIGN.md "Observability"); the old
        ``padding`` spelling resolves via a deprecation alias."""
        out = {
            "requests": self.requests,
            "calls": self.calls,
            "compiles": self.compiles,
            "executors_cached": len(self._executors),
            "evictions": self.evictions,
            "buckets": len({k.bucket for k in self._executors}),
            "waste": self.waste.as_dict(),
        }
        if self.ladder is not None:
            out["ladder"] = self.ladder.report()
        if self._degraded:
            out["degraded"] = sorted(
                f"{b.label}/d{d}/{f}" for b, d, f in self._degraded)
        return obs.renamed_keys(out, {"padding": "waste"})
