"""Batched multi-graph execution: block-diagonal composition, shape
bucketing, and the bucketed compilation cache.

    from repro.batch import BatchedSparseMatrix, BucketedExecutor

    B = BatchedSparseMatrix.from_matrices([A1, A2, A3])
    ys = B.unbatch(B @ B.batch_features([h1, h2, h3]))   # one SpMM

    ex = BucketedExecutor(max_batch=32)                  # O(#buckets)
    outs = ex.run(graphs, features)                      # compiles

The serving surface (bounded queue, micro-batch window, latency
reporting) is ``repro.serve.engine.BatchServingEngine``.
"""
from repro.batch.block_diag import (BatchedSparseMatrix, Segment,
                                    batch_matmul, batch_sddmm)
from repro.batch.bucketing import (Bucket, BucketingConfig,
                                   DEFAULT_BUCKETING, PaddingWaste,
                                   bucket_for, canonical_stats,
                                   empty_in_bucket, pad_to_bucket,
                                   quantize_up)
from repro.batch.executor import BucketedExecutor, ExecutorKey

__all__ = [
    "BatchedSparseMatrix", "Segment", "batch_matmul", "batch_sddmm",
    "Bucket", "BucketingConfig", "DEFAULT_BUCKETING", "PaddingWaste",
    "bucket_for", "canonical_stats", "empty_in_bucket", "pad_to_bucket",
    "quantize_up",
    "BucketedExecutor", "ExecutorKey",
]
