"""Block-diagonal batching of many ``SparseMatrix`` graphs.

The paper's driving workloads (GNN inference, recommendation) arrive as
streams of *small, variably-shaped* sparse problems.  One kernel launch
per tiny graph leaves the hardware idle between dispatches; the standard
bridge (Gale et al., *Sparse GPU Kernels for Deep Learning*) is to
compose N graphs into one **block-diagonal** operand

    B = diag(A_1, ..., A_N)

so the whole batch runs as a *single* planned SpMM / SDDMM through the
existing dispatch machinery.  Because every stored entry of B lives
inside one diagonal block, B @ H and B.sddmm(b, c) are exact — there is
no cross-graph mixing to correct for.

``BatchedSparseMatrix`` carries the composed ``SparseMatrix`` (CSR
and/or Block-ELL forms, concatenated with index offsets — never via
densification) plus static per-graph ``Segment`` offsets so results
split back out (``unbatch`` / ``unbatch_values``).  Segment metadata is
pytree aux data: jitting a batched product retraces only when the batch
*composition* changes shape, exactly like a single matrix.

Offsets use each graph's **padded** shape (``stats.shape``, a multiple
of the block size) so the element and blocked forms of one batch agree
on where graph i's rows/columns live.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BlockELL, SellCS
from repro.dispatch.stats import MatrixStats
from repro.sparse import paths
from repro.sparse.matrix import FORMATS, SparseMatrix

Array = Any


@dataclasses.dataclass(frozen=True)
class Segment:
    """Where one graph lives inside the batched (block-diagonal) space.

    ``row_start``/``col_start`` are offsets in the *padded* composition;
    ``rows``/``cols`` are the graph's padded extents, ``rows_logical``/
    ``cols_logical`` its true (unpadded) extents.  ``nnz`` and
    ``block_rows``/``ell_width`` drive the per-form value splits.
    """

    row_start: int
    col_start: int
    rows: int
    cols: int
    rows_logical: int
    cols_logical: int
    nnz: int
    block_rows: int
    ell_width: int
    # slot count of the graph's sell form (-1 = not carried): drives the
    # per-graph split of sell values in ``unbatch_values``
    sell_slots: int = -1


def _padded_shape(a: SparseMatrix) -> Tuple[int, int]:
    if a.stats is not None:
        return a.stats.shape
    return a.shape


def _common_formats(mats: Sequence[SparseMatrix]) -> Tuple[str, ...]:
    common = [f for f in FORMATS
              if all(m.has_form(f) for m in mats)]
    return tuple(f for f in ("ell", "sell", "csr") if f in common)


def _concat_csr(mats: Sequence[SparseMatrix],
                segments: Sequence[Segment]):
    rows, cols, vals = [], [], []
    for m, seg in zip(mats, segments):
        r, c, v = m.form("csr")
        rows.append(r + jnp.int32(seg.row_start))
        cols.append(c + jnp.int32(seg.col_start))
        vals.append(v)
    return (jnp.concatenate(rows), jnp.concatenate(cols),
            jnp.concatenate(vals))


def pad_ell_width(indices: Array, blocks: Array, width: int
                  ) -> Tuple[Array, Array]:
    """Widen ELL (indices, blocks) to ``width`` slots per block-row.

    Pad slots point at the row's slot-0 column (any valid id) and carry
    zero data — the Block-ELL padding contract.
    """
    pad = width - indices.shape[1]
    if pad <= 0:
        return indices, blocks
    return (
        jnp.concatenate(
            [indices, jnp.repeat(indices[:, :1], pad, axis=1)], axis=1),
        jnp.concatenate(
            [blocks, jnp.zeros(blocks.shape[:1] + (pad,) + blocks.shape[2:],
                               blocks.dtype)], axis=1),
    )


def _concat_ell(mats: Sequence[SparseMatrix],
                segments: Sequence[Segment],
                shape: Tuple[int, int]) -> BlockELL:
    ells = [m.form("ell") for m in mats]
    bms = {(e.bm, e.bn) for e in ells}
    if len(bms) != 1:
        raise ValueError(
            f"block-diagonal ELL needs one block size, got {sorted(bms)}")
    (bm, bn) = bms.pop()
    width = max(e.ell_width for e in ells)
    indices, blocks, nblocks = [], [], []
    for e, seg in zip(ells, segments):
        idx, blk = pad_ell_width(e.indices, e.blocks, width)
        indices.append(idx + jnp.int32(seg.col_start // bn))
        blocks.append(blk)
        nblocks.append(e.nblocks)
    return BlockELL(indices=jnp.concatenate(indices, axis=0),
                    blocks=jnp.concatenate(blocks, axis=0),
                    nblocks=jnp.concatenate(nblocks, axis=0),
                    shape=shape)


def _concat_sell(mats: Sequence[SparseMatrix],
                 segments: Sequence[Segment],
                 shape: Tuple[int, int]) -> SellCS:
    """Block-diagonal SELL-C-σ composition — pure index arithmetic.

    Each graph keeps its own slice packing (σ-window sorting stays
    per-graph, a valid SELL-C-σ with the graph as the window); slot and
    tile descriptors are concatenated with row/column/slot offsets and
    every sentinel is remapped to the composed sentinel.  No repacking,
    no host transfer of values.
    """
    sells = [m.form("sell") for m in mats]
    blocks = {(s.bm, s.bn) for s in sells}
    if len(blocks) != 1:
        raise ValueError(
            f"block-diagonal sell needs one tile size, got {sorted(blocks)}")
    (bm, bn) = blocks.pop()
    for seg in segments:
        if seg.col_start % bn:
            raise ValueError(
                f"column offset {seg.col_start} not aligned to bn={bn}")
    n_slots_total = sum(s.n_slots for s in sells)
    n_packed_total = sum(s.n_packed_rows for s in sells)
    n_live_total = sum(s.n_live_block_rows for s in sells)
    n_cells_total = sum(s.n_tiles for s in sells) * bm * bn
    m_total, _ = shape

    buckets = []
    slot_cols, slot_rows, slot_vals, perms = [], [], [], []
    tile_rows, tile_cols, tile_maps, slot_pos = [], [], [], []
    out_gather = jnp.full((m_total,), n_packed_total, jnp.int32)
    tile_out_gather = jnp.full((m_total,), n_live_total * bm, jnp.int32)
    row_off = slot_off = live_off = cell_off = 0
    for s, seg in zip(sells, segments):
        m_g = s.shape[0]
        for b_off, b_rows, b_width in s.buckets:
            buckets.append((b_off + row_off, b_rows, b_width))
        slot_cols.append(s.slot_cols + jnp.int32(seg.col_start))
        slot_rows.append(s.slot_rows + jnp.int32(seg.row_start))
        slot_vals.append(s.slot_vals)
        perms.append(jnp.where(s.perm == m_g, jnp.int32(m_total),
                               s.perm + jnp.int32(seg.row_start)))
        tile_rows.append(s.tile_rows + jnp.int32(live_off))
        tile_cols.append(s.tile_cols + jnp.int32(seg.col_start // bn))
        tile_maps.append(jnp.where(
            s.tile_slot_map == s.n_slots, jnp.int32(n_slots_total),
            s.tile_slot_map + jnp.int32(slot_off)))
        slot_pos.append(jnp.where(
            s.slot_tile_pos == s.n_tiles * bm * bn,
            jnp.int32(n_cells_total),
            s.slot_tile_pos + jnp.int32(cell_off)))
        og = jnp.where(s.out_gather == s.n_packed_rows,
                       jnp.int32(n_packed_total),
                       s.out_gather + jnp.int32(row_off))
        out_gather = out_gather.at[
            seg.row_start:seg.row_start + m_g].set(og)
        tog = jnp.where(s.tile_out_gather == s.n_live_block_rows * bm,
                        jnp.int32(n_live_total * bm),
                        s.tile_out_gather + jnp.int32(live_off * bm))
        tile_out_gather = tile_out_gather.at[
            seg.row_start:seg.row_start + m_g].set(tog)
        row_off += s.n_packed_rows
        slot_off += s.n_slots
        live_off += s.n_live_block_rows
        cell_off += s.n_tiles * bm * bn

    return SellCS(
        slot_cols=jnp.concatenate(slot_cols),
        slot_rows=jnp.concatenate(slot_rows),
        slot_vals=jnp.concatenate(slot_vals),
        out_gather=out_gather,
        perm=jnp.concatenate(perms),
        tile_rows=jnp.concatenate(tile_rows),
        tile_cols=jnp.concatenate(tile_cols),
        tile_slot_map=jnp.concatenate(tile_maps, axis=0),
        slot_tile_pos=jnp.concatenate(slot_pos),
        tile_out_gather=tile_out_gather,
        shape=shape,
        c=sells[0].c,
        sigma=sells[0].sigma,
        buckets=tuple(buckets),
        block=(bm, bn),
        n_live_block_rows=n_live_total,
    )


def _combined_stats(mats: Sequence[SparseMatrix],
                    shape: Tuple[int, int]) -> Optional[MatrixStats]:
    stats = [m.stats for m in mats]
    if any(s is None for s in stats):
        return None
    bm = max(s.block_m for s in stats)
    bn = max(s.block_n for s in stats)
    width = max(s.ell_width for s in stats)
    nbr = sum(s.n_block_rows for s in stats)
    stored = sum(s.stored_elements for s in stats)
    # slot-occupancy of the composed layout (streamed slots unchanged:
    # block-diag concatenation adds no padding beyond width alignment)
    occ = sum(s.occupancy * s.n_block_rows * max(s.ell_width, 1)
              for s in stats) / max(nbr * max(width, 1), 1)
    # sell slots concatenate exactly; unknown in any part poisons the sum
    sell_known = all(s.sell_stored_elements > 0 or s.nnz == 0
                     for s in stats)
    return MatrixStats(
        shape=shape,
        nnz=sum(s.nnz for s in stats),
        stored_elements=stored,
        block_m=bm,
        block_n=bn,
        n_block_rows=nbr,
        ell_width=width,
        occupancy=occ,
        sell_stored_elements=(sum(s.sell_stored_elements for s in stats)
                              if sell_known else 0),
    )


@jax.tree_util.register_pytree_node_class
class BatchedSparseMatrix:
    """N sparse graphs composed block-diagonally into one operand.

    ``B.matrix`` is a regular :class:`SparseMatrix` — every planned
    operator (``B @ H``, ``B.sddmm(b, c)``, gradients through both)
    works on the whole batch in one dispatch.  ``B.segments`` records
    the per-graph offsets for ``batch_features`` / ``unbatch``.
    """

    __slots__ = ("matrix", "segments")

    __array_priority__ = 1000
    __array_ufunc__ = None

    def __init__(self, matrix: SparseMatrix,
                 segments: Tuple[Segment, ...]):
        self.matrix = matrix
        self.segments = tuple(segments)

    # -- pytree plumbing ----------------------------------------------------

    def tree_flatten(self):
        return (self.matrix,), self.segments

    @classmethod
    def tree_unflatten(cls, aux, children):
        (matrix,) = children
        return cls(matrix, aux)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_matrices(cls, mats: Sequence[SparseMatrix], *,
                      formats: Optional[Tuple[str, ...]] = None,
                      stats: Optional[MatrixStats] = None,
                      ) -> "BatchedSparseMatrix":
        """Compose N matrices block-diagonally (no densification).

        ``formats`` picks which carried forms to compose (default: every
        form all inputs share, preferring ``("ell", "csr")``); each
        requested form is concatenated with index offsets directly.

        ``stats`` overrides the derived combined stats.  A continuous
        serving lane composes the *same* bucket geometry every step, so
        it computes the canonical combined stats once and passes them
        here — skipping the per-step host reduction and guaranteeing the
        jit aux is byte-identical across steps.
        """
        mats = list(mats)
        if not mats:
            raise ValueError("from_matrices needs at least one matrix")
        if formats is None:
            formats = _common_formats(mats)
            if not formats:
                raise ValueError(
                    "matrices share no common form; convert with .to() "
                    f"first (carried: {[m.formats for m in mats]})")
        for f in formats:
            missing = [i for i, m in enumerate(mats) if not m.has_form(f)]
            if missing:
                raise ValueError(
                    f"matrices {missing} carry no {f!r} form")
        segments: List[Segment] = []
        r0 = c0 = 0
        for m in mats:
            mp, np_ = _padded_shape(m)
            s = m.stats
            segments.append(Segment(
                row_start=r0, col_start=c0, rows=mp, cols=np_,
                rows_logical=m.shape[0], cols_logical=m.shape[1],
                nnz=s.nnz if s is not None else -1,
                block_rows=s.n_block_rows if s is not None else -1,
                ell_width=(m.form("ell").ell_width
                           if m.has_form("ell") else 0),
                sell_slots=(m.form("sell").n_slots
                            if m.has_form("sell") else -1),
            ))
            r0 += mp
            c0 += np_
        shape = (r0, c0)
        forms: Dict[str, Any] = {}
        for f in formats:
            if f == "csr":
                forms["csr"] = _concat_csr(mats, segments)
            elif f == "ell":
                forms["ell"] = _concat_ell(mats, segments, shape)
            elif f == "sell":
                forms["sell"] = _concat_sell(mats, segments, shape)
            else:
                raise ValueError(
                    f"cannot compose {f!r} block-diagonally; supported "
                    "forms: ('ell', 'sell', 'csr')")
        if stats is None:
            stats = _combined_stats(mats, shape)
        elif stats.shape != shape:
            raise ValueError(
                f"stats override has shape {stats.shape} but the "
                f"composition is {shape}")
        matrix = SparseMatrix(forms, shape, stats)
        return cls(matrix, tuple(segments))

    # -- metadata -----------------------------------------------------------

    @property
    def n_graphs(self) -> int:
        return len(self.segments)

    @property
    def shape(self) -> Tuple[int, int]:
        return self.matrix.shape

    @property
    def stats(self):
        return self.matrix.stats

    @property
    def formats(self) -> Tuple[str, ...]:
        return self.matrix.formats

    def __repr__(self) -> str:
        return (f"BatchedSparseMatrix(n_graphs={self.n_graphs}, "
                f"shape={self.shape}, formats={self.formats})")

    # -- feature stacking / result splitting --------------------------------

    def batch_features(self, hs: Sequence[Array]) -> Array:
        """Stack per-graph feature blocks [n_i, d] into the batched
        column space (zero rows fill each graph's block padding)."""
        if len(hs) != self.n_graphs:
            raise ValueError(
                f"got {len(hs)} feature blocks for {self.n_graphs} graphs")
        out = []
        for h, seg in zip(hs, self.segments):
            h = jnp.asarray(h)
            if h.ndim != 2:
                raise ValueError(
                    f"batch_features expects [n_i, d] blocks, got {h.shape}")
            if h.shape[0] != seg.cols_logical:
                raise ValueError(
                    f"feature block has {h.shape[0]} rows; graph has "
                    f"{seg.cols_logical} nodes")
            out.append(paths.pad_rows(h, seg.cols))
        return jnp.concatenate(out, axis=0)

    def unbatch(self, y: Array, *, space: str = "rows") -> List[Array]:
        """Split a batched row-space result (e.g. ``B @ H``) back into
        per-graph arrays, trimming each graph's padding."""
        if space not in ("rows", "cols"):
            raise ValueError(f"space must be 'rows' or 'cols', got {space!r}")
        out = []
        for seg in self.segments:
            if space == "rows":
                out.append(y[seg.row_start:seg.row_start + seg.rows_logical])
            else:
                out.append(y[seg.col_start:seg.col_start + seg.cols_logical])
        return out

    def unbatch_values(self, vals: Array, *, form: Optional[str] = None
                       ) -> List[Array]:
        """Split a batched values leaf (``B.matrix.data``, an SDDMM
        result, or a gradient w.r.t. the batched values) per graph.

        ``form`` names the layout the values are in (default: the
        batch's primary form).  Element (csr) values split by per-graph
        nnz; Block-ELL values split by block-rows with each graph's
        width padding trimmed back off.
        """
        form = form or self.matrix.format
        if form == "csr":
            if any(seg.nnz < 0 for seg in self.segments):
                raise ValueError(
                    "cannot split element values: a graph was composed "
                    "without stats (unknown nnz)")
            sizes = [seg.nnz for seg in self.segments]
            offs = np.cumsum([0] + sizes)
            return [vals[offs[i]:offs[i + 1]] for i in range(len(sizes))]
        if form == "ell":
            if any(seg.block_rows < 0 for seg in self.segments):
                raise ValueError(
                    "cannot split blocked values: a graph was composed "
                    "without stats (unknown block-row count)")
            width = self.matrix.form("ell").ell_width
            out = []
            row = 0
            for seg in self.segments:
                blk = vals[row:row + seg.block_rows]
                out.append(blk[:, :seg.ell_width] if seg.ell_width < width
                           else blk)
                row += seg.block_rows
            return out
        if form == "sell":
            if any(seg.sell_slots < 0 for seg in self.segments):
                raise ValueError(
                    "cannot split sell values: a graph was composed "
                    "without a sell form (unknown slot count)")
            offs = np.cumsum([0] + [seg.sell_slots
                                    for seg in self.segments])
            return [vals[offs[i]:offs[i + 1]]
                    for i in range(self.n_graphs)]
        raise ValueError(f"cannot split values of form {form!r}")

    # -- batched operators --------------------------------------------------

    def __matmul__(self, h):
        return self.matrix @ h

    def __rmatmul__(self, x):
        return x @ self.matrix

    def matmul(self, h, **kw):
        from repro.sparse import ops

        return ops.matmul(self.matrix, h, **kw)

    def sddmm(self, b, c, **kw) -> SparseMatrix:
        """Batched ``B ⊙ (b @ c)`` — one planned SDDMM for the batch."""
        return self.matrix.sddmm(b, c, **kw)


def batch_matmul(mats: Sequence[SparseMatrix], hs: Sequence[Array], *,
                 formats: Optional[Tuple[str, ...]] = None,
                 **kw) -> List[Array]:
    """One-shot helper: block-diag compose, run one SpMM, split back."""
    B = BatchedSparseMatrix.from_matrices(mats, formats=formats)
    y = B.matmul(B.batch_features(hs), **kw)
    return B.unbatch(y)


def batch_sddmm(B: BatchedSparseMatrix, bs: Sequence[Array],
                cs: Sequence[Array], **kw) -> List[Array]:
    """Batched attention scoring: one SDDMM over the block-diagonal
    composition, split back into per-graph sampled values.

    ``bs[i]``: [m_i, K] row factors; ``cs[i]``: [K, n_i] column factors.
    Because every stored entry of B is inside a diagonal block, the
    batched sample equals each graph's ``A_i ⊙ (b_i @ c_i)`` exactly.
    """
    if len(bs) != B.n_graphs or len(cs) != B.n_graphs:
        raise ValueError(
            f"got {len(bs)}/{len(cs)} factor blocks for {B.n_graphs} graphs")
    brows = []
    for b, seg in zip(bs, B.segments):
        b = jnp.asarray(b)
        if b.shape[0] != seg.rows_logical:
            raise ValueError(
                f"row factor has {b.shape[0]} rows; graph has "
                f"{seg.rows_logical}")
        brows.append(paths.pad_rows(b, seg.rows))
    ccols = []
    for c, seg in zip(cs, B.segments):
        c = jnp.asarray(c)
        if c.shape[1] != seg.cols_logical:
            raise ValueError(
                f"column factor has {c.shape[1]} columns; graph has "
                f"{seg.cols_logical}")
        ccols.append(paths.pad_cols(c, seg.cols))
    s = B.sddmm(jnp.concatenate(brows, axis=0),
                jnp.concatenate(ccols, axis=1), **kw)
    return B.unbatch_values(s.data, form=s.format)
