"""Shape bucketing: quantize variably-shaped graphs onto a compile grid.

Arbitrary serving traffic carries arbitrary (n_nodes, nnz, d) triples;
jitting one executor per exact shape compiles O(#requests) programs.
The bucketing compiler quantizes each dimension up onto a geometric grid
(growth factor ``growth`` per step, floored at the block size), pads the
graph *into* its bucket, and replaces its measured ``MatrixStats`` with
the bucket's **canonical stats** — a deterministic function of the
bucket geometry alone.  Two consequences:

  * every request in a bucket presents the *identical* jit cache key
    (same shapes, same static aux), so traffic compiles O(#buckets)
    executors, not O(#requests);
  * the dispatch path is planned once per bucket from the canonical
    stats, through the same cost model that plans single matrices.

Padding is the price: the counters in :class:`PaddingWaste` account the
streamed-but-dead volume (the batch-level analog of the paper's
padded-stream blow-up) so serving reports can show the tradeoff.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.batch.block_diag import pad_ell_width
from repro.core.formats import BlockELL, _cdiv
from repro.dispatch.stats import MatrixStats
from repro.sparse.matrix import SparseMatrix


@dataclasses.dataclass(frozen=True)
class BucketingConfig:
    """Geometry of the **fixed** geometric bucket grid.

    Note: the geometric grid is shape-oblivious — on real traffic it
    wastes 40–55 % of the streamed volume as padding (see
    ``BENCH_serve.json``).  Prefer the traffic-fitted quantile ladder
    (``repro.serve.runtime.AdaptiveBucketLadder``, opt-in via
    ``BatchServeConfig(adaptive=True)`` / ``ContinuousConfig``); the
    fixed grid remains the zero-warm-up default and the ladder's
    fallback before it has observed enough traffic to fit.
    """

    growth: float = 2.0        # geometric step between node-count buckets
    nnz_growth: float = 4.0    # coarser grid for nnz (correlates with n)
    min_rows: int = 32         # floor of the node grid
    min_nnz: int = 64          # floor of the nnz grid
    min_width: int = 1         # floor of the ELL-width grid


DEFAULT_BUCKETING = BucketingConfig()


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One cell of the compile grid (hashable; part of executor keys)."""

    rows: int       # padded node rows (multiple of block_m)
    cols: int       # padded node cols (multiple of block_n)
    nnz: int        # padded element count (csr form)
    width: int      # padded ELL width (ell form)
    block_m: int
    block_n: int

    @property
    def n_block_rows(self) -> int:
        return self.rows // self.block_m

    @property
    def label(self) -> str:
        """Stable human-readable key for per-bucket reporting."""
        return (f"r{self.rows}xc{self.cols}/nnz{self.nnz}/w{self.width}"
                f"/b{self.block_m}x{self.block_n}")


def quantize_up(x: int, base: int, growth: float) -> int:
    """Smallest grid point ``base * growth^k`` (k >= 0) at or above x."""
    if growth <= 1.0:
        raise ValueError(
            f"bucket growth must be > 1 (got {growth}); a growth of 1 "
            "would bucket per exact shape and compile per request")
    x = max(int(x), 1)
    base = max(int(base), 1)
    if x <= base:
        return base
    k = int(np.ceil(np.log(x / base) / np.log(growth)))
    q = int(round(base * growth ** k))
    while q < x:  # guard float rounding at the boundary
        q = int(round(q * growth))
    return q


def _round_to(x: int, mult: int) -> int:
    return _cdiv(max(int(x), 1), mult) * mult


def bucket_for(stats: MatrixStats,
               config: BucketingConfig = DEFAULT_BUCKETING) -> Bucket:
    """The bucket a matrix with these measured stats pads into."""
    bm, bn = stats.block_m, stats.block_n
    rows = _round_to(
        quantize_up(stats.shape[0], config.min_rows, config.growth), bm)
    cols = _round_to(
        quantize_up(stats.shape[1], config.min_rows, config.growth), bn)
    nnz = quantize_up(stats.nnz, config.min_nnz, config.nnz_growth)
    width = quantize_up(max(stats.ell_width, 1), config.min_width,
                        config.growth)
    return Bucket(rows=rows, cols=cols, nnz=nnz, width=width,
                  block_m=bm, block_n=bn)


def canonical_stats(bucket: Bucket) -> MatrixStats:
    """Deterministic stats of a bucket — identical for every request the
    bucket serves, so jitted executors never retrace on traffic."""
    nbr = bucket.n_block_rows
    slots = nbr * bucket.width
    # expected fraction of slots holding a real block if the bucket's
    # nnz were spread one-per-block (an upper bound on real occupancy)
    occ = min(1.0, bucket.nnz / max(slots, 1))
    return MatrixStats(
        shape=(bucket.rows, bucket.cols),
        nnz=bucket.nnz,
        stored_elements=slots * bucket.block_m * bucket.block_n,
        block_m=bucket.block_m,
        block_n=bucket.block_n,
        n_block_rows=nbr,
        ell_width=bucket.width,
        occupancy=occ,
    )


# ---------------------------------------------------------------------------
# Padding a matrix into its bucket
# ---------------------------------------------------------------------------


def _pad_csr_form(form, bucket: Bucket):
    r, c, v = form
    pad = bucket.nnz - r.shape[0]
    if pad < 0:
        raise ValueError(
            f"matrix has nnz={r.shape[0]} > bucket nnz={bucket.nnz}")
    if pad == 0:
        return form
    # dead entries at (0, 0) with value 0: they add exactly zero to any
    # product and their gradients are masked as structural zeros
    z = jnp.zeros((pad,), jnp.int32)
    return (jnp.concatenate([r, z]), jnp.concatenate([c, z]),
            jnp.concatenate([v, jnp.zeros((pad,), v.dtype)]))


def _pad_ell_form(ell: BlockELL, bucket: Bucket) -> BlockELL:
    if (ell.bm, ell.bn) != (bucket.block_m, bucket.block_n):
        raise ValueError(
            f"matrix block {(ell.bm, ell.bn)} != bucket block "
            f"{(bucket.block_m, bucket.block_n)}")
    nbr, w = ell.indices.shape
    if nbr > bucket.n_block_rows or w > bucket.width:
        raise ValueError(
            f"matrix ELL geometry ({nbr} rows, width {w}) exceeds bucket "
            f"({bucket.n_block_rows} rows, width {bucket.width})")
    idx, blk = pad_ell_width(ell.indices, ell.blocks, bucket.width)
    nbl = ell.nblocks
    if nbr < bucket.n_block_rows:
        pad = bucket.n_block_rows - nbr
        idx = jnp.concatenate(
            [idx, jnp.zeros((pad, bucket.width), jnp.int32)], axis=0)
        blk = jnp.concatenate(
            [blk, jnp.zeros((pad, bucket.width) + blk.shape[2:],
                            blk.dtype)], axis=0)
        nbl = jnp.concatenate([nbl, jnp.zeros((pad,), jnp.int32)])
    return BlockELL(indices=idx, blocks=blk, nblocks=nbl,
                    shape=(bucket.rows, bucket.cols))


def pad_to_bucket(a: SparseMatrix, bucket: Bucket, *,
                  form: Optional[str] = None) -> SparseMatrix:
    """Pad one matrix into its bucket and stamp the canonical stats.

    The result's shape, nnz, ELL geometry, and (crucially) static aux
    metadata depend only on ``bucket`` — every matrix padded into the
    same bucket is jit-cache-identical.
    """
    form = form or a.format
    if form == "csr":
        padded = {"csr": _pad_csr_form(a.form("csr"), bucket)}
    elif form == "ell":
        padded = {"ell": _pad_ell_form(a.form("ell"), bucket)}
    else:
        raise ValueError(
            f"cannot bucket-pad form {form!r}; supported: ('ell', 'csr')")
    return SparseMatrix(padded, (bucket.rows, bucket.cols),
                        canonical_stats(bucket))


def empty_in_bucket(bucket: Bucket, *, form: str,
                    dtype=jnp.float32) -> SparseMatrix:
    """An all-zero matrix padded into the bucket (batch-fill dummy)."""
    if form == "csr":
        z = jnp.zeros((bucket.nnz,), jnp.int32)
        padded = {"csr": (z, z, jnp.zeros((bucket.nnz,), dtype))}
    elif form == "ell":
        nbr = bucket.n_block_rows
        padded = {"ell": BlockELL(
            indices=jnp.zeros((nbr, bucket.width), jnp.int32),
            blocks=jnp.zeros((nbr, bucket.width, bucket.block_m,
                              bucket.block_n), dtype),
            nblocks=jnp.zeros((nbr,), jnp.int32),
            shape=(bucket.rows, bucket.cols))}
    else:
        raise ValueError(
            f"cannot build an empty {form!r} bucket matrix")
    return SparseMatrix(padded, (bucket.rows, bucket.cols),
                        canonical_stats(bucket))


# ---------------------------------------------------------------------------
# Padding-waste accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PaddingWaste:
    """Streamed-but-dead volume from bucket + batch-fill padding.

    Besides the aggregate counters, waste is broken down **per bucket**
    (keyed by :attr:`Bucket.label`) when callers tag their ``add`` with
    the bucket served — the aggregate ``waste_fraction`` hides *which*
    rungs of the grid are mis-sized, and the per-rung view is what the
    adaptive ladder is validated against.
    """

    real_rows: int = 0
    padded_rows: int = 0
    real_nnz: int = 0
    padded_nnz: int = 0
    per_bucket: Dict[str, "PaddingWaste"] = dataclasses.field(
        default_factory=dict)

    def add(self, *, real_rows: int, padded_rows: int, real_nnz: int,
            padded_nnz: int,
            bucket: Optional[Union[Bucket, str]] = None) -> None:
        self.real_rows += int(real_rows)
        self.padded_rows += int(padded_rows)
        self.real_nnz += int(real_nnz)
        self.padded_nnz += int(padded_nnz)
        # process-wide waste counters: every ledger instance also streams
        # into the obs registry, so one snapshot shows aggregate padding
        # without walking engines (per-bucket detail stays on the ledger)
        obs.counter("padding_rows_real_total").inc(int(real_rows))
        obs.counter("padding_rows_padded_total").inc(int(padded_rows))
        obs.counter("padding_nnz_real_total").inc(int(real_nnz))
        obs.counter("padding_nnz_padded_total").inc(int(padded_nnz))
        if bucket is not None:
            key = bucket if isinstance(bucket, str) else bucket.label
            sub = self.per_bucket.get(key)
            if sub is None:
                sub = self.per_bucket[key] = PaddingWaste()
            # direct field bumps: the sub-ledger must not re-stream the
            # volume into the process-wide obs counters
            sub.real_rows += int(real_rows)
            sub.padded_rows += int(padded_rows)
            sub.real_nnz += int(real_nnz)
            sub.padded_nnz += int(padded_nnz)

    @property
    def row_blowup(self) -> float:
        return self.padded_rows / max(self.real_rows, 1)

    @property
    def nnz_blowup(self) -> float:
        return self.padded_nnz / max(self.real_nnz, 1)

    @property
    def waste_fraction(self) -> float:
        """Fraction of streamed elements that are padding."""
        if self.padded_nnz == 0:
            return 0.0
        return 1.0 - self.real_nnz / self.padded_nnz

    def as_dict(self, *, per_bucket: bool = True) -> dict:
        out = {
            "real_rows": self.real_rows,
            "padded_rows": self.padded_rows,
            "real_nnz": self.real_nnz,
            "padded_nnz": self.padded_nnz,
            "row_blowup": round(self.row_blowup, 4),
            "nnz_blowup": round(self.nnz_blowup, 4),
            "waste_fraction": round(self.waste_fraction, 4),
        }
        if per_bucket and self.per_bucket:
            out["per_bucket"] = {
                k: self.per_bucket[k].as_dict(per_bucket=False)
                for k in sorted(self.per_bucket)
            }
        return out
