"""Per-instance plan memoization for ``SparseMatrix``.

A ``SparseMatrix`` carries one ``PlanCache`` in its static (aux) pytree
metadata.  The first ``A @ H`` for a given (op, width, policy, dtype)
resolves a dispatch ``Plan`` through the cost model / autotune machinery
and memoizes it; every later call with the same key skips re-planning.

The cache is deliberately *neutral* for jit purposes: two caches always
compare equal and hash alike, so the memo never forces a retrace — only
the matrix's shape/format/stats (the rest of the aux tuple) do.

Each cache also keeps its own hit/miss counters, so per-engine reports
(two serving engines in one process) never alias each other; the
module-level counters aggregate across all instances for the benchmark
harness.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Hashable, Optional

from repro import obs


@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0


# Process-global counters (all SparseMatrix instances).
GLOBAL_STATS = PlanCacheStats()


def plan_cache_stats() -> Dict[str, int]:
    """Aggregate plan-cache counters across every SparseMatrix."""
    return {"hits": GLOBAL_STATS.hits, "misses": GLOBAL_STATS.misses}


def reset_plan_cache_stats() -> None:
    GLOBAL_STATS.hits = 0
    GLOBAL_STATS.misses = 0


class PlanCache:
    """Mutable (key -> Plan) memo carried in pytree aux metadata.

    Equality/hash are constant so jit cache keys (which compare aux data)
    are insensitive to the memo's identity and contents.
    """

    __slots__ = ("entries", "hits", "misses")

    def __init__(self):
        self.entries: Dict[Hashable, Any] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable) -> Optional[Any]:
        plan = self.entries.get(key)
        if plan is None:
            self.misses += 1
            GLOBAL_STATS.misses += 1
            obs.counter("plan_cache_misses_total").inc()
        else:
            self.hits += 1
            GLOBAL_STATS.hits += 1
            obs.counter("plan_cache_hits_total").inc()
        return plan

    def put(self, key: Hashable, plan: Any) -> None:
        self.entries[key] = plan

    def stats(self) -> Dict[str, int]:
        """This instance's counters (see ``plan_cache_stats`` for the
        process-wide aggregate)."""
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self.entries)}

    def __len__(self) -> int:
        return len(self.entries)

    def __eq__(self, other) -> bool:
        return isinstance(other, PlanCache)

    def __hash__(self) -> int:
        return 17  # constant; see class docstring

    def __repr__(self) -> str:
        return f"PlanCache({len(self.entries)} plans)"
