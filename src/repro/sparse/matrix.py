"""`SparseMatrix` — one differentiable array type over every format.

A ``SparseMatrix`` wraps the repo's sparse storage formats behind one
pytree-registered interface:

  * ``"csr"`` — element-granular (row_ids, col_ids, values) device
    arrays, int32 indices (the expanded-CSR form every scalar path
    consumes);
  * ``"ell"`` — :class:`repro.core.formats.BlockELL` (the SELLPACK-like
    blocked streaming layout);
  * ``"sell"`` — :class:`repro.core.formats.SellCS` (SELL-C-σ: rows
    sorted by nnz within σ-windows, width-adaptive slices, live tiles
    only — the hyper-sparsity path);
  * ``"coo"`` — :class:`repro.core.formats.BlockCOO` (the SDDMM-side
    blocked layout, and the layout Block-ELL transposes into).

A matrix may carry several forms at once (e.g. a GNN adjacency holds
``("ell", "csr")`` so the dispatcher can route either path at jit trace
time).  Device data are pytree children; everything the planner needs —
logical shape, the format list, host-measured :class:`MatrixStats`, and
the per-instance plan memo — is static aux metadata, so ``jax.jit`` of
``lambda A, H: A @ H`` retraces only when shape/format/structure change,
never per call.

Operators: ``A @ H`` dispatches SpMM, ``A.sddmm(b, c)`` (or
``repro.sparse.sample``) dispatches SDDMM, ``A.T`` transposes (Block-ELL
transposes into Block-COO without host work, so it is trace-safe), and
both products are differentiable — see ``repro.sparse.autodiff`` for
the SpMM <-> SDDMM gradient duality.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR, BlockCOO, BlockELL, SellCS
from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
from repro.dispatch.policy import PATH_CSR, PATH_SELL
from repro.dispatch.stats import MatrixStats
from repro.sparse import paths
from repro.sparse.plan import PlanCache

Array = Any

FORMATS = ("ell", "sell", "coo", "csr")
# feature width assumed when from_dense(format="auto") prices the paths
_AUTO_FORMAT_D = 256  # the paper's SpMM setting (§4.1)

# Densified-form memo for concrete matrices, keyed on the id of the
# values leaf with a weakref finalizer for eviction (jax arrays are
# weakref-able but unhashable).  custom_vjp re-unflattens its pytree
# arguments (a fresh SparseMatrix per call), but the underlying array
# objects are passed through — so an instance-level memo would never
# hit, while this one survives reconstruction and dies with the array.
_DENSE_MEMO: Dict[int, Tuple[Tuple[int, ...], Any, Any]] = {}


def _leaf_ids(form) -> Tuple[int, ...]:
    return tuple(id(x) for x in jax.tree_util.tree_leaves(form))


def _dense_memo_get(vkey, form):
    hit = _DENSE_MEMO.get(id(vkey))
    if hit is not None and hit[0] == _leaf_ids(form):
        return hit[1]
    return None


def _dense_memo_put(vkey, form, out) -> None:
    k = id(vkey)
    try:
        wr = weakref.ref(vkey, lambda _ref: _DENSE_MEMO.pop(k, None))
    except TypeError:  # un-weakref-able leaf type (e.g. plain numpy)
        return
    _DENSE_MEMO[k] = (_leaf_ids(form), out, wr)


def _is_traced(*leaves) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in leaves)


def values_of(name: str, form) -> Array:
    """The differentiable data leaf of one form."""
    if name == "csr":
        return form[2]
    if name == "sell":
        return form.slot_vals
    return form.blocks


def with_values(name: str, form, vals: Array):
    """Same topology, new data leaf."""
    if name == "csr":
        return (form[0], form[1], vals)
    if name == "ell":
        return BlockELL(indices=form.indices, blocks=vals,
                        nblocks=form.nblocks, shape=form.shape)
    if name == "sell":
        return dataclasses.replace(form, slot_vals=vals)
    return BlockCOO(rows=form.rows, cols=form.cols, blocks=vals,
                    shape=form.shape)


def _blocked_stats(shape: Tuple[int, int], rows: np.ndarray,
                   cols: np.ndarray, bm: int, bn: int,
                   nnz: int) -> MatrixStats:
    """Blocked-layout stats from element coordinates (no blocks built)."""
    return MatrixStats.from_coords(shape, rows, cols, block_m=bm,
                                   block_n=bn, nnz=nnz)


def _transpose_stats(stats: Optional[MatrixStats]) -> Optional[MatrixStats]:
    if stats is None:
        return None
    bm, bn = stats.block_n, stats.block_m
    return MatrixStats(
        shape=(stats.shape[1], stats.shape[0]),
        nnz=stats.nnz,
        stored_elements=stats.stored_elements,
        block_m=bm,
        block_n=bn,
        n_block_rows=max(stats.shape[1] // max(bm, 1), 1),
        ell_width=0,
        occupancy=stats.occupancy,
    )


@jax.tree_util.register_pytree_node_class
class SparseMatrix:
    """One sparse matrix, any storage format, dispatch-ready.

    Construct with :meth:`from_dense` / :meth:`from_csr` /
    :meth:`from_blockell` / :meth:`from_blockcoo`; do not call the
    constructor with raw forms unless you know the pytree contract.
    """

    __slots__ = ("_forms", "shape", "stats", "_cache", "_transpose")

    # make `np_array @ A` defer to __rmatmul__ instead of numpy coercion
    __array_priority__ = 1000
    __array_ufunc__ = None

    def __init__(self, forms: Dict[str, Any], shape: Tuple[int, int],
                 stats: Optional[MatrixStats],
                 cache: Optional[PlanCache] = None):
        if not forms:
            raise ValueError("SparseMatrix needs at least one form")
        for name in forms:
            if name not in FORMATS:
                raise ValueError(
                    f"unknown format {name!r}; expected one of {FORMATS}")
        self._forms = dict(forms)
        self.shape = (int(shape[0]), int(shape[1]))
        self.stats = stats
        self._cache = cache if cache is not None else PlanCache()
        self._transpose: Optional["SparseMatrix"] = None

    # -- pytree plumbing ----------------------------------------------------

    def tree_flatten(self):
        names = tuple(self._forms)
        children = tuple(self._forms[n] for n in names)
        return children, (names, self.shape, self.stats, self._cache)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, shape, stats, cache = aux
        return cls(dict(zip(names, children)), shape, stats, cache=cache)

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_dense(cls, a, *, format: str = "auto",
                   formats: Optional[Tuple[str, ...]] = None,
                   block: Tuple[int, int] = (64, 64),
                   ell_width: Optional[int] = None,
                   cost_model: CostModel = DEFAULT_COST_MODEL,
                   ) -> "SparseMatrix":
        """Build from a concrete dense matrix.

        ``format="auto"`` measures the operand's blocked structure and
        picks the element form when the cost model predicts the scalar
        path wins (hyper-sparsity), the blocked form otherwise.
        ``formats`` overrides with an explicit multi-form tuple.
        """
        if _is_traced(a):
            raise TypeError(
                "SparseMatrix.from_dense needs a concrete (host) matrix; "
                "construct outside jit and pass the SparseMatrix in")
        a = np.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"expected a 2-D matrix, got shape {a.shape}")
        bm, bn = block
        rows, cols = np.nonzero(a)
        stats = _blocked_stats(a.shape, rows, cols, bm, bn, nnz=len(rows))
        if formats is None:
            if format == "auto":
                pick = CostModel.pick(
                    cost_model.spmm_costs(stats, _AUTO_FORMAT_D))
                format = {PATH_CSR: "csr", PATH_SELL: "sell"}.get(pick,
                                                                  "ell")
            formats = (format,)
        forms: Dict[str, Any] = {}
        for name in formats:
            if name == "ell":
                forms[name] = BlockELL.from_dense(a, bm, bn,
                                                  ell_width=ell_width)
            elif name == "sell":
                forms[name] = SellCS.from_dense(a, block=block)
            elif name == "coo":
                forms[name] = BlockCOO.from_dense(a, bm, bn)
            elif name == "csr":
                forms[name] = (
                    jnp.asarray(rows.astype(np.int32)),
                    jnp.asarray(cols.astype(np.int32)),
                    jnp.asarray(a[rows, cols]),
                )
            else:
                raise ValueError(
                    f"unknown format {name!r}; expected one of {FORMATS}")
        return cls(forms, a.shape, stats)

    @classmethod
    def from_csr(cls, csr: CSR, *, block: Tuple[int, int] = (64, 64)
                 ) -> "SparseMatrix":
        bm, bn = block
        row_ids, col_ids, vals = paths.csr_to_device_arrays(csr)
        stats = _blocked_stats(csr.shape, np.asarray(row_ids),
                               np.asarray(col_ids), bm, bn, nnz=csr.nnz)
        return cls({"csr": (row_ids, col_ids, vals)}, csr.shape, stats)

    @classmethod
    def from_blockell(cls, ell: BlockELL, *,
                      stats: Optional[MatrixStats] = None,
                      nnz: Optional[int] = None) -> "SparseMatrix":
        """Wrap an existing BlockELL.  For traced input pass ``stats``
        explicitly (or leave None and force a path at dispatch time)."""
        if stats is None and not _is_traced(ell.blocks, ell.indices):
            stats = MatrixStats.from_blockell(ell, nnz=nnz)
        return cls({"ell": ell}, ell.shape, stats)

    @classmethod
    def from_blockcoo(cls, coo: BlockCOO, *,
                      stats: Optional[MatrixStats] = None,
                      nnz: Optional[int] = None) -> "SparseMatrix":
        if stats is None and not _is_traced(coo.blocks, coo.rows):
            stats = MatrixStats.from_blockcoo(coo, nnz=nnz)
        return cls({"coo": coo}, coo.shape, stats)

    @classmethod
    def from_sellcs(cls, sell: SellCS, *,
                    stats: Optional[MatrixStats] = None) -> "SparseMatrix":
        """Wrap an existing SELL-C-σ packing (concrete input computes
        stats host-side; traced input needs ``stats`` or a forced path)."""
        if stats is None and not _is_traced(sell.slot_vals,
                                            sell.slot_rows):
            mask = np.asarray(sell.slot_vals) != 0
            rows = np.asarray(sell.slot_rows)[mask]
            cols = np.asarray(sell.slot_cols)[mask]
            stats = _blocked_stats(sell.shape, rows, cols,
                                   sell.bm, sell.bn, nnz=len(rows))
        return cls({"sell": sell}, sell.shape, stats)

    # -- basic metadata -----------------------------------------------------

    @property
    def format(self) -> str:
        """Primary format (the one ``.data`` / ``with_data`` address)."""
        return next(iter(self._forms))

    @property
    def formats(self) -> Tuple[str, ...]:
        return tuple(self._forms)

    def has_form(self, name: str) -> bool:
        return name in self._forms

    def form(self, name: str):
        """The raw container of one carried form."""
        if name not in self._forms:
            raise ValueError(
                f"matrix carries no {name!r} form (has {self.formats}); "
                "convert with .to()")
        return self._forms[name]

    @property
    def ndim(self) -> int:
        return 2

    @property
    def plan_cache(self) -> PlanCache:
        """This instance's plan memo (carries per-matrix hit/miss
        counters; see ``PlanCache.stats``)."""
        return self._cache

    @property
    def data(self) -> Array:
        """Differentiable values leaf of the primary form."""
        return values_of(self.format, self._forms[self.format])

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nnz(self) -> int:
        if self.stats is None:
            raise ValueError("matrix has no sparsity stats")
        return self.stats.nnz

    @property
    def density(self) -> float:
        if self.stats is None:
            raise ValueError("matrix has no sparsity stats")
        return self.stats.density

    @property
    def block(self) -> Tuple[int, int]:
        if self.stats is not None:
            return (self.stats.block_m, self.stats.block_n)
        return (64, 64)

    def nbytes(self) -> int:
        return sum(
            int(np.prod(np.shape(leaf))) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree_util.tree_leaves(self._forms))

    def __repr__(self) -> str:
        nnz = self.stats.nnz if self.stats is not None else "?"
        return (f"SparseMatrix(shape={self.shape}, formats={self.formats}, "
                f"nnz={nnz})")

    # -- data / topology edits ----------------------------------------------

    def with_data(self, values: Array) -> "SparseMatrix":
        """Same topology, new values on the *primary* form.

        Secondary forms are dropped (their values would go stale); the
        plan memo is shared — plans depend on structure, not values.
        """
        name = self.format
        form = with_values(name, self._forms[name], values)
        return SparseMatrix({name: form}, self.shape, self.stats,
                            cache=self._cache)

    def with_stats(self, stats: MatrixStats) -> "SparseMatrix":
        """Same forms and data, re-stated planner stats, fresh plan memo.

        Serving overlays (``repro.serve.runtime.DeltaGraph``) use this
        at repack/re-pricing boundaries: the stats are pytree aux, so a
        re-stat deliberately changes the jit cache key — the planner
        re-prices the matrix and consumers retrace once.  The plan memo
        is NOT shared (unlike :meth:`with_data`): memoized plans were
        priced off the old stats.
        """
        if stats is not None and (stats.shape[0] < self.shape[0]
                                  or stats.shape[1] < self.shape[1]):
            raise ValueError(
                f"stats shape {stats.shape} does not cover matrix shape "
                f"{self.shape} (stats carry the padded extent)")
        return SparseMatrix(self._forms, self.shape, stats)

    def pattern(self) -> "SparseMatrix":
        """0/1 mask of the primary form's nonzero entries (the sampling
        operand SDDMM and the backward pass work on)."""
        v = self.data
        return self.with_data(jnp.where(v != 0, jnp.ones_like(v),
                                        jnp.zeros_like(v)))

    def with_form(self, fmt: str) -> "SparseMatrix":
        """This matrix plus one more carried form (lazy: a no-op when
        ``fmt`` is already carried; host conversion otherwise).

        The added form makes its execution path a dispatch candidate;
        the plan memo is shared — plan keys include the candidate set,
        so cached plans stay correct.
        """
        if fmt in self._forms:
            return self
        converted = self.to(fmt)
        forms = dict(self._forms)
        forms[fmt] = converted._forms[fmt]
        return SparseMatrix(forms, self.shape, self.stats,
                            cache=self._cache)

    # -- transpose ----------------------------------------------------------

    @property
    def T(self) -> "SparseMatrix":
        if self._transpose is None:
            self._transpose = self._transposed()
            self._transpose._transpose = self
        return self._transpose

    def _transposed(self) -> "SparseMatrix":
        forms: Dict[str, Any] = {}
        for name, form in self._forms.items():
            if name == "csr":
                r, c, v = form
                forms["csr"] = (c, r, v)
            elif name == "sell":
                # a packed tile covers permuted (non-contiguous) rows,
                # so sell transposes element-granularly: the slot triplet
                # with coordinates swapped IS the transposed csr form
                # (duplicate padding coordinates carry zero values)
                forms.setdefault(
                    "csr", (form.slot_cols, form.slot_rows, form.slot_vals))
            else:
                coo = paths.ell_to_coo(form) if name == "ell" else form
                forms.setdefault("coo", paths.transpose_coo(coo))
        return SparseMatrix(forms, (self.shape[1], self.shape[0]),
                            _transpose_stats(self.stats))

    # -- conversions --------------------------------------------------------

    def densify(self) -> Array:
        """Dense jnp array (trace-safe device scatter from the primary
        form), trimmed to the logical shape.

        Memoized for concrete matrices so repeated dense-path dispatch
        pays the scatter once (traced leaves are never memoized — the
        result would capture another trace's tracers).
        """
        name = self.format
        form = self._forms[name]
        leaves = jax.tree_util.tree_leaves(form)
        concrete = not _is_traced(*leaves)
        vkey = values_of(name, form)
        if concrete:
            hit = _dense_memo_get(vkey, form)
            if hit is not None:
                return hit
        m, n = self.shape
        if name == "csr":
            out = paths.densify_elements(form[0], form[1], form[2], (m, n))
        elif name == "sell":
            out = paths.densify_sell(form)
        else:
            full = paths.densify_ell(form) if name == "ell" \
                else paths.densify_coo(form)
            out = full[:m, :n]
        if concrete and not isinstance(out, jax.core.Tracer):
            _dense_memo_put(vkey, form, out)
        return out

    def to_dense(self) -> np.ndarray:
        """Host numpy densification (concrete matrices only)."""
        return np.asarray(self.densify())

    def to(self, fmt: str) -> Any:
        """Convert to another format.

        Returns a (single-form) ``SparseMatrix`` for ``"ell"/"coo"/
        "csr"`` — reusing device arrays when the form is already carried
        — or a dense jnp array for ``"dense"``.  Host-side conversion of
        a missing form requires a concrete matrix.
        """
        if fmt == "dense":
            return self.densify()
        if fmt not in FORMATS:
            raise ValueError(
                f"unknown format {fmt!r}; expected 'dense' or {FORMATS}")
        if fmt in self._forms:
            return SparseMatrix({fmt: self._forms[fmt]}, self.shape,
                                self.stats, cache=self._cache)
        if _is_traced(*jax.tree_util.tree_leaves(self._forms)):
            raise TypeError(
                f"cannot convert a traced matrix to {fmt!r}; convert "
                "outside jit (only carried forms are trace-safe)")
        dense = self.to_dense()
        bm, bn = self.block
        if fmt == "ell":
            return SparseMatrix({"ell": BlockELL.from_dense(dense, bm, bn)},
                                self.shape, self.stats, cache=self._cache)
        if fmt == "sell":
            return SparseMatrix(
                {"sell": SellCS.from_dense(dense, block=(bm, bn))},
                self.shape, self.stats, cache=self._cache)
        if fmt == "coo":
            return SparseMatrix({"coo": BlockCOO.from_dense(dense, bm, bn)},
                                self.shape, self.stats, cache=self._cache)
        rows, cols = np.nonzero(dense)
        form = (jnp.asarray(rows.astype(np.int32)),
                jnp.asarray(cols.astype(np.int32)),
                jnp.asarray(dense[rows, cols]))
        return SparseMatrix({"csr": form}, self.shape, self.stats,
                            cache=self._cache)

    # -- operators ----------------------------------------------------------

    def __matmul__(self, h):
        if isinstance(h, SparseMatrix):
            return NotImplemented
        from repro.sparse import ops

        return ops.matmul(self, h)

    def matmul(self, h, *, epilogue=None, bias=None, residual=None, **kw):
        """``A @ H`` with an optional fused epilogue.

        ``A.matmul(h, epilogue="relu", bias=b)`` computes
        ``relu(A @ h + b)`` with the elementwise tail fused into the
        SpMM (applied to the kernel accumulator before the output
        flush).  See :func:`repro.sparse.ops.matmul`.
        """
        from repro.sparse import ops

        return ops.matmul(self, h, epilogue=epilogue, bias=bias,
                          residual=residual, **kw)

    def __rmatmul__(self, x):
        from repro.sparse import ops

        x = jnp.asarray(x)
        if x.ndim == 1:
            return ops.matmul(self.T, x)
        if x.ndim != 2:
            return NotImplemented
        return ops.matmul(self.T, x.T).T

    def sddmm(self, b, c, **kw) -> "SparseMatrix":
        """``self ⊙ (b @ c)`` at this matrix's stored entries."""
        from repro.sparse import ops

        return ops.sddmm(self, b, c, **kw)
