"""Unified differentiable sparse-matrix API.

One pytree-registered array type over the repo's sparse formats, with
operator dispatch through the sparsity-adaptive cost-model/autotune
machinery and ``custom_vjp`` gradients that realize the paper's kernel
duality (SpMM's backward is SDDMM and vice versa):

    from repro.sparse import SparseMatrix, sample

    A = SparseMatrix.from_dense(a, format="auto")   # measured structure
    y = A @ h                                       # SpMM, planned once
    s = sample(A.pattern(), b, c)                   # SDDMM at A's nnz
    g = jax.grad(lambda v: loss(A.with_data(v) @ h))(A.data)

See DESIGN.md "Public API" for the conversion table, operator
semantics, gradient rules, and the legacy-surface deprecation timeline.
"""
from repro.kernels.fused.epilogue import Epilogue
from repro.sparse.matrix import FORMATS, SparseMatrix
from repro.sparse.ops import (available_paths, fused_graph_attention,
                              matmul, sample, sddmm, spmv)
from repro.sparse.plan import (PlanCache, plan_cache_stats,
                               reset_plan_cache_stats)

spmm = matmul  # functional alias mirroring the legacy free function

__all__ = [
    "Epilogue", "FORMATS", "SparseMatrix",
    "available_paths", "fused_graph_attention", "matmul", "sample",
    "sddmm", "spmm", "spmv",
    "PlanCache", "plan_cache_stats", "reset_plan_cache_stats",
]
