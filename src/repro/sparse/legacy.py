"""Legacy-surface shim helpers: kwarg coercion + deprecation warnings.

This is the ONE place that interprets the historical ``use_kernel=`` /
``interpret=`` keyword pattern (previously duplicated across
core/spmm.py, core/sddmm.py, and dispatch/dispatcher.py): passing either
kwarg explicitly forces the blocked ("ell") path, because the kwargs
parameterize that path and requesting them implies it.

``warn_deprecated`` is the single DeprecationWarning emitter for the old
free-function surface; the message always carries the one-line migration
hint to ``repro.sparse``.

Deprecation timeline (see DESIGN.md "Public API"):

  * this PR      — ``core.spmm.spmm`` / ``core.sddmm.sddmm`` /
                   ``dispatch.SparseOperand`` warn and forward.
  * +2 PRs       — the legacy free functions stop accepting
                   ``use_kernel=`` / ``interpret=``.
  * +4 PRs       — the shims are removed; ``repro.sparse`` is the only
                   public sparse-matmul surface.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

from repro.dispatch.policy import (PATH_ELL, POLICY_AUTO, POLICY_AUTOTUNE,
                                   normalize_policy)

_MIGRATION_HINT = ("migrate to repro.sparse: "
                   "A = SparseMatrix.from_dense(a); A @ h / A.sddmm(b, c)")


def warn_deprecated(name: str, hint: str = _MIGRATION_HINT) -> None:
    """Emit the single DeprecationWarning for a legacy entry point."""
    warnings.warn(f"{name} is deprecated; {hint}",
                  DeprecationWarning, stacklevel=3)


def coerce_kernel_kwargs(
    policy: str,
    use_kernel: Optional[bool],
    interpret: Optional[bool],
) -> Tuple[str, Optional[bool], bool, bool]:
    """Normalize policy and apply the legacy kernel-kwarg rule.

    Returns ``(policy, use_kernel, interpret, kernel_forced)`` where
    ``kernel_forced`` records whether the caller passed either kwarg
    explicitly (which forces the blocked path under auto policies, so
    legacy ``spmm(ell, h, use_kernel=False)`` call sites stay
    meaningful).
    """
    kernel_forced = use_kernel is not None or interpret is not None
    policy = normalize_policy(policy)
    if kernel_forced and policy in (POLICY_AUTO, POLICY_AUTOTUNE):
        policy = PATH_ELL
    return policy, use_kernel, bool(interpret), kernel_forced
