"""Planned SpMM / SDDMM front-ends for ``SparseMatrix``.

``matmul`` (what ``A @ H`` calls) and ``sddmm`` (what ``A.sddmm(b, c)``
/ ``repro.sparse.sample`` call) resolve an execution path through the
sparsity-adaptive machinery in ``repro.dispatch`` — the analytic cost
model for ``policy="auto"``, the timed autotune cache for
``policy="autotune"``, or a forced path — then run the differentiable
``custom_vjp`` primitives in ``repro.sparse.autodiff``.

Plans are memoized per matrix instance (see ``repro.sparse.plan``):
the first call for a given (op, width, policy, dtype) plans, every
later call hits the memo and goes straight to execution.  Planning is
host logic over static ``MatrixStats`` aux metadata, so it happens at
``jax.jit`` trace time and is baked into the traced program.

Candidate paths follow the forms a matrix carries: ``ell`` (blocked)
needs an ``"ell"``/``"coo"`` form, ``sell`` (SELL-C-σ, the
hyper-sparsity path) a ``"sell"`` form, ``csr`` (element) a ``"csr"``
form; ``dense`` densifies on device and is always available.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import dataclasses

from repro.dispatch import autotune as autotune_mod
from repro.dispatch.autotune import AutotuneCache, make_key, measure
from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
from repro.dispatch.dispatcher import (Plan, plan_fused_attention,
                                       plan_sddmm, plan_spmm, plan_spmv,
                                       record_plan)
from repro.dispatch.policy import (DEFAULT_CONFIG, DispatchConfig, PATHS,
                                   PATH_CSR, PATH_DENSE, PATH_ELL,
                                   PATH_FUSED_ATTN, PATH_SELL, POLICY_AUTO,
                                   POLICY_AUTOTUNE, normalize_policy)
from repro.kernels.fused.epilogue import normalize_epilogue
from repro.sparse import autodiff
from repro.sparse.matrix import SparseMatrix, with_values


def _default_use_kernel(config: DispatchConfig) -> bool:
    if config.use_kernel is not None:
        return config.use_kernel
    return jax.default_backend() == "tpu"


def _is_traced(*operands) -> bool:
    return any(isinstance(x, jax.core.Tracer)
               for x in jax.tree_util.tree_leaves(list(operands)))


def available_paths(a: SparseMatrix) -> Tuple[str, ...]:
    """Execution paths the matrix's carried forms can run."""
    cand = []
    if "ell" in a._forms or "coo" in a._forms:
        cand.append(PATH_ELL)
    if "sell" in a._forms:
        cand.append(PATH_SELL)
    if "csr" in a._forms:
        cand.append(PATH_CSR)
    cand.append(PATH_DENSE)  # device densify works for every form
    return tuple(cand)


def _resolve_plan(op: str, a: SparseMatrix, inner_dim, ref_dtype,
                  policy: str, cand: Tuple[str, ...], uk: bool,
                  interpret: bool, cost_model: CostModel,
                  config: DispatchConfig,
                  autotune_cache: Optional[AutotuneCache],
                  exec_thunk, concrete: bool,
                  key_extra: Tuple = (),
                  fused: Optional[str] = None) -> Plan:
    """Resolve (and memoize) one dispatch plan.

    ``inner_dim`` is the operand feature width — an int for spmm/sddmm,
    a ``(k, d)`` pair for the fused attention op.  ``key_extra`` folds
    op-specific static config (e.g. the epilogue spec) into the memo
    key; ``fused`` tags the resulting plan for the dispatch log.
    """
    inner_key = tuple(int(x) for x in inner_dim) \
        if isinstance(inner_dim, tuple) else int(inner_dim)
    key = (op, inner_key, policy, str(ref_dtype), cand, uk, interpret,
           cost_model) + tuple(key_extra)
    if policy == POLICY_AUTOTUNE:
        # a trace-time autotune downgrades to the cost model; keep its
        # memo separate so it never masks a real (concrete) timing pass
        key += (concrete,)
    plan = a._cache.get(key)
    if plan is not None:
        return plan
    if policy in PATHS:
        if policy not in cand:
            raise ValueError(
                f"policy {policy!r} not among available paths {cand}")
        plan = Plan(op=op, path=policy, policy=policy, reason="forced",
                    use_kernel=uk, interpret=interpret, stats=a.stats)
    else:
        if a.stats is None:
            raise ValueError(
                f"{op}: matrix has no sparsity stats; construct it with "
                "SparseMatrix.from_dense/from_* (concrete) or force a "
                "path policy")
        # autotune must never time tracer thunks (it would cache trace-
        # construction time); any traced operand downgrades to the cost
        # model, exactly like plan_* does for pure planning
        if policy == POLICY_AUTOTUNE and concrete:
            cache = autotune_cache if autotune_cache is not None \
                else autotune_mod.GLOBAL_CACHE
            # the timing key must see the same static config as the plan
            # memo (a fused-epilogue thunk is a different computation),
            # stringified so the cache stays JSON-serializable
            akey = make_key(op, a.stats.shape, sum(inner_key)
                            if isinstance(inner_key, tuple) else inner_key,
                            ref_dtype, a.stats.density,
                            buckets_per_decade=config.buckets_per_decade) \
                + tuple(str(x) for x in key_extra)
            hit = cache.get(akey)
            if hit is None:
                hit = measure({p: exec_thunk(p) for p in cand},
                              warmup=config.autotune_warmup,
                              iters=config.autotune_iters)
                cache.put(akey, hit)
                reason = "autotune: measured " + ", ".join(
                    f"{p}={t:.0f}us"
                    for p, t in sorted(hit.timings_us.items()))
            else:
                reason = "autotune: cached winner"
            path = hit.path
            if path not in cand:  # cache shared across operands with
                finite = {p: t for p, t in hit.timings_us.items()
                          if p in cand}  # different carried forms
                path = min(finite, key=finite.get) if finite else cand[0]
            plan = Plan(op=op, path=path, policy=POLICY_AUTOTUNE,
                        reason=reason, use_kernel=uk, interpret=interpret,
                        timings_us=hit.timings_us, stats=a.stats)
        elif op == PATH_FUSED_ATTN:
            plan = plan_fused_attention(
                a.stats, inner_dim[0], inner_dim[1], policy=policy,
                cost_model=cost_model, config=config, use_kernel=uk,
                interpret=interpret, candidates=cand)
        elif op == "spmv":
            plan = plan_spmv(a.stats, policy=policy,
                             cost_model=cost_model, config=config,
                             use_kernel=uk, interpret=interpret,
                             candidates=cand)
        else:
            planner = plan_spmm if op == "spmm" else plan_sddmm
            plan = planner(a.stats, inner_dim, policy=policy,
                           cost_model=cost_model, config=config,
                           use_kernel=uk, interpret=interpret,
                           candidates=cand)
    if fused is not None and plan.fused != fused:
        plan = dataclasses.replace(plan, fused=fused)
    a._cache.put(key, plan)
    return plan


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------


def matmul(
    a: SparseMatrix,
    h,
    *,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    bd: Optional[int] = None,
    out_dtype=None,
    epilogue=None,
    bias=None,
    residual=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
):
    """Y = A @ H through the unified sparse front-end (differentiable).

    ``epilogue`` fuses an elementwise tail into the product:
    ``Y = act(A @ H + bias + residual)`` with ``act`` one of
    ``"identity" | "relu" | "leaky_relu"`` (or a full
    :class:`repro.kernels.fused.Epilogue` spec).  The kernel execution
    paths apply it to the VMEM accumulator before the single output
    flush; reference paths compose it elementwise — either way the raw
    product never makes a dedicated round-trip through memory, and the
    whole pipeline stays differentiable (bias/residual get cotangents).
    """
    if not isinstance(a, SparseMatrix):
        raise TypeError(f"matmul expects a SparseMatrix, got {type(a)}")
    h = jnp.asarray(h)
    h_was_1d = h.ndim == 1
    if h_was_1d and epilogue is None and bias is None and residual is None:
        # vector operand with no fused tail: take the SpMV fast lane
        # (direct per-layout reductions, no [N, 1] tile machinery)
        return spmv(a, h, policy=policy, candidates=candidates,
                    use_kernel=use_kernel, interpret=interpret,
                    out_dtype=out_dtype, cost_model=cost_model,
                    config=config, autotune_cache=autotune_cache)
    if h_was_1d:
        h = h[:, None]
        if residual is not None and jnp.ndim(residual) == 1:
            residual = residual[:, None]
    if h.ndim != 2:
        raise ValueError(f"spmm: H must be 1-D or 2-D, got shape {h.shape}")
    if h.shape[0] != a.shape[1]:
        raise ValueError(
            f"spmm: H has {h.shape[0]} rows but A has {a.shape[1]} "
            f"columns (A shape {a.shape})")
    if bias is not None:
        # canonicalize to a [D] vector (scalars broadcast) so every
        # execution path — and the bwd cotangent — sees one shape
        bias = jnp.asarray(bias)
        if bias.ndim == 0:
            bias = jnp.broadcast_to(bias, (h.shape[1],))
        if bias.shape != (h.shape[1],):
            raise ValueError(
                f"spmm epilogue: bias must be a scalar or a [{h.shape[1]}]"
                f" vector, got shape {bias.shape}")
    if residual is not None:
        residual = jnp.asarray(residual)
        if residual.shape != (a.shape[0], h.shape[1]):
            raise ValueError(
                f"spmm epilogue: residual must be output-shaped "
                f"[{a.shape[0]}, {h.shape[1]}], got {residual.shape}")
    epi = normalize_epilogue(epilogue, bias, residual)
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        if epi is None:
            return lambda: autodiff.spmm_exec((p, uk, interpret, bd, odt),
                                              a, h)
        return lambda: autodiff.spmm_epilogue_exec(
            (p, uk, interpret, bd, odt, epi), a, h, bias, residual)

    plan = _resolve_plan("spmm", a, h.shape[1], h.dtype, policy, cand, uk,
                         interpret, cost_model, config, autotune_cache,
                         exec_thunk,
                         concrete=not _is_traced(a, h, bias, residual),
                         key_extra=() if epi is None else (epi,),
                         fused=None if epi is None else epi.describe())
    record_plan(plan)
    if epi is None:
        y = autodiff.spmm(
            (plan.path, plan.use_kernel, plan.interpret, bd, odt), a, h)
    else:
        y = autodiff.spmm_epilogue(
            (plan.path, plan.use_kernel, plan.interpret, bd, odt, epi),
            a, h, bias, residual)
    return y[:, 0] if h_was_1d else y


# ---------------------------------------------------------------------------
# SpMV
# ---------------------------------------------------------------------------


def spmv(
    a: SparseMatrix,
    x,
    *,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
):
    """y = A @ x for a [N] vector, through the unified front-end.

    The dedicated d = 1 entry: plans on the SpMM cost surface at unit
    feature width (op tag ``"spmv"`` in the dispatch log) and executes
    direct per-layout reductions — no kernel grids, no D-padding, no
    epilogue plumbing.  ``matmul`` delegates its 1-D branch here, so
    ``A @ v`` gets this lane automatically.  Differentiable: the
    backward is the same SpMM duality at d = 1 (dx = Aᵀ ḡ, dA a rank-1
    SDDMM).
    """
    if not isinstance(a, SparseMatrix):
        raise TypeError(f"spmv expects a SparseMatrix, got {type(a)}")
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"spmv: x must be 1-D, got shape {x.shape}")
    if x.shape[0] != a.shape[1]:
        raise ValueError(
            f"spmv: x has {x.shape[0]} rows but A has {a.shape[1]} "
            f"columns (A shape {a.shape})")
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        return lambda: autodiff.spmv_exec((p, uk, interpret, None, odt),
                                          a, x)

    plan = _resolve_plan("spmv", a, 1, x.dtype, policy, cand, uk,
                         interpret, cost_model, config, autotune_cache,
                         exec_thunk, concrete=not _is_traced(a, x))
    record_plan(plan)
    return autodiff.spmv(
        (plan.path, plan.use_kernel, plan.interpret, None, odt), a, x)


# ---------------------------------------------------------------------------
# SDDMM
# ---------------------------------------------------------------------------


def sddmm(
    a: SparseMatrix,
    b,
    c,
    *,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    bk: Optional[int] = None,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
) -> SparseMatrix:
    """S = A ⊙ (B @ C) at A's stored entries (differentiable).

    Returns a single-form ``SparseMatrix`` sharing A's topology, in the
    layout of the form the planned path read; ``S.data`` holds the
    sampled values (element order for the csr path — what GAT's
    segment-softmax consumes).
    """
    if not isinstance(a, SparseMatrix):
        raise TypeError(f"sddmm expects a SparseMatrix, got {type(a)}")
    b = jnp.asarray(b)
    c = jnp.asarray(c)
    if b.shape[0] != a.shape[0]:
        raise ValueError(
            f"sddmm: B has {b.shape[0]} rows but A has {a.shape[0]}")
    if c.shape[1] != a.shape[1]:
        raise ValueError(
            f"sddmm: C has {c.shape[1]} columns but A has {a.shape[1]}")
    if b.shape[1] != c.shape[0]:
        raise ValueError(
            f"sddmm: inner dims disagree: B {b.shape} vs C {c.shape}")
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        return lambda: autodiff.sddmm_values(
            (p, uk, interpret, bk, odt), a, b, c)

    plan = _resolve_plan("sddmm", a, b.shape[1], b.dtype, policy, cand, uk,
                         interpret, cost_model, config, autotune_cache,
                         exec_thunk, concrete=not _is_traced(a, b, c))
    record_plan(plan)
    vals = autodiff.sddmm_values(
        (plan.path, plan.use_kernel, plan.interpret, bk, odt), a, b, c)
    form_name = autodiff.form_read_by(a, plan.path)
    return SparseMatrix(
        {form_name: with_values(form_name, a._forms[form_name], vals)},
        a.shape, a.stats, cache=a._cache)


# the paper's naming for the masked product
sample = sddmm


# ---------------------------------------------------------------------------
# Fused graph attention (one-pass SDDMM → edge act → softmax → SpMM)
# ---------------------------------------------------------------------------


def fused_graph_attention(
    a: SparseMatrix,
    q,
    k,
    v,
    *,
    edge_act: str = "leaky_relu",
    negative_slope: float = 0.2,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
):
    """Y = softmax_row(act(q kᵀ ⊙ pattern(A))) @ V, in one dispatch.

    The whole GAT aggregation — score the edges (SDDMM at A's nonzero
    pattern), activate, segment-softmax each row, aggregate V (SpMM) —
    runs as ONE planned pipeline: a single plan in ``dispatch_log()``,
    and on the blocked kernel paths a single pass over the topology's
    live tiles with the softmax statistics resident in VMEM (the
    E-length edge-score vector never exists in HBM).

    ``q``: [M, dk] / ``k``: [N, dk] score factors (1-D inputs are
    treated as single-column), ``v``: [N, D] values.  A contributes its
    structural nonzeros only (values are not read).  Differentiable in
    q, k, v via a ``custom_vjp`` that reassembles the backward from the
    SpMM/SDDMM duality plus the softmax Jacobian-vector trick.
    """
    if not isinstance(a, SparseMatrix):
        raise TypeError(
            f"fused_graph_attention expects a SparseMatrix, got {type(a)}")
    q = jnp.asarray(q)
    k = jnp.asarray(k)
    v = jnp.asarray(v)
    if q.ndim == 1:
        q = q[:, None]
    if k.ndim == 1:
        k = k[:, None]
    v_was_1d = v.ndim == 1
    if v_was_1d:
        v = v[:, None]
    if q.shape[0] != a.shape[0]:
        raise ValueError(
            f"fused_graph_attention: q has {q.shape[0]} rows but A has "
            f"{a.shape[0]}")
    if k.shape[0] != a.shape[1]:
        raise ValueError(
            f"fused_graph_attention: k has {k.shape[0]} rows but A has "
            f"{a.shape[1]} columns")
    if v.shape[0] != a.shape[1]:
        raise ValueError(
            f"fused_graph_attention: v has {v.shape[0]} rows but A has "
            f"{a.shape[1]} columns")
    if q.shape[1] != k.shape[1]:
        raise ValueError(
            f"fused_graph_attention: score widths disagree: q {q.shape} "
            f"vs k {k.shape}")
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    slope = float(negative_slope)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        return lambda: autodiff.fused_attention_exec(
            (p, uk, interpret, edge_act, slope, odt), a, q, k, v)

    plan = _resolve_plan(PATH_FUSED_ATTN, a, (q.shape[1], v.shape[1]),
                         q.dtype, policy, cand, uk, interpret, cost_model,
                         config, autotune_cache, exec_thunk,
                         concrete=not _is_traced(a, q, k, v),
                         key_extra=(edge_act, slope), fused="attn")
    record_plan(plan)
    y = autodiff.fused_attention(
        (plan.path, plan.use_kernel, plan.interpret, edge_act, slope, odt),
        a, q, k, v)
    return y[:, 0] if v_was_1d else y
