"""Planned SpMM / SDDMM front-ends for ``SparseMatrix``.

``matmul`` (what ``A @ H`` calls) and ``sddmm`` (what ``A.sddmm(b, c)``
/ ``repro.sparse.sample`` call) resolve an execution path through the
sparsity-adaptive machinery in ``repro.dispatch`` — the analytic cost
model for ``policy="auto"``, the timed autotune cache for
``policy="autotune"``, or a forced path — then run the differentiable
``custom_vjp`` primitives in ``repro.sparse.autodiff``.

Plans are memoized per matrix instance (see ``repro.sparse.plan``):
the first call for a given (op, width, policy, dtype) plans, every
later call hits the memo and goes straight to execution.  Planning is
host logic over static ``MatrixStats`` aux metadata, so it happens at
``jax.jit`` trace time and is baked into the traced program.

Candidate paths follow the forms a matrix carries: ``ell`` (blocked)
needs an ``"ell"``/``"coo"`` form, ``sell`` (SELL-C-σ, the
hyper-sparsity path) a ``"sell"`` form, ``csr`` (element) a ``"csr"``
form; ``dense`` densifies on device and is always available.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dispatch import autotune as autotune_mod
from repro.dispatch.autotune import AutotuneCache, make_key, measure
from repro.dispatch.cost_model import DEFAULT_COST_MODEL, CostModel
from repro.dispatch.dispatcher import (Plan, plan_sddmm, plan_spmm,
                                       record_plan)
from repro.dispatch.policy import (DEFAULT_CONFIG, DispatchConfig, PATHS,
                                   PATH_CSR, PATH_DENSE, PATH_ELL,
                                   PATH_SELL, POLICY_AUTO, POLICY_AUTOTUNE,
                                   normalize_policy)
from repro.sparse import autodiff
from repro.sparse.matrix import SparseMatrix, with_values


def _default_use_kernel(config: DispatchConfig) -> bool:
    if config.use_kernel is not None:
        return config.use_kernel
    return jax.default_backend() == "tpu"


def _is_traced(*operands) -> bool:
    return any(isinstance(x, jax.core.Tracer)
               for x in jax.tree_util.tree_leaves(list(operands)))


def available_paths(a: SparseMatrix) -> Tuple[str, ...]:
    """Execution paths the matrix's carried forms can run."""
    cand = []
    if "ell" in a._forms or "coo" in a._forms:
        cand.append(PATH_ELL)
    if "sell" in a._forms:
        cand.append(PATH_SELL)
    if "csr" in a._forms:
        cand.append(PATH_CSR)
    cand.append(PATH_DENSE)  # device densify works for every form
    return tuple(cand)


def _resolve_plan(op: str, a: SparseMatrix, inner_dim: int, ref_dtype,
                  policy: str, cand: Tuple[str, ...], uk: bool,
                  interpret: bool, cost_model: CostModel,
                  config: DispatchConfig,
                  autotune_cache: Optional[AutotuneCache],
                  exec_thunk, concrete: bool) -> Plan:
    key = (op, int(inner_dim), policy, str(ref_dtype), cand, uk, interpret)
    if policy == POLICY_AUTOTUNE:
        # a trace-time autotune downgrades to the cost model; keep its
        # memo separate so it never masks a real (concrete) timing pass
        key += (concrete,)
    plan = a._cache.get(key)
    if plan is not None:
        return plan
    if policy in PATHS:
        if policy not in cand:
            raise ValueError(
                f"policy {policy!r} not among available paths {cand}")
        plan = Plan(op=op, path=policy, policy=policy, reason="forced",
                    use_kernel=uk, interpret=interpret, stats=a.stats)
    else:
        if a.stats is None:
            raise ValueError(
                f"{op}: matrix has no sparsity stats; construct it with "
                "SparseMatrix.from_dense/from_* (concrete) or force a "
                "path policy")
        # autotune must never time tracer thunks (it would cache trace-
        # construction time); any traced operand downgrades to the cost
        # model, exactly like plan_* does for pure planning
        if policy == POLICY_AUTOTUNE and concrete:
            cache = autotune_cache if autotune_cache is not None \
                else autotune_mod.GLOBAL_CACHE
            akey = make_key(op, a.stats.shape, inner_dim, ref_dtype,
                            a.stats.density,
                            buckets_per_decade=config.buckets_per_decade)
            hit = cache.get(akey)
            if hit is None:
                hit = measure({p: exec_thunk(p) for p in cand},
                              warmup=config.autotune_warmup,
                              iters=config.autotune_iters)
                cache.put(akey, hit)
                reason = "autotune: measured " + ", ".join(
                    f"{p}={t:.0f}us"
                    for p, t in sorted(hit.timings_us.items()))
            else:
                reason = "autotune: cached winner"
            path = hit.path
            if path not in cand:  # cache shared across operands with
                finite = {p: t for p, t in hit.timings_us.items()
                          if p in cand}  # different carried forms
                path = min(finite, key=finite.get) if finite else cand[0]
            plan = Plan(op=op, path=path, policy=POLICY_AUTOTUNE,
                        reason=reason, use_kernel=uk, interpret=interpret,
                        timings_us=hit.timings_us, stats=a.stats)
        else:
            planner = plan_spmm if op == "spmm" else plan_sddmm
            plan = planner(a.stats, inner_dim, policy=policy,
                           cost_model=cost_model, config=config,
                           use_kernel=uk, interpret=interpret,
                           candidates=cand)
    a._cache.put(key, plan)
    return plan


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------


def matmul(
    a: SparseMatrix,
    h,
    *,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    bd: Optional[int] = None,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
):
    """Y = A @ H through the unified sparse front-end (differentiable)."""
    if not isinstance(a, SparseMatrix):
        raise TypeError(f"matmul expects a SparseMatrix, got {type(a)}")
    h = jnp.asarray(h)
    h_was_1d = h.ndim == 1
    if h_was_1d:
        h = h[:, None]
    if h.ndim != 2:
        raise ValueError(f"spmm: H must be 1-D or 2-D, got shape {h.shape}")
    if h.shape[0] != a.shape[1]:
        raise ValueError(
            f"spmm: H has {h.shape[0]} rows but A has {a.shape[1]} "
            f"columns (A shape {a.shape})")
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        return lambda: autodiff.spmm_exec((p, uk, interpret, bd, odt), a, h)

    plan = _resolve_plan("spmm", a, h.shape[1], h.dtype, policy, cand, uk,
                         interpret, cost_model, config, autotune_cache,
                         exec_thunk, concrete=not _is_traced(a, h))
    record_plan(plan)
    y = autodiff.spmm((plan.path, plan.use_kernel, plan.interpret, bd, odt),
                      a, h)
    return y[:, 0] if h_was_1d else y


# ---------------------------------------------------------------------------
# SDDMM
# ---------------------------------------------------------------------------


def sddmm(
    a: SparseMatrix,
    b,
    c,
    *,
    policy: str = POLICY_AUTO,
    candidates: Optional[Tuple[str, ...]] = None,
    use_kernel: Optional[bool] = None,
    interpret: bool = False,
    bk: Optional[int] = None,
    out_dtype=None,
    cost_model: CostModel = DEFAULT_COST_MODEL,
    config: DispatchConfig = DEFAULT_CONFIG,
    autotune_cache: Optional[AutotuneCache] = None,
) -> SparseMatrix:
    """S = A ⊙ (B @ C) at A's stored entries (differentiable).

    Returns a single-form ``SparseMatrix`` sharing A's topology, in the
    layout of the form the planned path read; ``S.data`` holds the
    sampled values (element order for the csr path — what GAT's
    segment-softmax consumes).
    """
    if not isinstance(a, SparseMatrix):
        raise TypeError(f"sddmm expects a SparseMatrix, got {type(a)}")
    b = jnp.asarray(b)
    c = jnp.asarray(c)
    if b.shape[0] != a.shape[0]:
        raise ValueError(
            f"sddmm: B has {b.shape[0]} rows but A has {a.shape[0]}")
    if c.shape[1] != a.shape[1]:
        raise ValueError(
            f"sddmm: C has {c.shape[1]} columns but A has {a.shape[1]}")
    if b.shape[1] != c.shape[0]:
        raise ValueError(
            f"sddmm: inner dims disagree: B {b.shape} vs C {c.shape}")
    policy = normalize_policy(policy)
    cand = tuple(candidates) if candidates else available_paths(a)
    uk = use_kernel if use_kernel is not None else _default_use_kernel(config)
    interpret = bool(interpret)
    odt = None if out_dtype is None else str(jnp.dtype(out_dtype))

    def exec_thunk(p):
        return lambda: autodiff.sddmm_values(
            (p, uk, interpret, bk, odt), a, b, c)

    plan = _resolve_plan("sddmm", a, b.shape[1], b.dtype, policy, cand, uk,
                         interpret, cost_model, config, autotune_cache,
                         exec_thunk, concrete=not _is_traced(a, b, c))
    record_plan(plan)
    vals = autodiff.sddmm_values(
        (plan.path, plan.use_kernel, plan.interpret, bk, odt), a, b, c)
    form_name = autodiff.form_read_by(a, plan.path)
    return SparseMatrix(
        {form_name: with_values(form_name, a._forms[form_name], vals)},
        a.shape, a.stats, cache=a._cache)


# the paper's naming for the masked product
sample = sddmm
