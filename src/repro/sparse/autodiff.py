"""custom_vjp rules wiring the paper's two kernels into each other.

SpMM and SDDMM are transpose/backward duals (Gale et al., *Sparse GPU
Kernels for Deep Learning*): for ``Y = A @ H``,

  * ``dH = Aᵀ @ ḡ``            — another SpMM, on the transposed operand;
  * ``dA = pattern(A) ⊙ (ḡ Hᵀ)`` — exactly SDDMM sampled on A's nonzero
    topology.

and for ``S = A ⊙ (B C)``,

  * ``dA = ḡ ⊙ (B C)``          — elementwise on the stored values;
  * ``dB = (A ⊙ ḡ) @ Cᵀ``       — an SpMM with the cotangent-weighted A;
  * ``dC = ((A ⊙ ḡ)ᵀ @ B)ᵀ``    — the transposed SpMM.

Each rule executes through the same path the forward ran (ell / csr /
dense) and records its decision in the dispatch log, so the duality is
observable: after a backward pass ``dispatch_log()`` contains the
partner op's plan.

Gradient semantics: cotangents flow to the *stored values* of the form
the forward pass read; structural zeros (padding slots, element zeros)
receive zero gradient so SGD can never resurrect pruned entries.
Integer topology arrays get ``float0`` cotangents.  Secondary forms of
a multi-form matrix were not read by the forward computation, so their
values correctly receive zero.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BlockCOO
from repro.dispatch.dispatcher import Plan, record_plan
from repro.dispatch.policy import (PATH_CSR, PATH_DENSE, PATH_ELL,
                                   PATH_SELL)
from repro.kernels.fused.epilogue import (Epilogue, act_grad_from_out,
                                          apply_epilogue)
from repro.sparse import paths
from repro.sparse.matrix import SparseMatrix, values_of, with_values

# cfg: (path, use_kernel, interpret, bd_or_bk, out_dtype_str) — hashable,
# resolved by the planner in ops.py before the differentiable call.
Cfg = Tuple[str, bool, bool, Optional[int], Optional[str]]
# epilogue cfg: Cfg + (Epilogue,) — the fused-SpMM variant.
EpiCfg = Tuple[str, bool, bool, Optional[int], Optional[str], Epilogue]
# attention cfg: (path, use_kernel, interpret, act, slope, out_dtype_str)
AttnCfg = Tuple[str, bool, bool, str, float, Optional[str]]


def _float0_like(x):
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


def _cotangent_like(a: SparseMatrix, form_name: str,
                    dvals) -> SparseMatrix:
    """A-structured cotangent: dvals on ``form_name``'s values leaf,
    zeros on other forms' values, float0 on integer topology arrays."""
    forms = {}
    for name, form in a._forms.items():
        v = values_of(name, form)
        dv = dvals if name == form_name else jnp.zeros_like(v)
        if name == "csr":
            forms[name] = (_float0_like(form[0]), _float0_like(form[1]), dv)
        elif name == "ell":
            forms[name] = type(form)(
                indices=_float0_like(form.indices), blocks=dv,
                nblocks=_float0_like(form.nblocks), shape=form.shape)
        elif name == "sell":
            forms[name] = type(form)(
                slot_cols=_float0_like(form.slot_cols),
                slot_rows=_float0_like(form.slot_rows),
                slot_vals=dv,
                out_gather=_float0_like(form.out_gather),
                perm=_float0_like(form.perm),
                tile_rows=_float0_like(form.tile_rows),
                tile_cols=_float0_like(form.tile_cols),
                tile_slot_map=_float0_like(form.tile_slot_map),
                slot_tile_pos=_float0_like(form.slot_tile_pos),
                tile_out_gather=_float0_like(form.tile_out_gather),
                shape=form.shape, c=form.c, sigma=form.sigma,
                buckets=form.buckets, block=form.block,
                n_live_block_rows=form.n_live_block_rows)
        else:
            forms[name] = type(form)(
                rows=_float0_like(form.rows), cols=_float0_like(form.cols),
                blocks=dv, shape=form.shape)
    return SparseMatrix(forms, a.shape, a.stats, cache=a._cache)


def form_read_by(a: SparseMatrix, path: str) -> str:
    """Which carried form a given execution path reads."""
    if path == PATH_CSR:
        return "csr"
    if path == PATH_ELL:
        return "ell" if "ell" in a._forms else "coo"
    if path == PATH_SELL:
        # the transpose of a sell operand carries the slot triplet as an
        # element form; the sell path falls back to it (see spmm_exec)
        return "sell" if "sell" in a._forms else "csr"
    return a.format  # dense path densifies the primary form


# ---------------------------------------------------------------------------
# Path execution (shared by forward and both backward rules)
# ---------------------------------------------------------------------------


def spmm_exec(cfg: Cfg, a: SparseMatrix, h):
    """Run one planned SpMM path; h: [N, D] logical rows; returns [M, D]."""
    path, use_kernel, interpret, bd, out_dtype = cfg
    m = a.shape[0]
    if path == PATH_ELL:
        if "ell" in a._forms:
            ell = a._forms["ell"]
            y = paths.spmm_ell(ell, paths.pad_rows(h, ell.shape[1]),
                               use_kernel=use_kernel, interpret=interpret,
                               bd=bd, out_dtype=out_dtype)
        else:
            coo = a._forms["coo"]
            y = paths.spmm_coo(coo, paths.pad_rows(h, coo.shape[1]),
                               out_dtype=out_dtype)
        return y[:m]
    if path == PATH_SELL:
        if "sell" in a._forms:
            return paths.spmm_sell(a._forms["sell"], h,
                                   use_kernel=use_kernel,
                                   interpret=interpret, bd=bd,
                                   out_dtype=out_dtype)
        # transposed sell operand: the slot triplet is an element form
        r, c, v = a.form("csr")
        y = paths.spmm_elements(r, c, v, h, m)
        return y.astype(out_dtype) if out_dtype else y
    if path == PATH_CSR:
        r, c, v = a.form("csr")
        y = paths.spmm_elements(r, c, v, h, m)
        return y.astype(out_dtype) if out_dtype else y
    if path == PATH_DENSE:
        y = paths.spmm_dense(a.densify(), h)
        return y.astype(out_dtype) if out_dtype else y
    raise ValueError(f"unknown spmm path {path!r}")


def spmv_exec(cfg: Cfg, a: SparseMatrix, x):
    """Run one planned SpMV path; x: [N] logical entries; returns [M].

    The vector fast lane: same path vocabulary as SpMM, but each layout
    runs a direct reduction (see paths.spmv_*) instead of the [N, 1]
    tile pipeline.  ``bd`` in cfg is ignored — there is no D to tile.
    """
    path, _use_kernel, _interpret, _bd, out_dtype = cfg
    m = a.shape[0]
    if path == PATH_ELL:
        if "ell" in a._forms:
            ell = a._forms["ell"]
            y = paths.spmv_ell(ell, paths.pad_rows(x, ell.shape[1]),
                               out_dtype=out_dtype)
        else:
            coo = a._forms["coo"]
            y = paths.spmv_coo(coo, paths.pad_rows(x, coo.shape[1]),
                               out_dtype=out_dtype)
        return y[:m]
    if path == PATH_SELL:
        if "sell" in a._forms:
            return paths.spmv_sell(a._forms["sell"], x,
                                   out_dtype=out_dtype)
        r, c, v = a.form("csr")  # transposed sell: slot triplet
        y = paths.spmv_elements(r, c, v, x, m)
        return y.astype(out_dtype) if out_dtype else y
    if path == PATH_CSR:
        r, c, v = a.form("csr")
        y = paths.spmv_elements(r, c, v, x, m)
        return y.astype(out_dtype) if out_dtype else y
    if path == PATH_DENSE:
        y = paths.spmm_dense(a.densify(), x)
        return y.astype(out_dtype) if out_dtype else y
    raise ValueError(f"unknown spmv path {path!r}")


def sample_exec(cfg: Cfg, a: SparseMatrix, b, c):
    """Raw sampled dots (B @ C at A's stored slots), in the layout of the
    form the path reads — the unweighted SDDMM the backward rules share."""
    path, use_kernel, interpret, bk, _ = cfg
    form_name = form_read_by(a, path)
    form = a._forms[form_name]
    if path == PATH_CSR:
        return paths.sddmm_element_dots(form[0], form[1], b, c)
    if path == PATH_SELL:
        if form_name == "sell":
            return paths.sample_sell(form, b, c, use_kernel=use_kernel,
                                     interpret=interpret, bk=bk)
        return paths.sddmm_element_dots(form[0], form[1], b, c)
    if path == PATH_ELL:
        coo = paths.ell_to_coo(form) if form_name == "ell" else form
        ones = BlockCOO(rows=coo.rows, cols=coo.cols,
                        blocks=jnp.ones_like(coo.blocks), shape=coo.shape)
        out = paths.sddmm_blocked(
            ones, paths.pad_rows(b, coo.shape[0]),
            paths.pad_cols(c, coo.shape[1]),
            use_kernel=use_kernel, interpret=interpret, bk=bk).blocks
        if form_name == "ell":
            return out.reshape(form.blocks.shape)
        return out
    if path == PATH_DENSE:
        full = b.astype(jnp.float32) @ c.astype(jnp.float32)
        if form_name == "csr":
            return full[form[0], form[1]].astype(b.dtype)
        if form_name == "sell":
            return full[form.slot_rows, form.slot_cols].astype(b.dtype)
        coo = paths.ell_to_coo(form) if form_name == "ell" else form
        full = paths.pad_cols(paths.pad_rows(full, coo.shape[0]),
                              coo.shape[1])
        out = paths.sample_blocks(full, coo.rows, coo.cols,
                                  coo.bm, coo.bn).astype(b.dtype)
        if form_name == "ell":
            return out.reshape(form.blocks.shape)
        return out
    raise ValueError(f"unknown sddmm path {path!r}")


def _mask_structural(vals, grad):
    """Zero the gradient at structural zeros (padding, pruned entries)."""
    return jnp.where(vals != 0, grad, jnp.zeros_like(grad)) \
        .astype(vals.dtype)


def _record_vjp(op: str, path: str, reason: str, cfg: Cfg) -> None:
    record_plan(Plan(op=op, path=path, policy="vjp", reason=reason,
                     use_kernel=bool(cfg[1]), interpret=bool(cfg[2])))


# ---------------------------------------------------------------------------
# SpMM: Y = A @ H
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def spmm(cfg: Cfg, a: SparseMatrix, h):
    return spmm_exec(cfg, a, h)


def _spmm_fwd(cfg: Cfg, a: SparseMatrix, h):
    return spmm_exec(cfg, a, h), (a, h)


def _spmm_bwd(cfg: Cfg, res, g):
    path = cfg[0]
    a, h = res
    # dH = Aᵀ @ ḡ : SpMM on the transposed operand, same path (Block-ELL
    # transposes into Block-COO, which the blocked path also executes).
    dh = spmm_exec((path, cfg[1], cfg[2], None, None), a.T, g)
    _record_vjp("spmm", path, "vjp: dH = Aᵀ @ ḡ (spmm backward)", cfg)
    # dA = pattern(A) ⊙ (ḡ @ Hᵀ) : SDDMM on A's nonzero topology.
    form_name = form_read_by(a, path)
    raw = sample_exec((path, cfg[1], cfg[2], None, None), a, g, h.T)
    _record_vjp("sddmm", path,
                "vjp: dA = pattern(A) ⊙ (ḡ @ Hᵀ) (spmm backward is sddmm)",
                cfg)
    vals = values_of(form_name, a._forms[form_name])
    da = _cotangent_like(a, form_name, _mask_structural(vals, raw))
    return da, dh.astype(h.dtype)


spmm.defvjp(_spmm_fwd, _spmm_bwd)


# ---------------------------------------------------------------------------
# SpMV: y = A @ x  (vector fast lane; same duality at d = 1)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def spmv(cfg: Cfg, a: SparseMatrix, x):
    return spmv_exec(cfg, a, x)


def _spmv_fwd(cfg: Cfg, a: SparseMatrix, x):
    return spmv_exec(cfg, a, x), (a, x)


def _spmv_bwd(cfg: Cfg, res, g):
    path = cfg[0]
    a, x = res
    # dx = Aᵀ @ ḡ : another SpMV, on the transposed operand.
    dx = spmv_exec((path, cfg[1], cfg[2], None, None), a.T, g)
    _record_vjp("spmv", path, "vjp: dx = Aᵀ @ ḡ (spmv backward)", cfg)
    # dA = pattern(A) ⊙ (ḡ xᵀ) : rank-1 SDDMM on A's topology.
    form_name = form_read_by(a, path)
    raw = sample_exec((path, cfg[1], cfg[2], None, None), a,
                      g[:, None], x[None, :])
    _record_vjp("sddmm", path,
                "vjp: dA = pattern(A) ⊙ (ḡ xᵀ) (spmv backward is sddmm)",
                cfg)
    vals = values_of(form_name, a._forms[form_name])
    da = _cotangent_like(a, form_name, _mask_structural(vals, raw))
    return da, dx.astype(x.dtype)


spmv.defvjp(_spmv_fwd, _spmv_bwd)


# ---------------------------------------------------------------------------
# SDDMM: S = A ⊙ (B @ C)  (values in the layout of the form the path reads)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def sddmm_values(cfg: Cfg, a: SparseMatrix, b, c):
    return _sddmm_fwd(cfg, a, b, c)[0]


def _sddmm_fwd(cfg: Cfg, a: SparseMatrix, b, c):
    raw = sample_exec(cfg, a, b, c)
    form_name = form_read_by(a, cfg[0])
    vals = values_of(form_name, a._forms[form_name])
    out = vals.astype(jnp.float32) * raw.astype(jnp.float32)
    out_dtype = cfg[4] or jnp.result_type(vals.dtype, b.dtype)
    return out.astype(out_dtype), (a, b, c, raw)


def _sddmm_bwd(cfg: Cfg, res, g):
    path = cfg[0]
    a, b, c, raw = res
    form_name = form_read_by(a, path)
    vals = values_of(form_name, a._forms[form_name])
    # dA = ḡ ⊙ (B C) sampled — elementwise on the stored values.
    dvals = _mask_structural(
        vals, g.astype(jnp.float32) * raw.astype(jnp.float32))
    da = _cotangent_like(a, form_name, dvals)
    # M = A ⊙ ḡ shares A's topology; both remaining grads are SpMMs.
    mg = (vals.astype(jnp.float32) * g.astype(jnp.float32))
    m_mat = SparseMatrix(
        {form_name: with_values(form_name, a._forms[form_name],
                                mg.astype(vals.dtype))},
        a.shape, a.stats, cache=a._cache)
    exec_cfg = (path, cfg[1], cfg[2], None, None)
    db = spmm_exec(exec_cfg, m_mat, c.T)
    _record_vjp("spmm", path, "vjp: dB = (A ⊙ ḡ) @ Cᵀ (sddmm backward is "
                "spmm)", cfg)
    dc = spmm_exec(exec_cfg, m_mat.T, b).T
    _record_vjp("spmm", path, "vjp: dC = ((A ⊙ ḡ)ᵀ @ B)ᵀ (sddmm backward "
                "is spmm)", cfg)
    return da, db.astype(b.dtype), dc.astype(c.dtype)


sddmm_values.defvjp(_sddmm_fwd, _sddmm_bwd)


# ---------------------------------------------------------------------------
# Fused SpMM + epilogue: Y = act(A @ H + bias + residual)
# ---------------------------------------------------------------------------


def spmm_epilogue_exec(cfg: EpiCfg, a: SparseMatrix, h, bias, residual):
    """Run one planned SpMM path with its epilogue fused.

    The blocked kernel routes (Block-ELL / SELL-C-σ on the kernel path)
    apply the epilogue to the VMEM accumulator at the flush; every other
    route composes the reference SpMM with the elementwise tail, which
    XLA fuses — semantics are identical either way.
    """
    path, use_kernel, interpret, bd, out_dtype, epi = cfg
    kernelish = use_kernel or interpret
    if kernelish and path == PATH_ELL and "ell" in a._forms:
        from repro.kernels.fused.spmm import spmm_blockell_fused

        ell = a._forms["ell"]
        y = spmm_blockell_fused(
            ell, paths.pad_rows(h, ell.shape[1]), epi, bias, residual,
            bd=bd, out_dtype=out_dtype, use_kernel=use_kernel,
            interpret=interpret)
        return y[: a.shape[0]]
    if kernelish and path == PATH_SELL and "sell" in a._forms:
        from repro.kernels.fused.spmm import spmm_sell_fused

        return spmm_sell_fused(
            a._forms["sell"], h, epi, bias, residual, bd=bd,
            out_dtype=out_dtype, use_kernel=use_kernel,
            interpret=interpret)
    y = spmm_exec((path, use_kernel, interpret, bd, out_dtype), a, h)
    return apply_epilogue(y, epi, bias, residual)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def spmm_epilogue(cfg: EpiCfg, a: SparseMatrix, h, bias, residual):
    return spmm_epilogue_exec(cfg, a, h, bias, residual)


def _spmm_epilogue_fwd(cfg: EpiCfg, a: SparseMatrix, h, bias, residual):
    out = spmm_epilogue_exec(cfg, a, h, bias, residual)
    # the activation derivative is recoverable from the output sign
    # (relu/leaky_relu preserve it), so `out` is the only extra residual
    return out, (a, h, bias, residual, out)


def _spmm_epilogue_bwd(cfg: EpiCfg, res, g):
    path, use_kernel, interpret = cfg[0], cfg[1], cfg[2]
    epi = cfg[5]
    a, h, bias, residual, out = res
    dz = g.astype(jnp.float32) * act_grad_from_out(
        out.astype(jnp.float32), epi.act, epi.negative_slope)
    dbias = None
    if epi.has_bias:
        # ops.matmul canonicalizes bias to [D], so the cotangent is the
        # row reduction reshaped to the operand's (validated) shape
        dbias = dz.sum(axis=0).reshape(jnp.shape(bias)).astype(bias.dtype)
    dres = dz.astype(residual.dtype) if epi.has_residual else None
    # past the elementwise tail the rules are exactly the SpMM duality
    exec_cfg = (path, use_kernel, interpret, None, None)
    dh = spmm_exec(exec_cfg, a.T, dz)
    _record_vjp("spmm", path,
                "vjp: dH = Aᵀ @ (ḡ ⊙ act') (fused-epilogue spmm backward)",
                cfg)
    form_name = form_read_by(a, path)
    raw = sample_exec(exec_cfg, a, dz, h.T)
    _record_vjp("sddmm", path,
                "vjp: dA = pattern(A) ⊙ ((ḡ ⊙ act') @ Hᵀ) (fused-epilogue "
                "spmm backward is sddmm)", cfg)
    vals = values_of(form_name, a._forms[form_name])
    da = _cotangent_like(a, form_name, _mask_structural(vals, raw))
    return da, dh.astype(h.dtype), dbias, dres


spmm_epilogue.defvjp(_spmm_epilogue_fwd, _spmm_epilogue_bwd)


# ---------------------------------------------------------------------------
# Fused graph attention: Y = softmax_row(act(q kᵀ ⊙ pattern(A))) @ V
# ---------------------------------------------------------------------------


def _edge_act_grad(raw, act: str, slope: float):
    """d act/ds at the raw sampled scores."""
    if act == "identity":
        return jnp.ones_like(raw)
    if act == "relu":
        return jnp.where(raw > 0, 1.0, 0.0)
    if act == "leaky_relu":
        return jnp.where(raw >= 0, 1.0, slope)
    raise ValueError(f"unknown edge activation {act!r}")


def _form_broadcast_rows(a: SparseMatrix, form_name: str, vec):
    """Broadcast a per-logical-row vector onto a form's values layout."""
    form = a._forms[form_name]
    if form_name == "csr":
        return vec[form[0]]
    if form_name == "sell":
        return vec[form.slot_rows]
    bm = form.bm
    padded = paths.pad_rows(vec, form.shape[0])
    by_row = padded.reshape(-1, bm)  # [nbr, bm]
    if form_name == "ell":
        return by_row[:, None, :, None]   # -> [nbr, W, bm, bn] broadcast
    return by_row[form.rows][:, :, None]  # coo: [nnzb, bm, 1]


def _form_row_softmax(a: SparseMatrix, form_name: str, e, mask):
    """Row softmax of masked scores ``e`` laid out like one form's values.

    ``e`` is float32 with masked (structural-zero) entries already at
    NEG_INF; the result carries exact zeros there.  Matches
    ``models.gnn._segment_softmax`` (same 1e-12 denominator guard).
    """
    from repro.kernels.fused.attention import EPS

    form = a._forms[form_name]
    m = a.shape[0]
    if form_name in ("csr", "sell"):
        rows = form[0] if form_name == "csr" else form.slot_rows
        mx = jax.ops.segment_max(e, rows, num_segments=m)
        ex = jnp.where(mask, jnp.exp(e - mx[rows]), 0.0)
        den = jax.ops.segment_sum(ex, rows, num_segments=m)
        return ex / jnp.maximum(den[rows], EPS)
    if form_name == "ell":
        mx = e.max(axis=(1, 3))  # [nbr, bm]
        ex = jnp.where(mask, jnp.exp(e - mx[:, None, :, None]), 0.0)
        den = ex.sum(axis=(1, 3))
        return ex / jnp.maximum(den, EPS)[:, None, :, None]
    # coo: segment over block rows
    nbr = form.shape[0] // form.bm
    mx = jax.ops.segment_max(e.max(axis=2), form.rows, num_segments=nbr)
    ex = jnp.where(mask, jnp.exp(e - mx[form.rows][:, :, None]), 0.0)
    den = jax.ops.segment_sum(ex.sum(axis=2), form.rows, num_segments=nbr)
    return ex / jnp.maximum(den[form.rows][:, :, None], EPS)


def fused_attention_exec(cfg: AttnCfg, a: SparseMatrix, q, k, v):
    """One-pass SDDMM→edge-act→softmax→SpMM over A's structural nonzeros.

    ``q``: [M, dk] and ``k``: [N, dk] score factors (scores = q @ kᵀ
    sampled at A's pattern), ``v``: [N, D] values.  A's stored *values*
    only contribute their nonzero pattern.
    """
    from repro.kernels.fused import attention as fat

    path, use_kernel, interpret, act, slope, out_dtype = cfg
    m = a.shape[0]
    kt = k.T
    if path == PATH_ELL:
        if "ell" in a._forms:
            y = fat.fused_attn_blockell(
                a._forms["ell"], q, kt, v, act=act, slope=slope,
                out_dtype=out_dtype, use_kernel=use_kernel,
                interpret=interpret)
            return y[:m]
        coo = a._forms["coo"]
        return fat.fused_attn_blockcoo_ref(
            coo, paths.pad_rows(q, coo.shape[0]),
            paths.pad_cols(kt, coo.shape[1]),
            paths.pad_rows(v, coo.shape[1]),
            act=act, slope=slope,
            out_dtype=out_dtype or jnp.result_type(q.dtype, v.dtype))[:m]
    if path == PATH_SELL:
        if "sell" in a._forms:
            return fat.fused_attn_sell(
                a._forms["sell"], q, kt, v, act=act, slope=slope,
                out_dtype=out_dtype, use_kernel=use_kernel,
                interpret=interpret)
        r, c, vals = a.form("csr")  # transposed sell: slot triplet
        return fat.fused_attn_elements(r, c, vals, q, kt, v, m, act=act,
                                       slope=slope, out_dtype=out_dtype)
    if path == PATH_CSR:
        r, c, vals = a.form("csr")
        return fat.fused_attn_elements(r, c, vals, q, kt, v, m, act=act,
                                       slope=slope, out_dtype=out_dtype)
    if path == PATH_DENSE:
        return fat.fused_attn_dense(a.densify(), q, kt, v, act=act,
                                    slope=slope, out_dtype=out_dtype)
    raise ValueError(f"unknown fused-attention path {path!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def fused_attention(cfg: AttnCfg, a: SparseMatrix, q, k, v):
    return fused_attention_exec(cfg, a, q, k, v)


def _fused_attention_fwd(cfg: AttnCfg, a: SparseMatrix, q, k, v):
    out = fused_attention_exec(cfg, a, q, k, v)
    return out, (a, q, k, v, out)


def _fused_attention_bwd(cfg: AttnCfg, res, g):
    """The fused pipeline's backward, assembled from the kernel duality.

    With α = softmax(act(e)) and O = α V:

      * dV = αᵀ ḡ                      — SpMM on the transposed α;
      * dα = ḡ Vᵀ sampled at pattern   — SDDMM;
      * softmax JVP trick: de' = α ⊙ (dα - rowdot), where
        rowdot_i = ḡ_i · O_i re-uses the forward output instead of a
        second α-weighted reduction;
      * de = de' ⊙ act'(e); then dq = (P ⊙ de) k and dk = (P ⊙ de)ᵀ q
        — the SDDMM backward's two SpMMs.

    α and the raw scores are recomputed in the forward layout (one
    SDDMM + a row softmax), so the forward never has to spill them.
    """
    path, use_kernel, interpret, act, slope, _ = cfg
    a, q, k, v, out = res
    exec_cfg = (path, use_kernel, interpret, None, None)
    form_name = form_read_by(a, path)
    form = a._forms[form_name]
    vals = values_of(form_name, form)
    mask = vals != 0

    from repro.kernels.fused.attention import NEG_INF
    from repro.kernels.fused.epilogue import apply_act

    raw = sample_exec(exec_cfg, a, q, k.T).astype(jnp.float32)
    _record_vjp("sddmm", path,
                "vjp: recompute e = act(q kᵀ) at pattern (fused attn "
                "backward)", cfg)
    e = jnp.where(mask, apply_act(raw, act, slope), NEG_INF)
    alpha = _form_row_softmax(a, form_name, e, mask)

    dalpha = sample_exec(exec_cfg, a, g, v.T).astype(jnp.float32)
    _record_vjp("sddmm", path,
                "vjp: dα = ḡ Vᵀ at pattern (fused attn backward is sddmm)",
                cfg)
    rowdot = (g.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
    rd = _form_broadcast_rows(a, form_name, rowdot)
    de = alpha * (dalpha - rd) * _edge_act_grad(raw, act, slope)
    de = jnp.where(mask, de, 0.0)

    de_mat = SparseMatrix(
        {form_name: with_values(form_name, form, de.astype(vals.dtype))},
        a.shape, a.stats, cache=a._cache)
    dq = spmm_exec(exec_cfg, de_mat, k)
    _record_vjp("spmm", path,
                "vjp: dq = (P ⊙ de) k (fused attn backward is spmm)", cfg)
    dk = spmm_exec(exec_cfg, de_mat.T, q)
    _record_vjp("spmm", path,
                "vjp: dk = (P ⊙ de)ᵀ q (fused attn backward is spmm)", cfg)
    alpha_mat = SparseMatrix(
        {form_name: with_values(form_name, form,
                                alpha.astype(vals.dtype))},
        a.shape, a.stats, cache=a._cache)
    dv = spmm_exec(exec_cfg, alpha_mat.T, g)
    _record_vjp("spmm", path,
                "vjp: dV = αᵀ ḡ (fused attn backward is spmm)", cfg)
    # attention reads only A's nonzero *pattern*; its stored values get
    # zero cotangent (structure is not differentiable)
    da = _cotangent_like(a, form_name, jnp.zeros_like(vals))
    return da, dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


fused_attention.defvjp(_fused_attention_fwd, _fused_attention_bwd)
