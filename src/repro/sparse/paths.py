"""Traceable execution paths shared by the unified sparse front-end.

Every path here is pure jnp (or routes to the Pallas kernels), takes
device arrays, and is safe to call at ``jax.jit`` trace time — planning
(which path to run) is host logic and lives in ``repro.sparse.ops``; the
functions below only *execute*.

Path vocabulary matches the dispatch layer (see dispatch/policy.py):

  * ``ell``   — blocked streaming: Block-ELL SpMM / Block-COO SDDMM
                (Pallas kernel on TPU, jnp reference elsewhere), plus a
                blocked-COO SpMM used for transposed Block-ELL operands.
  * ``sell``  — SELL-C-σ: width-adaptive row-sorted slices.  The jnp
                reference runs one scatter-free batched contraction per
                width bucket (the slice descriptor is static aux, so the
                loop unrolls at trace time); the kernel route iterates
                live tiles only (see kernels/spmm/sell.py).
  * ``csr``   — element-granular: gather + segment-sum SpMM, per-edge
                dot SDDMM.  Exact nnz work, no MXU.
  * ``dense`` — densify (device scatter) and run the dense matmul /
                full-product sample.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR, BlockCOO, BlockELL, SellCS

Array = Any


# ---------------------------------------------------------------------------
# Element-granular ("csr") paths
# ---------------------------------------------------------------------------


def csr_to_device_arrays(csr: CSR) -> Tuple[Array, Array, Array]:
    """Expand host CSR to (row_ids, col_ids, values) int32 device arrays."""
    row_ids = np.repeat(
        np.arange(csr.shape[0], dtype=np.int32), np.diff(csr.indptr)
    )
    return (
        jnp.asarray(row_ids),
        jnp.asarray(csr.indices.astype(np.int32, copy=False)),
        jnp.asarray(csr.values),
    )


def spmm_elements(row_ids, col_ids, values, h, num_rows: int):
    """Y = A @ H via gather + segment-sum (element-granular)."""
    gathered = values[:, None].astype(jnp.float32) * h[col_ids].astype(
        jnp.float32
    )
    out = jax.ops.segment_sum(gathered, row_ids, num_segments=num_rows)
    return out.astype(h.dtype)


def sddmm_element_dots(row_ids, col_ids, b, c):
    """out[e] = b[row[e]] . c[:, col[e]] — the per-edge dot products.

    b: [M, K]; c: [K, N] -> dots[e] for each coordinate.
    """
    bs = b[row_ids].astype(jnp.float32)  # [nnz, K]
    cs = c.T[col_ids].astype(jnp.float32)  # [nnz, K]
    return jnp.sum(bs * cs, axis=-1).astype(b.dtype)


def sddmm_elements(row_ids, col_ids, values, b, c):
    """values ⊙ (B @ C) sampled at the element coordinates."""
    dots = sddmm_element_dots(row_ids, col_ids, b, c)
    return (values.astype(jnp.float32)
            * dots.astype(jnp.float32)).astype(values.dtype)


# ---------------------------------------------------------------------------
# SpMV (d = 1) paths — vector fast lane, no SpMM tile machinery
# ---------------------------------------------------------------------------
#
# y = A @ x for a [N] vector.  The SpMM paths would run these as [N, 1]
# matrices through the blocked tile pipeline (kernel grids, D-padding,
# epilogue plumbing); with one output column none of that pays for
# itself, so each layout gets a direct reduction instead.


def spmv_elements(row_ids, col_ids, values, x, num_rows: int):
    """y = A @ x via gather + segment-sum (element-granular)."""
    prod = values.astype(jnp.float32) * x[col_ids].astype(jnp.float32)
    out = jax.ops.segment_sum(prod, row_ids, num_segments=num_rows)
    return out.astype(x.dtype)


def spmv_ell(ell: BlockELL, x, *, out_dtype=None):
    """y = A @ x with A in Block-ELL; x already padded to ell.shape[1].

    One einsum over the gathered x-blocks — the block columns each slot
    points at — contracting both the slot axis and the in-block column.
    """
    bn = ell.bn
    x_blocks = x.reshape(ell.shape[1] // bn, bn)
    gathered = x_blocks[ell.indices]  # [nbr, W, bn]
    y = jnp.einsum("rwmn,rwn->rm", ell.blocks.astype(jnp.float32),
                   gathered.astype(jnp.float32))
    out_dtype = out_dtype or jnp.result_type(ell.blocks.dtype, x.dtype)
    return y.reshape(ell.shape[0]).astype(out_dtype)


def spmv_coo(coo: BlockCOO, x, *, out_dtype=None):
    """y = A @ x with A in Block-COO (scatter-add over nonzero blocks)."""
    bm, bn = coo.bm, coo.bn
    x_blocks = x.reshape(coo.shape[1] // bn, bn)
    prods = jnp.einsum("emn,en->em", coo.blocks.astype(jnp.float32),
                       x_blocks[coo.cols].astype(jnp.float32))
    out = jnp.zeros((coo.shape[0] // bm, bm), jnp.float32) \
        .at[coo.rows].add(prods)
    out_dtype = out_dtype or jnp.result_type(coo.blocks.dtype, x.dtype)
    return out.reshape(coo.shape[0]).astype(out_dtype)


def spmv_sell(sell: SellCS, x, *, out_dtype=None):
    """y = A @ x with A in SELL-C-σ — scatter-free per-bucket reduction.

    Each width bucket is one [rows, w] elementwise product + row sum;
    the epilogue gather un-permutes rows exactly like spmm_sell_ref
    (the appended zero covers pruned all-zero rows).
    """
    m, _ = sell.shape
    out_dtype = out_dtype or jnp.result_type(sell.slot_vals.dtype, x.dtype)
    if not sell.buckets:
        return jnp.zeros((m,), out_dtype)
    outs = []
    off = 0
    for _, rows, width in sell.buckets:
        cols = sell.slot_cols[off:off + rows * width].reshape(rows, width)
        vals = sell.slot_vals[off:off + rows * width].reshape(rows, width)
        outs.append((vals.astype(jnp.float32)
                     * x[cols].astype(jnp.float32)).sum(axis=-1))
        off += rows * width
    packed = jnp.concatenate(outs + [jnp.zeros((1,), jnp.float32)])
    return packed[sell.out_gather].astype(out_dtype)


# ---------------------------------------------------------------------------
# Blocked ("ell") paths
# ---------------------------------------------------------------------------


def spmm_ell(ell: BlockELL, h, *, use_kernel: bool = False,
             interpret: bool = False, bd: Optional[int] = None,
             out_dtype=None):
    """Y = A @ H with A in Block-ELL; H already padded to ell.shape[1]."""
    from repro.kernels.spmm.ops import spmm_blockell

    return spmm_blockell(ell, h, bd=bd, out_dtype=out_dtype,
                         use_kernel=use_kernel or interpret,
                         interpret=interpret)


def spmm_coo(coo: BlockCOO, h, *, out_dtype=None):
    """Y = A @ H with A in Block-COO (scatter-add over nonzero blocks).

    The blocked path for transposed Block-ELL operands: ELL transposes
    into COO without host re-bucketing, and this scatter is its SpMM.
    Padded entries carry zero blocks, so duplicate coordinates are
    harmless under the add.
    """
    nnzb, bm, bn = coo.blocks.shape
    mp, np_ = coo.shape
    n, d = h.shape
    h_blocks = h.reshape(np_ // bn, bn, d)
    prods = jnp.einsum(
        "emn,end->emd",
        coo.blocks.astype(jnp.float32),
        h_blocks[coo.cols].astype(jnp.float32),
    )
    out = jnp.zeros((mp // bm, bm, d), jnp.float32).at[coo.rows].add(prods)
    out_dtype = out_dtype or jnp.result_type(coo.blocks.dtype, h.dtype)
    return out.reshape(mp, d).astype(out_dtype)


def sddmm_blocked(coo: BlockCOO, b, c, *, use_kernel: bool = False,
                  interpret: bool = False, bk: Optional[int] = None,
                  out_dtype=None) -> BlockCOO:
    """coo.blocks ⊙ (B @ C) at the nonzero blocks; B/C already padded."""
    from repro.kernels.sddmm.ops import sddmm_blockcoo

    return sddmm_blockcoo(coo, b, c, bk=bk, out_dtype=out_dtype,
                          use_kernel=use_kernel or interpret,
                          interpret=interpret)


def ell_to_coo(ell: BlockELL) -> BlockCOO:
    """Flatten Block-ELL slots into Block-COO (traceable, no host work).

    Padded slots become zero blocks at duplicated coordinates — exactly
    the Block-COO padding contract.
    """
    nbr, w = ell.indices.shape
    bm, bn = ell.bm, ell.bn
    rows = jnp.repeat(jnp.arange(nbr, dtype=jnp.int32), w)
    cols = ell.indices.reshape(-1).astype(jnp.int32)
    blocks = ell.blocks.reshape(nbr * w, bm, bn)
    return BlockCOO(rows=rows, cols=cols, blocks=blocks, shape=ell.shape)


def transpose_coo(coo: BlockCOO) -> BlockCOO:
    """A.T in Block-COO: swap coordinates, transpose each block."""
    return BlockCOO(
        rows=coo.cols,
        cols=coo.rows,
        blocks=coo.blocks.transpose(0, 2, 1),
        shape=(coo.shape[1], coo.shape[0]),
    )


# ---------------------------------------------------------------------------
# SELL-C-σ ("sell") paths
# ---------------------------------------------------------------------------


def spmm_sell_ref(sell: SellCS, h, *, out_dtype=None):
    """Y = A @ H with A in SELL-C-σ — the scatter-free reference.

    One batched ``[rows, 1, w] @ [rows, w, D]`` contraction per width
    bucket (slices of equal width are contiguous), then a single epilogue
    gather that un-permutes rows and re-inserts the pruned all-zero rows.
    Work is proportional to the *packed slot* count — there is no global
    ELL width to pad to and no segment-sum scatter.
    """
    m, n = sell.shape
    d = h.shape[1]
    out_dtype = out_dtype or jnp.result_type(sell.slot_vals.dtype, h.dtype)
    if not sell.buckets:
        return jnp.zeros((m, d), out_dtype)
    outs = []
    off = 0
    for _, rows, width in sell.buckets:
        cols = sell.slot_cols[off:off + rows * width].reshape(rows, width)
        vals = sell.slot_vals[off:off + rows * width].reshape(rows, width)
        gathered = h[cols].astype(jnp.float32)  # [rows, w, D]
        out = jax.lax.dot_general(
            vals[:, None, :].astype(jnp.float32),
            gathered,
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        )  # [rows, 1, D]
        outs.append(out.reshape(rows, d))
        off += rows * width
    packed = jnp.concatenate(outs + [jnp.zeros((1, d), jnp.float32)])
    return packed[sell.out_gather].astype(out_dtype)


def spmm_sell(sell: SellCS, h, *, use_kernel: bool = False,
              interpret: bool = False, bd: Optional[int] = None,
              out_dtype=None):
    """Y = A @ H with A in SELL-C-σ; h carries the logical N rows."""
    if use_kernel or interpret:
        from repro.kernels.spmm.sell import spmm_sell_blocked

        return spmm_sell_blocked(sell, h, bd=bd, out_dtype=out_dtype,
                                 interpret=interpret)
    return spmm_sell_ref(sell, h, out_dtype=out_dtype)


def sample_sell(sell: SellCS, b, c, *, use_kernel: bool = False,
                interpret: bool = False, bk: Optional[int] = None):
    """Raw dots of B @ C at the packed slots (slot order).

    Padding slots sample at their repeated coordinates on the element
    route and read the appended zero cell on the tile route; either way
    the caller masks them against the structural values.
    """
    if use_kernel or interpret:
        from repro.kernels.sddmm.sell import sample_sell_blocked

        return sample_sell_blocked(sell, b, c, bk=bk, interpret=interpret)
    return sddmm_element_dots(sell.slot_rows, sell.slot_cols, b, c)


def densify_sell(sell: SellCS):
    """Device scatter of the slots (padding slots add zeros)."""
    m, n = sell.shape
    return jnp.zeros((m, n), sell.slot_vals.dtype) \
        .at[sell.slot_rows, sell.slot_cols].add(sell.slot_vals)


# ---------------------------------------------------------------------------
# Densify ("dense") paths — device scatter, trace-safe for every format
# ---------------------------------------------------------------------------


def densify_elements(row_ids, col_ids, values, shape: Tuple[int, int]):
    m, n = shape
    return jnp.zeros((m, n), values.dtype).at[row_ids, col_ids].add(values)


def densify_ell(ell: BlockELL):
    nbr, w, bm, bn = ell.blocks.shape
    nbc = ell.shape[1] // bn
    out = jnp.zeros((nbr, nbc, bm, bn), ell.blocks.dtype)
    out = out.at[jnp.arange(nbr)[:, None], ell.indices].add(ell.blocks)
    return out.transpose(0, 2, 1, 3).reshape(ell.shape)


def densify_coo(coo: BlockCOO):
    bm, bn = coo.bm, coo.bn
    nbr, nbc = coo.shape[0] // bm, coo.shape[1] // bn
    out = jnp.zeros((nbr, nbc, bm, bn), coo.blocks.dtype)
    out = out.at[coo.rows, coo.cols].add(coo.blocks)
    return out.transpose(0, 2, 1, 3).reshape(coo.shape)


def spmm_dense(a_dense, h):
    """Dense baseline (the paper's Fig. 2 failure mode)."""
    return a_dense @ h


def sample_blocks(full, rows, cols, bm: int, bn: int):
    """Gather (bm, bn) tiles of a full [M, N] product at block coords."""
    m, n = full.shape
    tiles = full.reshape(m // bm, bm, n // bn, bn).transpose(0, 2, 1, 3)
    return tiles[rows, cols]  # [nnzb, bm, bn]


def pad_rows(x, target: int):
    """Zero-pad x's leading dim up to ``target`` (no-op when equal)."""
    if x.shape[0] == target:
        return x
    return jnp.zeros((target,) + x.shape[1:], x.dtype).at[: x.shape[0]].set(x)


def pad_cols(x, target: int):
    if x.shape[1] == target:
        return x
    return jnp.zeros((x.shape[0], target), x.dtype) \
        .at[:, : x.shape[1]].set(x)
