"""Attention built on the paper's sparse primitives.

Block-sparse attention is the paper's flagship transformer application
(§1: "sparse attention in transformers"; §4.4: GAT).  An attention layer
with a block-sparse mask is exactly SDDMM -> masked softmax -> SpMM:

    S = M ⊙ (Q Kᵀ)        (SDDMM with sampling mask M)
    P = softmax(S)         (only over sampled blocks)
    O = P V                (SpMM with P in Block-ELL layout)

`local_block_attention` implements the fused banded case (sliding window)
directly: the kv-block index list per q-block is a *constant-width* band, so
the gather is uniform — the attention analog of the paper's equal-length
SELLPACK streams.  `flash_attention` is the dense/causal fallback (chunked
online softmax, memory O(q_chunk x kv_chunk)).

All functions take q:[B,S,Hq,D], k/v:[B,S,Hkv,D] (GQA: Hq % Hkv == 0) and
return [B,S,Hq,D].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime

NEG_INF = -1e30


def _split_gqa(q, n_kv: int):
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


# ---------------------------------------------------------------------------
# Dense reference (oracle for tests)
# ---------------------------------------------------------------------------


def mha_reference(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, scale: Optional[float] = None):
    """Plain O(S^2) masked attention — the test oracle."""
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = _split_gqa(q, n_kv)  # [B,S,Hkv,G,D]
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp; dense or causal)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024, scale: Optional[float] = None,
                    skip_masked_blocks: bool = False):
    """Online-softmax attention, O(q_chunk*kv_chunk) live scores.

    ``skip_masked_blocks``: with causal=True, kv chunks strictly above the
    diagonal are skipped per q-chunk via a bounded scan length — this halves
    the score FLOPs (the causal analog of not streaming NULL blocks; see
    EXPERIMENTS.md §Perf).
    """
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    if runtime.unrolled():
        override = runtime.attn_chunk_override()
        if override:
            q_chunk = kv_chunk = min(override, s)
        return _flash_attention_unrolled(
            q, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
            scale=scale, causal_skip=runtime.causal_skip())
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    nq, nk = s // q_chunk, s // kv_chunk

    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    q_blocks = qg.reshape(b, nq, q_chunk, n_kv, hq // n_kv, d)
    k_blocks = kf.reshape(b, nk, kv_chunk, n_kv, d)
    v_blocks = vf.reshape(b, nk, kv_chunk, n_kv, d)

    qpos_in = jnp.arange(q_chunk)
    kpos_in = jnp.arange(kv_chunk)

    def q_block_body(qi, q_blk):
        # q_blk: [B, q_chunk, Hkv, G, D]
        def kv_step(carry, ki):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_index_in_dim(k_blocks, ki, 1, False)
            v_blk = jax.lax.dynamic_index_in_dim(v_blocks, ki, 1, False)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            if causal:
                qpos = qi * q_chunk + qpos_in
                kpos = ki * kv_chunk + kpos_in
                mask = kpos[None, :] <= qpos[:, None]
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk)
            return (acc_new, m_new, l_new), None

        g = hq // n_kv
        acc0 = jnp.zeros((b, n_kv, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, n_kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        if causal and skip_masked_blocks and nk == nq and q_chunk == kv_chunk:
            # Only kv blocks [0..qi] can contribute; bound the scan with a
            # fori_loop of dynamic trip count qi+1.
            def fori_body(ki, carry):
                new_carry, _ = kv_step(carry, ki)
                return new_carry
            acc, m, l = jax.lax.fori_loop(
                0, qi + 1, fori_body, (acc0, m0, l0))
        else:
            (acc, m, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # [B, Hkv, G, q_chunk, D]

    outs = jax.lax.map(
        lambda args: q_block_body(*args),
        (jnp.arange(nq), q_blocks.transpose(1, 0, 2, 3, 4, 5)),
    )  # [nq, B, Hkv, G, q_chunk, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, hq, d)
    return out.astype(q.dtype)


def _flash_attention_unrolled(q, k, v, *, causal: bool, q_chunk: int,
                              kv_chunk: int, scale: float,
                              causal_skip: bool):
    """Straight-line (no lax loop) flash attention for cost-model compiles.

    ``causal_skip=True`` statically visits only kv chunks 0..i for q chunk
    i — exact causal FLOPs, differentiable (all slices static).
    """
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    nq, nk = s // q_chunk, s // kv_chunk
    g = hq // n_kv
    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    outs = []
    for qi in range(nq):
        q_blk = qg[:, qi * q_chunk:(qi + 1) * q_chunk]
        acc = jnp.zeros((b, n_kv, g, q_chunk, d), jnp.float32)
        m = jnp.full((b, n_kv, g, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, n_kv, g, q_chunk), jnp.float32)
        if causal and causal_skip:
            kv_range = [ki for ki in range(nk)
                        if ki * kv_chunk <= qi * q_chunk + q_chunk - 1]
        else:
            kv_range = list(range(nk))
        for ki in kv_range:
            k_blk = kf[:, ki * kv_chunk:(ki + 1) * kv_chunk]
            v_blk = vf[:, ki * kv_chunk:(ki + 1) * kv_chunk]
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            if causal:
                qpos = qi * q_chunk + np.arange(q_chunk)
                kpos = ki * kv_chunk + np.arange(kv_chunk)
                mask = kpos[None, :] <= qpos[:, None]
                if not mask.all():
                    logits = jnp.where(
                        jnp.asarray(mask)[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk)
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, d))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Banded block-sparse attention (sliding window) — the paper's technique
# ---------------------------------------------------------------------------


def local_block_attention(q, k, v, *, window: int, block: int = 512,
                          scale: Optional[float] = None):
    """Sliding-window causal attention as banded Block-ELL gather.

    Each q block attends to a constant-width band of kv blocks
    [i - w_blocks + 1, i]: the ELL index list per block-row has uniform
    width (the paper's equal-length streams), so the whole computation is a
    single uniform gather + batched matmul — SDDMM/softmax/SpMM fused.
    Memory/compute: O(S * window), independent of S^2.
    """
    b, s, hq, d = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    assert s % block == 0, (s, block)
    assert window % block == 0, (window, block)
    nq = s // block
    w_blocks = window // block + 1  # +1: the diagonal (causal partial) block

    qg = _split_gqa(q, n_kv).astype(jnp.float32)
    g = hq // n_kv
    q_blocks = qg.reshape(b, nq, block, n_kv, g, d)

    # Banded ELL indices: block-row i gathers kv blocks [i-w+1 .. i], clipped.
    rows = np.arange(nq)[:, None]
    ell = rows - np.arange(w_blocks - 1, -1, -1)[None, :]  # ascending kv idx
    valid = ell >= 0
    ell_idx = jnp.asarray(np.where(valid, ell, 0))  # [nq, w_blocks]
    valid = jnp.asarray(valid)

    k_blocks = k.astype(jnp.float32).reshape(b, nq, block, n_kv, d)
    v_blocks = v.astype(jnp.float32).reshape(b, nq, block, n_kv, d)
    k_g = k_blocks[:, ell_idx]  # [B, nq, w, block, Hkv, D]
    v_g = v_blocks[:, ell_idx]

    logits = jnp.einsum("bnqhgd,bnwkhd->bnhgqwk", q_blocks, k_g) * scale

    qpos = jnp.arange(block)[:, None, None]  # within-block q position
    kpos = jnp.arange(block)[None, None, :]
    # absolute positions: q = i*block + qpos ; k = ell[i,w]*block + kpos
    block_off = (ell_idx - rows)[..., None, :, None] * block  # [nq,1,w,1]
    rel = kpos + block_off - qpos  # k_abs - q_abs
    mask = (rel <= 0) & (rel > -window) & valid[:, None, :, None]
    logits = jnp.where(mask[None, :, None, None], logits, NEG_INF)

    flat = logits.reshape(*logits.shape[:-2], w_blocks * block)
    p = jax.nn.softmax(flat, axis=-1).reshape(logits.shape)
    out = jnp.einsum("bnhgqwk,bnwkhd->bnqhgd", p, v_g)
    return out.reshape(b, s, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, *, length=None,
                     window: Optional[int] = None,
                     scale: Optional[float] = None):
    """q: [B,1,Hq,D] against k/v cache [B,S,Hkv,D]; O(S) per token.

    ``length``: number of valid cache positions (int or [B] array).
    ``window``: restrict to the last ``window`` positions (local layers).
    """
    b, s, n_kv, d = k_cache.shape
    hq = q.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = _split_gqa(q, n_kv).astype(jnp.float32)[:, 0]  # [B,Hkv,G,D]
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg,
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)
    if length is None:
        length = s
    length = jnp.asarray(length)
    if length.ndim == 0:
        length = jnp.full((b,), length)
    mask = kpos[None, :] < length[:, None]  # [B,S]
    if window is not None:
        mask &= kpos[None, :] >= (length[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def decode_attention_partial(q, k_shard, v_shard, mask_shard, *, scale=None):
    """Per-shard flash-decode partial for sequence-parallel 500k decode.

    Returns (numerator [B,Hq,D], denominator [B,Hq], running max [B,Hq]).
    Partials from seq shards merge with `merge_partials` (psum-style tree
    fold) — the cross-chip analog of the paper's north->south partial-sum
    accumulation.
    """
    b, s, n_kv, d = k_shard.shape
    hq = q.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = _split_gqa(q, n_kv).astype(jnp.float32)[:, 0]
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg,
                        k_shard.astype(jnp.float32)) * scale
    logits = jnp.where(mask_shard[:, None, None, :], logits, NEG_INF)
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(axis=-1)
    num = jnp.einsum("bhgk,bkhd->bhgd", p, v_shard.astype(jnp.float32))
    return (num.reshape(b, hq, d), l.reshape(b, hq), m.reshape(b, hq))


def merge_partials(p1, p2):
    """Associative merge of two flash-decode partials."""
    n1, l1, m1 = p1
    n2, l2, m2 = p2
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return (n1 * a1[..., None] + n2 * a2[..., None], l1 * a1 + l2 * a2, m)
