"""Sparse storage formats.

The paper's SELLPACK-like format re-buckets nonzeros of A by the consumer
PE-row's column range and pads every stream to the same length so that all
I/O channels carry uniform traffic.  The TPU-native analog implemented here
is **Block-ELL**: A is tiled into (bm x bn) blocks, each block-row keeps its
nonzero blocks left-aligned and is padded to a fixed width W with zero
blocks whose index points at an arbitrary valid block (they contribute
exactly zero to the product, the MXU analog of NULL wavelets).

``BlockCOO`` is the SDDMM-side format: the paper stores the nonzeros of a
tile of A in COO on each worker; here each nonzero *block* carries its
(block-row, block-col) coordinates.

``CSR`` mirrors the paper's host-side baseline format and is what the
streaming-footprint accounting (Fig. 8 reproduction) starts from.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# CSR (host-side baseline; mirrors scipy.sparse.csr_matrix layout)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row; host-side (numpy) container.

    Index arrays are int32 end-to-end (matching every device-bound index
    array in the repo: BlockELL.indices/nblocks, BlockCOO.rows/cols, the
    expanded element ids); ``from_dense`` asserts nnz fits.
    """

    indptr: np.ndarray  # int32[M+1]
    indices: np.ndarray  # int32[nnz]
    values: np.ndarray  # dtype[nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @staticmethod
    def from_dense(dense: np.ndarray) -> "CSR":
        dense = np.asarray(dense)
        m, n = dense.shape
        mask = dense != 0
        counts = mask.sum(axis=1)
        indptr64 = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr64[1:])
        nnz = int(indptr64[-1])
        if nnz >= np.iinfo(np.int32).max:
            raise ValueError(
                f"nnz={nnz} overflows the int32 index space; shard the "
                "matrix before building CSR")
        idx = np.nonzero(mask)
        return CSR(
            indptr=indptr64.astype(np.int32),
            indices=idx[1].astype(np.int32),
            values=dense[idx],
            shape=(m, n),
        )

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.values.dtype)
        for r in range(m):
            lo, hi = self.indptr[r], self.indptr[r + 1]
            out[r, self.indices[lo:hi]] = self.values[lo:hi]
        return out

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes + self.values.nbytes


# ---------------------------------------------------------------------------
# Block-ELL (SELLPACK-like, TPU-adapted)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockELL:
    """Block-ELL sparse matrix.

    indices: int32[nbr, W]   block-column ids; padded slots point at slot 0's
                             block column (any valid id) and carry zero data.
    blocks:  dtype[nbr, W, bm, bn]  block data; padded slots are all-zero.
    nblocks: int32[nbr]      true (unpadded) block count per block-row.
    shape:   (M, N) logical dense shape (multiples of bm / bn after padding).
    """

    indices: Array
    blocks: Array
    nblocks: Array
    shape: Tuple[int, int]

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.indices, self.blocks, self.nblocks), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, blocks, nblocks = children
        return cls(indices=indices, blocks=blocks, nblocks=nblocks, shape=aux)

    # -- derived metadata ---------------------------------------------------
    @property
    def bm(self) -> int:
        return self.blocks.shape[2]

    @property
    def bn(self) -> int:
        return self.blocks.shape[3]

    @property
    def n_block_rows(self) -> int:
        return self.indices.shape[0]

    @property
    def ell_width(self) -> int:
        return self.indices.shape[1]

    @property
    def dtype(self):
        return self.blocks.dtype

    def nbytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in (self.indices, self.blocks, self.nblocks))

    # -- conversions ---------------------------------------------------------
    @staticmethod
    def from_dense(
        dense: np.ndarray,
        bm: int,
        bn: int,
        ell_width: int | None = None,
    ) -> "BlockELL":
        """Tile ``dense`` into (bm, bn) blocks and keep nonzero blocks.

        The dense input is zero-padded up to multiples of (bm, bn).  If
        ``ell_width`` is given, block-rows with more nonzero blocks raise.
        """
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
        if (mp, np_) != (m, n):
            pad = np.zeros((mp, np_), dtype=dense.dtype)
            pad[:m, :n] = dense
            dense = pad
        nbr, nbc = mp // bm, np_ // bn
        tiles = dense.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
        nz = tiles.reshape(nbr, nbc, -1).any(axis=-1)  # bool[nbr, nbc]
        counts = nz.sum(axis=1).astype(np.int32)
        width = int(counts.max()) if ell_width is None else int(ell_width)
        width = max(width, 1)
        if (counts > width).any():
            raise ValueError(
                f"ell_width={width} < max nonzero blocks per row "
                f"({int(counts.max())})")
        indices = np.zeros((nbr, width), dtype=np.int32)
        blocks = np.zeros((nbr, width, bm, bn), dtype=dense.dtype)
        for i in range(nbr):
            cols = np.nonzero(nz[i])[0]
            indices[i, : len(cols)] = cols
            blocks[i, : len(cols)] = tiles[i, cols]
            # padded slots: index 0 (or first real col), zero data
            if len(cols) == 0:
                indices[i, :] = 0
            else:
                indices[i, len(cols):] = cols[0]
        return BlockELL(
            indices=jnp.asarray(indices),
            blocks=jnp.asarray(blocks),
            nblocks=jnp.asarray(counts),
            shape=(mp, np_),
        )

    def to_dense(self) -> np.ndarray:
        """Inverse of from_dense (padded shape)."""
        indices = np.asarray(self.indices)
        blocks = np.asarray(self.blocks)
        nblocks = np.asarray(self.nblocks)
        nbr, w = indices.shape
        bm, bn = self.bm, self.bn
        nbc = self.shape[1] // bn
        out = np.zeros((nbr, nbc, bm, bn), dtype=blocks.dtype)
        for i in range(nbr):
            for s in range(int(nblocks[i])):
                out[i, indices[i, s]] += blocks[i, s]
        return out.transpose(0, 2, 1, 3).reshape(self.shape)

    def occupancy(self) -> float:
        """Fraction of ELL slots that hold real blocks (1.0 = no padding)."""
        total = self.n_block_rows * self.ell_width
        return float(np.asarray(self.nblocks).sum()) / max(total, 1)


# ---------------------------------------------------------------------------
# Block-COO (SDDMM-side format)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockCOO:
    """Coordinate list of nonzero (bm x bn) blocks.

    rows/cols: int32[nnzb] block coordinates (padded entries repeat slot 0 and
               carry an all-zero mask so they contribute nothing).
    blocks:    dtype[nnzb, bm, bn] block data (for SDDMM this is the sampling
               mask / values of A).
    """

    rows: Array
    cols: Array
    blocks: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.rows, self.cols, self.blocks), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, blocks = children
        return cls(rows=rows, cols=cols, blocks=blocks, shape=aux)

    @property
    def bm(self) -> int:
        return self.blocks.shape[1]

    @property
    def bn(self) -> int:
        return self.blocks.shape[2]

    @property
    def nnzb(self) -> int:
        return self.rows.shape[0]

    def nbytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in (self.rows, self.cols, self.blocks))

    @staticmethod
    def from_dense(
        dense: np.ndarray, bm: int, bn: int, pad_to: int | None = None
    ) -> "BlockCOO":
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
        if (mp, np_) != (m, n):
            pad = np.zeros((mp, np_), dtype=dense.dtype)
            pad[:m, :n] = dense
            dense = pad
        nbr, nbc = mp // bm, np_ // bn
        tiles = dense.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
        nz = tiles.reshape(nbr, nbc, -1).any(axis=-1)
        ridx, cidx = np.nonzero(nz)
        nnzb = len(ridx)
        if nnzb == 0:
            ridx, cidx = np.zeros(1, np.int64), np.zeros(1, np.int64)
            blocks = np.zeros((1, bm, bn), dtype=dense.dtype)
            nnzb = 1
        else:
            blocks = tiles[ridx, cidx]
        if pad_to is not None and pad_to > nnzb:
            padn = pad_to - nnzb
            ridx = np.concatenate([ridx, np.full(padn, ridx[0])])
            cidx = np.concatenate([cidx, np.full(padn, cidx[0])])
            blocks = np.concatenate(
                [blocks, np.zeros((padn, bm, bn), dtype=blocks.dtype)])
        return BlockCOO(
            rows=jnp.asarray(ridx, jnp.int32),
            cols=jnp.asarray(cidx, jnp.int32),
            blocks=jnp.asarray(blocks),
            shape=(mp, np_),
        )

    def to_dense(self) -> np.ndarray:
        rows = np.asarray(self.rows)
        cols = np.asarray(self.cols)
        blocks = np.asarray(self.blocks)
        bm, bn = self.bm, self.bn
        nbr, nbc = self.shape[0] // bm, self.shape[1] // bn
        out = np.zeros((nbr, nbc, bm, bn), dtype=blocks.dtype)
        # Padded duplicates carry zero blocks; += keeps them harmless.
        np.add.at(out, (rows, cols), blocks)
        return out.transpose(0, 2, 1, 3).reshape(self.shape)


# ---------------------------------------------------------------------------
# SELL-C-σ (tile-pruned, row-sorted packing for the hyper-sparse regime)
# ---------------------------------------------------------------------------

# Defaults shared by the packer and the stats layer (they must agree so
# the cost model prices exactly the layout the packer would build).
SELL_C = 8          # slice height (rows per width-adaptive slice)
SELL_SIGMA = 0      # sort-window size in rows; 0 = sort the whole matrix

# Geometric width ladder (~1.5x growth): slice widths round *up* onto it,
# so padding is bounded (<= 50 %, typically ~10 %) while the number of
# distinct widths — and hence jnp reference buckets — stays O(log nnz).
def _width_ladder(upto: int) -> np.ndarray:
    vals = [1]
    while vals[-1] < upto:
        q = vals[-1]
        vals.append(q + 1 if q < 2 else q * 3 // 2)
    return np.array(vals, dtype=np.int64)


def _quantize_width(w: int) -> int:
    if w <= 0:
        return 0
    return int(_width_ladder(w)[-1])


def _sell_row_order(row_nnz: np.ndarray, c: int, sigma: int,
                    width_slack: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(row order, quantized slice widths) of the SELL-C-σ packing.

    Rows are sorted by nnz (descending, stable) within ``sigma``-row
    windows, grouped into slices of ``c`` rows, and each slice's width is
    the quantized max nnz of its rows.  Pure function of the per-row
    nonzero counts — `MatrixStats` uses it to price the layout without
    packing anything, so it runs on every stats construction and stays
    vectorized.

    ``width_slack`` reserves that many extra (zero) slots per row of
    every non-empty slice *before* quantization — the mutable-overlay
    headroom ``DeltaGraph`` patches edge inserts into.  The default 0
    reproduces the historical packing exactly (and is what the stats
    layer prices).
    """
    m = len(row_nnz)
    mp = _cdiv(max(m, 1), c) * c
    padded = np.zeros(mp, dtype=np.int64)
    padded[:m] = row_nnz
    sigma = sigma if sigma and sigma > 0 else mp
    order = np.concatenate([
        w0 + np.argsort(-padded[w0:w0 + sigma], kind="stable")
        for w0 in range(0, mp, sigma)
    ]) if mp else np.zeros(0, np.int64)
    slice_max = padded[order].reshape(-1, c).max(axis=1) if mp \
        else np.zeros(0, np.int64)
    target = np.where(slice_max > 0, slice_max + int(width_slack), 0)
    ladder = _width_ladder(int(target.max()) if len(target) else 1)
    widths = np.where(
        target > 0,
        ladder[np.searchsorted(ladder, target, side="left")
               .clip(max=len(ladder) - 1)],
        0)
    return order, widths


def sell_slot_volume(row_nnz: np.ndarray, c: int = SELL_C,
                     sigma: int = SELL_SIGMA) -> int:
    """Padded slot count of the SELL-C-σ packing (empty slices pruned).

    This is the `stored_elements` analog for the sell path: the exact
    number of (col, value) slots the packed layout streams.
    """
    _, widths = _sell_row_order(np.asarray(row_nnz), c, sigma)
    return int(widths.sum()) * c


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SellCS:
    """SELL-C-σ sparse matrix with a tile-pruned block companion view.

    Two synchronized views of the same nonzeros:

    **Slot view** (element-granular, the differentiable storage): rows
    sorted by nnz within σ-windows, grouped into slices of C rows, each
    slice padded to its own quantized width — never to a global max, so
    the hyper-sparsity padding cliff of Block-ELL cannot happen.  Slices
    whose width is 0 (all-empty rows) are dropped entirely.  Same-width
    slices are stored contiguously (``buckets``), so the jnp reference
    runs one scatter-free batched contraction per width bucket.

    * ``slot_cols``/``slot_rows``: int32[n_slots] original coordinates
      per slot (padding slots repeat the row's first column and carry
      zero values).
    * ``slot_vals``: dtype[n_slots] — THE values leaf; gradients flow
      here, padding slots are structural zeros.
    * ``out_gather``: int32[M] original row -> packed row (``n_packed``
      for rows in pruned slices; the consumer appends a zero row).

    **Tile view** (block-granular, what the Pallas kernels iterate):
    the packed row axis is tiled into (bm x bn) blocks and only live
    (non-empty) tiles are kept, ordered block-row-major.  Block-rows
    with no live tile are never launched — the explicit non-empty-tile
    map of the kernel grid.

    * ``perm``: int32[n_live*bm] live packed row -> original row (M for
      padding rows) — the row gather SDDMM applies to B.
    * ``tile_rows``/``tile_cols``: int32[T] live-tile coordinates
      (compacted block-row, original block-column).
    * ``tile_slot_map``: int32[T, bm, bn] tile cell -> slot id
      (``n_slots`` for dead cells) — tile data is gathered from
      ``slot_vals`` so the values live exactly once.
    * ``slot_tile_pos``: int32[n_slots] slot -> flat tile-cell position
      (``T*bm*bn`` for padding slots) — how SDDMM tile output folds
      back into slot order.
    * ``tile_out_gather``: int32[M] original row -> row of the compact
      kernel output (``n_live*bm`` for pruned rows).

    Static aux: logical ``shape``, slice height ``c``, sort window
    ``sigma`` (0 = whole matrix), ``buckets`` — a tuple of
    ``(row_offset, n_rows, width)`` per width bucket in storage order —
    the tile ``block`` and the live block-row count.
    """

    slot_cols: Array
    slot_rows: Array
    slot_vals: Array
    out_gather: Array
    perm: Array
    tile_rows: Array
    tile_cols: Array
    tile_slot_map: Array
    slot_tile_pos: Array
    tile_out_gather: Array
    shape: Tuple[int, int]
    c: int
    sigma: int
    buckets: Tuple[Tuple[int, int, int], ...]
    block: Tuple[int, int]
    n_live_block_rows: int

    _CHILDREN = ("slot_cols", "slot_rows", "slot_vals", "out_gather",
                 "perm", "tile_rows", "tile_cols", "tile_slot_map",
                 "slot_tile_pos", "tile_out_gather")

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        children = tuple(getattr(self, f) for f in self._CHILDREN)
        aux = (self.shape, self.c, self.sigma, self.buckets, self.block,
               self.n_live_block_rows)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, c, sigma, buckets, block, n_live = aux
        return cls(*children, shape=shape, c=c, sigma=sigma,
                   buckets=buckets, block=block, n_live_block_rows=n_live)

    # -- derived metadata ---------------------------------------------------
    @property
    def bm(self) -> int:
        return self.block[0]

    @property
    def bn(self) -> int:
        return self.block[1]

    @property
    def n_slots(self) -> int:
        return sum(r * w for _, r, w in self.buckets)

    @property
    def n_packed_rows(self) -> int:
        return sum(r for _, r, _ in self.buckets)

    @property
    def n_tiles(self) -> int:
        return int(self.tile_rows.shape[0])

    @property
    def dtype(self):
        return self.slot_vals.dtype

    def nbytes(self) -> int:
        return sum(int(np.prod(np.shape(getattr(self, f))))
                   * np.dtype(getattr(self, f).dtype).itemsize
                   for f in self._CHILDREN)

    def stream_elements(self) -> int:
        """Slots the packed layout streams (the sell `stored_elements`)."""
        return self.n_slots

    # -- conversions ---------------------------------------------------------
    @staticmethod
    def from_dense(dense: np.ndarray, *, c: int = SELL_C,
                   sigma: int = SELL_SIGMA,
                   block: Tuple[int, int] = (64, 64),
                   width_slack: int = 0) -> "SellCS":
        """Pack a concrete dense matrix into SELL-C-σ.

        ``block`` sets the (bm, bn) tile geometry of the kernel view; it
        is independent of the slice height ``c``.  ``width_slack``
        reserves extra zero slots per row of every non-empty slice (the
        in-place-patchable headroom a ``DeltaGraph`` overlay consumes);
        0 keeps the historical packing.
        """
        dense = np.asarray(dense)
        m, n = dense.shape
        bm, bn = block
        row_nnz = (dense != 0).sum(axis=1)
        order, widths = _sell_row_order(row_nnz, c, sigma, width_slack)
        mp = len(order)

        # group equal-width slices into buckets (ascending width); the
        # packed row order is bucket-major, slice-order-preserving
        by_width: Dict[int, list] = {}
        for s, w in enumerate(widths):
            if w > 0:
                by_width.setdefault(int(w), []).append(s)
        buckets = []
        packed_rows = []  # original (padded) row id per packed row
        for w in sorted(by_width):
            slices = by_width[w]
            buckets.append((len(packed_rows), len(slices) * c, w))
            for s in slices:
                packed_rows.extend(order[s * c:(s + 1) * c])
        n_packed = len(packed_rows)

        # slot view (one nonzero scan per row, reused by the tile view)
        n_slots = sum(r * w for _, r, w in buckets)
        slot_cols = np.zeros(n_slots, np.int32)
        slot_rows = np.zeros(n_slots, np.int32)
        slot_vals = np.zeros(n_slots, dense.dtype)
        out_gather = np.full(m, n_packed, np.int32)
        slot_start = {}  # packed row -> offset of its first slot
        row_cols = {}    # packed row -> its nonzero column indices
        off = 0
        for row_off, n_rows, w in buckets:
            for i in range(n_rows):
                r = packed_rows[row_off + i]
                lo = off + i * w
                slot_start[row_off + i] = lo
                if r < m:
                    cc = np.nonzero(dense[r])[0]
                    row_cols[row_off + i] = cc
                    k = len(cc)
                    slot_cols[lo:lo + w] = cc[0] if k else 0
                    slot_cols[lo:lo + k] = cc
                    slot_rows[lo:lo + w] = r
                    slot_vals[lo:lo + k] = dense[r, cc]
                    out_gather[r] = row_off + i
                # rows >= m are slice padding: zero slots at (0, 0)
            off += n_rows * w

        # tile view: block the packed row axis, keep live tiles only
        tiles: Dict[Tuple[int, int], np.ndarray] = {}
        for p, cc in row_cols.items():
            lo = slot_start[p]
            for k, col in enumerate(cc):
                key = (p // bm, col // bn)
                cell = tiles.get(key)
                if cell is None:
                    cell = np.full((bm, bn), n_slots, np.int32)
                    tiles[key] = cell
                cell[p % bm, col % bn] = lo + k

        live_brs = sorted({br for br, _ in tiles})
        br_compact = {br: i for i, br in enumerate(live_brs)}
        n_live = len(live_brs)
        keys = sorted(tiles)  # block-row-major, then block-column
        t_count = len(keys)
        tile_rows = np.zeros(t_count, np.int32)
        tile_cols = np.zeros(t_count, np.int32)
        tile_slot_map = np.full((t_count, bm, bn), n_slots, np.int32)
        for t, (br, bc) in enumerate(keys):
            tile_rows[t] = br_compact[br]
            tile_cols[t] = bc
            tile_slot_map[t] = tiles[(br, bc)]
        slot_tile_pos = np.full(n_slots, t_count * bm * bn, np.int32)
        flat = tile_slot_map.reshape(-1)
        live = flat < n_slots
        slot_tile_pos[flat[live]] = np.nonzero(live)[0].astype(np.int32)

        # perm: live packed row -> original row (M for padding rows)
        perm = np.full(n_live * bm, m, np.int32)
        tile_out_gather = np.full(m, n_live * bm, np.int32)
        for i, br in enumerate(live_brs):
            for j in range(bm):
                p = br * bm + j
                if p < n_packed and packed_rows[p] < m:
                    perm[i * bm + j] = packed_rows[p]
                    tile_out_gather[packed_rows[p]] = i * bm + j

        return SellCS(
            slot_cols=jnp.asarray(slot_cols),
            slot_rows=jnp.asarray(slot_rows),
            slot_vals=jnp.asarray(slot_vals),
            out_gather=jnp.asarray(out_gather),
            perm=jnp.asarray(perm),
            tile_rows=jnp.asarray(tile_rows),
            tile_cols=jnp.asarray(tile_cols),
            tile_slot_map=jnp.asarray(tile_slot_map),
            slot_tile_pos=jnp.asarray(slot_tile_pos),
            tile_out_gather=jnp.asarray(tile_out_gather),
            shape=(m, n),
            c=c,
            sigma=sigma,
            buckets=tuple(buckets),
            block=(bm, bn),
            n_live_block_rows=n_live,
        )

    def to_dense(self) -> np.ndarray:
        """Host densification (scatter the slots; padding adds zeros)."""
        m, n = self.shape
        out = np.zeros((m, n), np.asarray(self.slot_vals).dtype)
        rows = np.asarray(self.slot_rows)
        cols = np.asarray(self.slot_cols)
        vals = np.asarray(self.slot_vals)
        np.add.at(out, (rows, cols), vals)
        return out

    def occupancy(self) -> float:
        """Real nonzeros per stored slot (1.0 = zero padding)."""
        nnz = int(np.count_nonzero(np.asarray(self.slot_vals)))
        return nnz / max(self.n_slots, 1)


# ---------------------------------------------------------------------------
# Paper-faithful SELLPACK-like stream accounting (Fig. 8 reproduction)
# ---------------------------------------------------------------------------


def sellpack_stream_elements(
    csr: CSR, max_y_chunk: int, max_v_per_pe: int
) -> int:
    """Total (index,value)-pair count streamed in the paper's SELLPACK-like
    format.

    The host slices A into chunks of ``max_y_chunk`` rows.  Within a chunk,
    the nonzeros of each row are re-bucketed by worker-row column range
    (``max_v_per_pe`` wide).  Every bucket's stream carries one END_ROW
    marker per *run* of row terminations (run-length encoded: consecutive
    empty rows collapse into a single END_ROW pair), and all streams in a
    chunk are padded with NULLs to the chunk's longest stream.
    """
    m, n = csr.shape
    n_buckets = _cdiv(n, max_v_per_pe)
    total = 0
    for c0 in range(0, m, max_y_chunk):
        c1 = min(c0 + max_y_chunk, m)
        # per-bucket stream length for this chunk
        lengths = np.zeros(n_buckets, dtype=np.int64)
        # nonzero counts: bucket each row's column indices
        prev_emitted_end = np.zeros(n_buckets, dtype=bool)
        for r in range(c0, c1):
            lo, hi = csr.indptr[r], csr.indptr[r + 1]
            cols = csr.indices[lo:hi]
            counts = np.bincount(cols // max_v_per_pe, minlength=n_buckets)
            lengths += counts
            # END_ROW run-length coding: a bucket that receives nonzeros for
            # this row must emit an END_ROW afterwards; a bucket receiving
            # nothing extends the previous END_ROW run (no new element).
            has_data = counts > 0
            new_end = has_data | ~prev_emitted_end
            lengths += new_end.astype(np.int64)
            prev_emitted_end = np.ones(n_buckets, dtype=bool)
        total += int(lengths.max()) * n_buckets  # NULL-padded to equal length
    return total


def blockell_stream_elements(ell: BlockELL) -> int:
    """Elements (index or value words) resident in the Block-ELL layout —
    the TPU analog of the paper's streamed-element count."""
    return int(np.prod(ell.blocks.shape)) + int(np.prod(ell.indices.shape))
