"""Sparse storage formats.

The paper's SELLPACK-like format re-buckets nonzeros of A by the consumer
PE-row's column range and pads every stream to the same length so that all
I/O channels carry uniform traffic.  The TPU-native analog implemented here
is **Block-ELL**: A is tiled into (bm x bn) blocks, each block-row keeps its
nonzero blocks left-aligned and is padded to a fixed width W with zero
blocks whose index points at an arbitrary valid block (they contribute
exactly zero to the product, the MXU analog of NULL wavelets).

``BlockCOO`` is the SDDMM-side format: the paper stores the nonzeros of a
tile of A in COO on each worker; here each nonzero *block* carries its
(block-row, block-col) coordinates.

``CSR`` mirrors the paper's host-side baseline format and is what the
streaming-footprint accounting (Fig. 8 reproduction) starts from.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# CSR (host-side baseline; mirrors scipy.sparse.csr_matrix layout)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row; host-side (numpy) container.

    Index arrays are int32 end-to-end (matching every device-bound index
    array in the repo: BlockELL.indices/nblocks, BlockCOO.rows/cols, the
    expanded element ids); ``from_dense`` asserts nnz fits.
    """

    indptr: np.ndarray  # int32[M+1]
    indices: np.ndarray  # int32[nnz]
    values: np.ndarray  # dtype[nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @staticmethod
    def from_dense(dense: np.ndarray) -> "CSR":
        dense = np.asarray(dense)
        m, n = dense.shape
        mask = dense != 0
        counts = mask.sum(axis=1)
        indptr64 = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr64[1:])
        nnz = int(indptr64[-1])
        if nnz >= np.iinfo(np.int32).max:
            raise ValueError(
                f"nnz={nnz} overflows the int32 index space; shard the "
                "matrix before building CSR")
        idx = np.nonzero(mask)
        return CSR(
            indptr=indptr64.astype(np.int32),
            indices=idx[1].astype(np.int32),
            values=dense[idx],
            shape=(m, n),
        )

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        out = np.zeros((m, n), dtype=self.values.dtype)
        for r in range(m):
            lo, hi = self.indptr[r], self.indptr[r + 1]
            out[r, self.indices[lo:hi]] = self.values[lo:hi]
        return out

    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes + self.values.nbytes


# ---------------------------------------------------------------------------
# Block-ELL (SELLPACK-like, TPU-adapted)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockELL:
    """Block-ELL sparse matrix.

    indices: int32[nbr, W]   block-column ids; padded slots point at slot 0's
                             block column (any valid id) and carry zero data.
    blocks:  dtype[nbr, W, bm, bn]  block data; padded slots are all-zero.
    nblocks: int32[nbr]      true (unpadded) block count per block-row.
    shape:   (M, N) logical dense shape (multiples of bm / bn after padding).
    """

    indices: Array
    blocks: Array
    nblocks: Array
    shape: Tuple[int, int]

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.indices, self.blocks, self.nblocks), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, blocks, nblocks = children
        return cls(indices=indices, blocks=blocks, nblocks=nblocks, shape=aux)

    # -- derived metadata ---------------------------------------------------
    @property
    def bm(self) -> int:
        return self.blocks.shape[2]

    @property
    def bn(self) -> int:
        return self.blocks.shape[3]

    @property
    def n_block_rows(self) -> int:
        return self.indices.shape[0]

    @property
    def ell_width(self) -> int:
        return self.indices.shape[1]

    @property
    def dtype(self):
        return self.blocks.dtype

    def nbytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in (self.indices, self.blocks, self.nblocks))

    # -- conversions ---------------------------------------------------------
    @staticmethod
    def from_dense(
        dense: np.ndarray,
        bm: int,
        bn: int,
        ell_width: int | None = None,
    ) -> "BlockELL":
        """Tile ``dense`` into (bm, bn) blocks and keep nonzero blocks.

        The dense input is zero-padded up to multiples of (bm, bn).  If
        ``ell_width`` is given, block-rows with more nonzero blocks raise.
        """
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
        if (mp, np_) != (m, n):
            pad = np.zeros((mp, np_), dtype=dense.dtype)
            pad[:m, :n] = dense
            dense = pad
        nbr, nbc = mp // bm, np_ // bn
        tiles = dense.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
        nz = tiles.reshape(nbr, nbc, -1).any(axis=-1)  # bool[nbr, nbc]
        counts = nz.sum(axis=1).astype(np.int32)
        width = int(counts.max()) if ell_width is None else int(ell_width)
        width = max(width, 1)
        if (counts > width).any():
            raise ValueError(
                f"ell_width={width} < max nonzero blocks per row "
                f"({int(counts.max())})")
        indices = np.zeros((nbr, width), dtype=np.int32)
        blocks = np.zeros((nbr, width, bm, bn), dtype=dense.dtype)
        for i in range(nbr):
            cols = np.nonzero(nz[i])[0]
            indices[i, : len(cols)] = cols
            blocks[i, : len(cols)] = tiles[i, cols]
            # padded slots: index 0 (or first real col), zero data
            if len(cols) == 0:
                indices[i, :] = 0
            else:
                indices[i, len(cols):] = cols[0]
        return BlockELL(
            indices=jnp.asarray(indices),
            blocks=jnp.asarray(blocks),
            nblocks=jnp.asarray(counts),
            shape=(mp, np_),
        )

    def to_dense(self) -> np.ndarray:
        """Inverse of from_dense (padded shape)."""
        indices = np.asarray(self.indices)
        blocks = np.asarray(self.blocks)
        nblocks = np.asarray(self.nblocks)
        nbr, w = indices.shape
        bm, bn = self.bm, self.bn
        nbc = self.shape[1] // bn
        out = np.zeros((nbr, nbc, bm, bn), dtype=blocks.dtype)
        for i in range(nbr):
            for s in range(int(nblocks[i])):
                out[i, indices[i, s]] += blocks[i, s]
        return out.transpose(0, 2, 1, 3).reshape(self.shape)

    def occupancy(self) -> float:
        """Fraction of ELL slots that hold real blocks (1.0 = no padding)."""
        total = self.n_block_rows * self.ell_width
        return float(np.asarray(self.nblocks).sum()) / max(total, 1)


# ---------------------------------------------------------------------------
# Block-COO (SDDMM-side format)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockCOO:
    """Coordinate list of nonzero (bm x bn) blocks.

    rows/cols: int32[nnzb] block coordinates (padded entries repeat slot 0 and
               carry an all-zero mask so they contribute nothing).
    blocks:    dtype[nnzb, bm, bn] block data (for SDDMM this is the sampling
               mask / values of A).
    """

    rows: Array
    cols: Array
    blocks: Array
    shape: Tuple[int, int]

    def tree_flatten(self):
        return (self.rows, self.cols, self.blocks), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, blocks = children
        return cls(rows=rows, cols=cols, blocks=blocks, shape=aux)

    @property
    def bm(self) -> int:
        return self.blocks.shape[1]

    @property
    def bn(self) -> int:
        return self.blocks.shape[2]

    @property
    def nnzb(self) -> int:
        return self.rows.shape[0]

    def nbytes(self) -> int:
        return sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in (self.rows, self.cols, self.blocks))

    @staticmethod
    def from_dense(
        dense: np.ndarray, bm: int, bn: int, pad_to: int | None = None
    ) -> "BlockCOO":
        dense = np.asarray(dense)
        m, n = dense.shape
        mp, np_ = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
        if (mp, np_) != (m, n):
            pad = np.zeros((mp, np_), dtype=dense.dtype)
            pad[:m, :n] = dense
            dense = pad
        nbr, nbc = mp // bm, np_ // bn
        tiles = dense.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
        nz = tiles.reshape(nbr, nbc, -1).any(axis=-1)
        ridx, cidx = np.nonzero(nz)
        nnzb = len(ridx)
        if nnzb == 0:
            ridx, cidx = np.zeros(1, np.int64), np.zeros(1, np.int64)
            blocks = np.zeros((1, bm, bn), dtype=dense.dtype)
            nnzb = 1
        else:
            blocks = tiles[ridx, cidx]
        if pad_to is not None and pad_to > nnzb:
            padn = pad_to - nnzb
            ridx = np.concatenate([ridx, np.full(padn, ridx[0])])
            cidx = np.concatenate([cidx, np.full(padn, cidx[0])])
            blocks = np.concatenate(
                [blocks, np.zeros((padn, bm, bn), dtype=blocks.dtype)])
        return BlockCOO(
            rows=jnp.asarray(ridx, jnp.int32),
            cols=jnp.asarray(cidx, jnp.int32),
            blocks=jnp.asarray(blocks),
            shape=(mp, np_),
        )

    def to_dense(self) -> np.ndarray:
        rows = np.asarray(self.rows)
        cols = np.asarray(self.cols)
        blocks = np.asarray(self.blocks)
        bm, bn = self.bm, self.bn
        nbr, nbc = self.shape[0] // bm, self.shape[1] // bn
        out = np.zeros((nbr, nbc, bm, bn), dtype=blocks.dtype)
        # Padded duplicates carry zero blocks; += keeps them harmless.
        np.add.at(out, (rows, cols), blocks)
        return out.transpose(0, 2, 1, 3).reshape(self.shape)


# ---------------------------------------------------------------------------
# Paper-faithful SELLPACK-like stream accounting (Fig. 8 reproduction)
# ---------------------------------------------------------------------------


def sellpack_stream_elements(
    csr: CSR, max_y_chunk: int, max_v_per_pe: int
) -> int:
    """Total (index,value)-pair count streamed in the paper's SELLPACK-like
    format.

    The host slices A into chunks of ``max_y_chunk`` rows.  Within a chunk,
    the nonzeros of each row are re-bucketed by worker-row column range
    (``max_v_per_pe`` wide).  Every bucket's stream carries one END_ROW
    marker per *run* of row terminations (run-length encoded: consecutive
    empty rows collapse into a single END_ROW pair), and all streams in a
    chunk are padded with NULLs to the chunk's longest stream.
    """
    m, n = csr.shape
    n_buckets = _cdiv(n, max_v_per_pe)
    total = 0
    for c0 in range(0, m, max_y_chunk):
        c1 = min(c0 + max_y_chunk, m)
        # per-bucket stream length for this chunk
        lengths = np.zeros(n_buckets, dtype=np.int64)
        # nonzero counts: bucket each row's column indices
        prev_emitted_end = np.zeros(n_buckets, dtype=bool)
        for r in range(c0, c1):
            lo, hi = csr.indptr[r], csr.indptr[r + 1]
            cols = csr.indices[lo:hi]
            counts = np.bincount(cols // max_v_per_pe, minlength=n_buckets)
            lengths += counts
            # END_ROW run-length coding: a bucket that receives nonzeros for
            # this row must emit an END_ROW afterwards; a bucket receiving
            # nothing extends the previous END_ROW run (no new element).
            has_data = counts > 0
            new_end = has_data | ~prev_emitted_end
            lengths += new_end.astype(np.int64)
            prev_emitted_end = np.ones(n_buckets, dtype=bool)
        total += int(lengths.max()) * n_buckets  # NULL-padded to equal length
    return total


def blockell_stream_elements(ell: BlockELL) -> int:
    """Elements (index or value words) resident in the Block-ELL layout —
    the TPU analog of the paper's streamed-element count."""
    return int(np.prod(ell.blocks.shape)) + int(np.prod(ell.indices.shape))
