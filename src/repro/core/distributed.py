"""Distributed SpMM / SDDMM decompositions (paper §2.4) on a TPU mesh.

The paper frames CS-3 SpMM as a distributed matmul: A streamed (not
resident), H partitioned over the PE grid => a 1.5D decomposition; H
replicated across sub-grids => 2.5D.  Across TPU chips the same taxonomy
maps onto shard_map programs:

  1.5D  A block-rows sharded over `data`; H row-sharded over `data`;
        each shard all-gathers H (comm volume N*D/p per chip per step —
        exactly the 1.5D cost), then runs the local Block-ELL kernel.
  2D    A block-rows sharded over `data`; H column-sharded over `model`;
        zero communication — each chip owns a (M/p_d, D/p_m) output tile.
        (The degenerate-communication point of the taxonomy; possible
        because every chip can hold its H column slice, unlike a CS-3 PE.)
  2.5D  multi-pod: H replicated across the `pod` axis so the 1.5D
        all-gather stays on intra-pod ICI; A sharded over (pod, data).

`allgather_matmul_overlap` is the collective-matmul trick (bidirectional
ppermute ring) used to hide the 1.5D all-gather behind the local SpMM —
compute/comm overlap, the cross-chip version of the paper's accumulator-
row buffering (§3.1.3).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.formats import BlockELL
from repro.kernels.spmm.ops import spmm_blockell


def _as_blockell(a) -> BlockELL:
    """Accept a BlockELL or a ``repro.sparse.SparseMatrix``.

    The distributed decompositions shard the blocked layout; a
    SparseMatrix is unwrapped to its ``"ell"`` form (converting host-side
    if it carries only other forms).
    """
    from repro.sparse.matrix import SparseMatrix

    if isinstance(a, SparseMatrix):
        if "ell" not in a.formats:
            a = a.to("ell")
        return a.form("ell")
    return a


def _ell_specs(ell: BlockELL, row_axis) -> BlockELL:
    """PartitionSpec pytree matching a BlockELL (block-rows sharded)."""
    leaves, treedef = jax.tree_util.tree_flatten(ell)
    specs = [
        P(row_axis, None),              # indices [nbr, W]
        P(row_axis, None, None, None),  # blocks  [nbr, W, bm, bn]
        P(row_axis),                    # nblocks [nbr]
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def spmm_1p5d(ell, h, mesh: Mesh, *, row_axis: str = "data",
              use_kernel: bool = False):
    """1.5D: A row-sharded, H row-sharded + all-gathered per step.

    ``ell``: BlockELL or ``repro.sparse.SparseMatrix``.
    """
    ell = _as_blockell(ell)

    def local(ell_shard: BlockELL, h_shard):
        h_full = jax.lax.all_gather(h_shard, row_axis, axis=0, tiled=True)
        return spmm_blockell(ell_shard, h_full, use_kernel=use_kernel)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(_ell_specs(ell, row_axis), P(row_axis, None)),
        out_specs=P(row_axis, None),
        check_rep=False,
    )
    return fn(ell, h)


def spmm_2d(ell, h, mesh: Mesh, *, row_axis: str = "data",
            col_axis: str = "model", use_kernel: bool = False):
    """2D: A row-sharded over data, H column-sharded over model; no comm.

    ``ell``: BlockELL or ``repro.sparse.SparseMatrix``.
    """
    ell = _as_blockell(ell)

    def local(ell_shard: BlockELL, h_shard):
        return spmm_blockell(ell_shard, h_shard, use_kernel=use_kernel)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(_ell_specs(ell, row_axis), P(None, col_axis)),
        out_specs=P(row_axis, col_axis),
        check_rep=False,
    )
    return fn(ell, h)


def spmm_2p5d(ell, h, mesh: Mesh, *, pod_axis: str = "pod",
              row_axis: str = "data", use_kernel: bool = False):
    """2.5D multi-pod: H replicated across pods; all-gather intra-pod only.

    A's block-rows are sharded over (pod, data) jointly; each pod computes
    its row stripe of Y independently — inter-pod traffic is zero inside
    the kernel (the paper's replication-trades-memory-for-comm point).
    ``ell``: BlockELL or ``repro.sparse.SparseMatrix``.
    """
    ell = _as_blockell(ell)

    def local(ell_shard: BlockELL, h_shard):
        h_full = jax.lax.all_gather(h_shard, row_axis, axis=0, tiled=True)
        return spmm_blockell(ell_shard, h_full, use_kernel=use_kernel)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            _ell_specs(ell, (pod_axis, row_axis)),
            P(row_axis, None),  # H row-sharded over data, replicated on pod
        ),
        out_specs=P((pod_axis, row_axis), None),
        check_rep=False,
    )
    return fn(ell, h)


# ---------------------------------------------------------------------------
# Collective matmul: all-gather overlapped with compute via a ppermute ring
# ---------------------------------------------------------------------------


def allgather_matmul_overlap(x, w, mesh: Mesh, *, axis: str = "model"):
    """y = x @ w_full where w is row-sharded over `axis`.

    Instead of all-gather(w) then matmul (serializing comm before compute),
    runs a ring: at step t each chip multiplies the w shard it currently
    holds against the matching x column slice while ppermute-ing the shard
    to its neighbor — comm hidden behind the per-step matmul.
    x: [..., K] (replicated on `axis`); w: [K, N] sharded on rows (K).
    """
    n = mesh.shape[axis]

    def local(x_local, w_shard):
        idx = jax.lax.axis_index(axis)
        k_shard = w_shard.shape[0]
        perm = [(i, (i + 1) % n) for i in range(n)]

        def step(carry, t):
            acc, w_cur = carry
            # shard currently held originated at chip (idx - t) mod n
            src = (idx - t) % n
            x_slice = jax.lax.dynamic_slice_in_dim(
                x_local, src * k_shard, k_shard, axis=-1)
            acc = acc + jnp.einsum("...k,kn->...n", x_slice, w_cur)
            w_next = jax.lax.ppermute(w_cur, axis, perm)
            return (acc, w_next), None

        acc0 = jnp.zeros(x_local.shape[:-1] + (w_shard.shape[1],),
                         jnp.promote_types(x_local.dtype, w_shard.dtype))
        (acc, _), _ = jax.lax.scan(step, (acc0, w_shard), jnp.arange(n))
        return acc

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axis, None)),
        out_specs=P(),
        check_rep=False,
    )
    return fn(x, w)
