"""Legacy SpMM surface — thin deprecation shim over ``repro.sparse``.

``spmm()`` (and the per-path helpers below) predate the unified
``SparseMatrix`` API.  They keep working — forwarding to the dispatch
machinery / the shared path implementations in ``repro.sparse.paths`` —
but emit a ``DeprecationWarning`` with the migration hint.  New code
should use::

    from repro.sparse import SparseMatrix
    y = SparseMatrix.from_dense(a) @ h

See ``repro.sparse.legacy`` for the deprecation timeline.
"""
from __future__ import annotations

from repro.core.formats import CSR, BlockELL  # noqa: F401  (legacy re-export)
from repro.sparse.legacy import warn_deprecated
from repro.sparse.paths import (csr_to_device_arrays, spmm_dense,
                                spmm_elements)


def spmm(a, h, *, policy: str = "auto", **kw):
    """Y = A @ H for sparse A (BlockELL, SparseMatrix, operand, or dense).

    .. deprecated:: use ``repro.sparse.SparseMatrix`` / ``A @ h``.
    """
    warn_deprecated(
        "repro.core.spmm.spmm",
        "use repro.sparse: SparseMatrix.from_dense(a) @ h "
        "(policy/use_kernel/interpret move to repro.sparse.ops.matmul)")
    from repro.dispatch.dispatcher import dispatch_spmm

    return dispatch_spmm(a, h, policy=policy, **kw)


def spmm_csr(row_ids, col_ids, values, h, num_rows: int):
    """Y = A @ H via gather + segment-sum (forwards to repro.sparse)."""
    return spmm_elements(row_ids, col_ids, values, h, num_rows)
