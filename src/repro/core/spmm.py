"""Public SpMM API:  Y = A @ H  with sparse A.

Three execution paths, mirroring the paper's design space:
  * Block-ELL Pallas kernel (TPU target; `repro.kernels.spmm`) — the
    SELLPACK-like streaming design.
  * Block-ELL jnp reference — same math, XLA-fused; CPU path and oracle.
  * Element-level CSR segment-sum — the general scalar path (and the analog
    of the paper's initial CSR-streaming design); exact for any sparsity
    pattern without blocking/padding overhead, but does not use the MXU.

``spmm`` routes between them through the sparsity-adaptive dispatch
layer (repro.dispatch): policy "auto" applies the cost model over the
operand's measured sparsity structure, "autotune" times the candidates
once per (shape, dtype, sparsity-bucket), and "ell"/"csr"/"dense" force
a path.  The low-level per-path entry points below remain public for
callers that have already planned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import CSR, BlockELL


def spmm(a, h, *, policy: str = "auto", **kw):
    """Y = A @ H for sparse A (BlockELL, SparseOperand, or dense).

    Dispatches to the Block-ELL kernel/reference, the CSR element path,
    or the dense fallback based on ``policy`` — see repro.dispatch.
    """
    from repro.dispatch.dispatcher import dispatch_spmm

    return dispatch_spmm(a, h, policy=policy, **kw)


# ---------------------------------------------------------------------------
# Element-level CSR path (jnp; the "initial design" analog)
# ---------------------------------------------------------------------------


def csr_to_device_arrays(csr: CSR):
    """Expand CSR to (row_ids, col_ids, values) device arrays."""
    row_ids = np.repeat(
        np.arange(csr.shape[0], dtype=np.int32), np.diff(csr.indptr)
    )
    return (
        jnp.asarray(row_ids),
        jnp.asarray(csr.indices),
        jnp.asarray(csr.values),
    )


def spmm_csr(row_ids, col_ids, values, h, num_rows: int):
    """Y = A @ H via gather + segment-sum (element-granular)."""
    gathered = values[:, None].astype(jnp.float32) * h[col_ids].astype(
        jnp.float32
    )
    out = jax.ops.segment_sum(gathered, row_ids, num_segments=num_rows)
    return out.astype(h.dtype)


def spmm_dense(a_dense, h):
    """Dense baseline (the paper's Fig. 2 failure mode)."""
    return a_dense @ h
