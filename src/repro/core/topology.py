"""Load balancing & format-parameter selection.

The paper (§2.4) identifies load balancing across PEs as a first-order
concern: uneven nonzero distribution inflates SELLPACK padding (their Fig. 8
footprint blowup) and idles workers.  The TPU analog is ELL-width padding:
one pathologically dense block-row forces W up for every row.  The standard
SELL fix — sort rows by nonzero count so each slice is uniform — is applied
here as a *block-row permutation*, plus helpers to pick W from an occupancy
target instead of the worst row.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.formats import CSR, _cdiv


def block_row_counts(dense: np.ndarray, bm: int, bn: int) -> np.ndarray:
    """Number of nonzero (bm x bn) blocks in each block-row."""
    m, n = dense.shape
    nbr, nbc = _cdiv(m, bm), _cdiv(n, bn)
    pad = np.zeros((nbr * bm, nbc * bn), dtype=bool)
    pad[:m, :n] = dense != 0
    tiles = pad.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
    return tiles.reshape(nbr, nbc, -1).any(-1).sum(-1).astype(np.int64)


def balance_permutation(counts: np.ndarray) -> np.ndarray:
    """Permutation sorting (block-)rows by descending nonzero count.

    Mirrors Sliced-ELLPACK row sorting: after permuting, rows with similar
    work are adjacent, so chunked/sliced processing sees uniform streams.
    Returns ``perm`` such that ``dense[perm]`` is balanced.
    """
    return np.argsort(-counts, kind="stable")


def snake_permutation(counts: np.ndarray, n_parts: int) -> np.ndarray:
    """Snake (boustrophedon) assignment of rows to ``n_parts`` partitions.

    Used by the distributed 1.5D path so every mesh shard receives
    approximately equal nonzero work — the cross-chip version of the
    paper's router column-range balancing.
    """
    order = np.argsort(-counts, kind="stable")
    n = len(counts)
    rows_per = _cdiv(n, n_parts)
    slots = np.empty(n, dtype=np.int64)
    part_fill = np.zeros(n_parts, dtype=np.int64)
    loads = np.zeros(n_parts, dtype=np.int64)
    for r in order:
        p = int(np.argmin(loads + (part_fill >= rows_per) * 10**15))
        slots[r] = p * rows_per + part_fill[p]
        part_fill[p] += 1
        loads[p] += counts[r]
    perm = np.empty(n, dtype=np.int64)
    perm[slots] = np.arange(n)
    # perm maps new position -> old row, as expected by dense[perm]
    out = np.empty(n, dtype=np.int64)
    for new_pos, old in enumerate(perm):
        out[new_pos] = old
    return out


def choose_ell_width(
    counts: np.ndarray, occupancy_target: float = 0.0
) -> int:
    """Pick ELL width W.

    occupancy_target=0 reproduces the paper's behaviour (pad to the worst
    row).  A target in (0, 1] picks the smallest W such that
    kept_blocks / (n_rows * W) >= target, i.e. trades a bounded amount of
    dropped (explicitly handled out-of-band) work for padding reduction —
    exposed for experimentation, not used by default.
    """
    w_max = int(counts.max()) if len(counts) else 1
    if occupancy_target <= 0:
        return max(w_max, 1)
    total = counts.sum()
    for w in range(1, w_max + 1):
        kept = np.minimum(counts, w).sum()
        if kept / max(total, 1) >= occupancy_target:
            return w
    return max(w_max, 1)


def padding_stats(counts: np.ndarray, w: int | None = None) -> dict:
    w = w or int(counts.max())
    total_slots = len(counts) * w
    real = int(np.minimum(counts, w).sum())
    return {
        "ell_width": w,
        "occupancy": real / max(total_slots, 1),
        "padding_ratio": total_slots / max(real, 1),
        "max_count": int(counts.max()) if len(counts) else 0,
        "mean_count": float(counts.mean()) if len(counts) else 0.0,
    }


def csr_row_counts(csr: CSR) -> np.ndarray:
    return np.diff(csr.indptr).astype(np.int64)
