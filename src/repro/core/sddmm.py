"""Legacy SDDMM surface — thin deprecation shim over ``repro.sparse``.

``sddmm()`` keeps working (forwarding through the dispatch machinery)
but emits a ``DeprecationWarning``; new code should use::

    from repro.sparse import SparseMatrix, sample
    s = sample(SparseMatrix.from_dense(mask), b, c)   # or A.sddmm(b, c)

See ``repro.sparse.legacy`` for the deprecation timeline.
"""
from __future__ import annotations

from repro.core.formats import BlockCOO  # noqa: F401  (legacy re-export)
from repro.sparse.legacy import warn_deprecated
from repro.sparse.paths import sddmm_element_dots


def sddmm(a, b, c, *, policy: str = "auto", **kw) -> BlockCOO:
    """SDDMM for sparse-mask A (BlockCOO or dense); returns BlockCOO.

    .. deprecated:: use ``repro.sparse.sample`` / ``A.sddmm(b, c)``.
    """
    warn_deprecated(
        "repro.core.sddmm.sddmm",
        "use repro.sparse: sample(SparseMatrix.from_dense(mask), b, c) "
        "(policy/use_kernel/interpret move to repro.sparse.ops.sddmm)")
    from repro.dispatch.dispatcher import dispatch_sddmm

    return dispatch_sddmm(a, b, c, policy=policy, **kw)


def sddmm_coo(row_ids, col_ids, b, c):
    """Element-granular SDDMM dots (forwards to repro.sparse)."""
    return sddmm_element_dots(row_ids, col_ids, b, c)
