"""Public SDDMM API:  Y = A ⊙ (B @ C)  computed only at A's nonzeros."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCOO
from repro.kernels.sddmm.ops import sddmm_blockcoo as _sddmm_kernelpath


def sddmm(a: BlockCOO, b, c, **kw) -> BlockCOO:
    """Block-granular SDDMM (kernel or reference path)."""
    return _sddmm_kernelpath(a, b, c, **kw)


def sddmm_coo(row_ids, col_ids, b, c):
    """Element-granular SDDMM: out[e] = b[row[e]] . c[:, col[e]].

    The scalar path used by GAT on CPU and as the general-pattern oracle.
    b: [M, K]; c: [K, N] -> values[e] for each coordinate.
    """
    bs = b[row_ids].astype(jnp.float32)  # [nnz, K]
    cs = c.T[col_ids].astype(jnp.float32)  # [nnz, K]
    return jnp.sum(bs * cs, axis=-1).astype(b.dtype)
