"""Public SDDMM API:  Y = A ⊙ (B @ C)  computed only at A's nonzeros.

``sddmm`` routes through the sparsity-adaptive dispatch layer
(repro.dispatch): the blocked Block-COO path, the element-COO scalar
path, or the dense-sample fallback, per the chosen policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCOO


def sddmm(a, b, c, *, policy: str = "auto", **kw) -> BlockCOO:
    """SDDMM for sparse-mask A (BlockCOO or dense); returns BlockCOO."""
    from repro.dispatch.dispatcher import dispatch_sddmm

    return dispatch_sddmm(a, b, c, policy=policy, **kw)


def sddmm_coo(row_ids, col_ids, b, c):
    """Element-granular SDDMM: out[e] = b[row[e]] . c[:, col[e]].

    The scalar path used by GAT on CPU and as the general-pattern oracle.
    b: [M, K]; c: [K, N] -> values[e] for each coordinate.
    """
    bs = b[row_ids].astype(jnp.float32)  # [nnz, K]
    cs = c.T[col_ids].astype(jnp.float32)  # [nnz, K]
    return jnp.sum(bs * cs, axis=-1).astype(b.dtype)
